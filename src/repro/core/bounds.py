"""Paper §4/§5 analysis: the loss upper bound G(K), its lazy-client variant,
and the optimal number of integrated rounds K*.

Equations (numbers follow the paper):
  (3)  tau = floor((t_sum/K - beta) / alpha)
  (4)  G(K) = 1 / g(K),
       g(K) = gamma*eta*phi - [ (delta*xi*K/L)(lambda^(gamma/K) - 1)
                                - eta*xi*delta*gamma ] / eps^2
       lambda = eta*L + 1,  gamma = (t_sum - K*beta)/alpha  (= K*tau)
  (6)  K* = t_sum / sqrt(2*alpha*beta/(eta*L) + alpha*beta + beta^2)
  (8)  lazy bound: g_lazy(K) = g(K) - (K*xi/eps^2) * (M/N*theta + sqrt(M)/N*sigma^2)

The proofs set eps^2 = delta*xi/phi (Appendix C); we default to that choice.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class BoundParams:
    """Learning-theoretic constants of Theorem 1."""
    eta: float            # learning rate (eta * L < 1 required)
    L: float              # smoothness
    xi: float             # Lipschitz constant of F_i
    delta: float          # gradient divergence (Definition 1)
    alpha: float          # training time per local iteration
    beta: float           # mining time per block
    t_sum: float          # total computing-time budget
    w0_dist: float = 1.0  # ||w^0 - w*||_2
    eps2: Optional[float] = None  # eps^2; None => delta*xi/phi (Appendix C)

    @property
    def phi(self) -> float:
        return (1.0 - self.eta * self.L / 2.0) / self.w0_dist

    @property
    def epsilon2(self) -> float:
        if self.eps2 is not None:
            return self.eps2
        return self.delta * self.xi / self.phi

    @property
    def lam(self) -> float:
        return self.eta * self.L + 1.0


def gamma(p: BoundParams, K: float) -> float:
    """Total local iterations K*tau (continuous relaxation of eq. 3)."""
    return (p.t_sum - K * p.beta) / p.alpha


def g_of_k(p: BoundParams, K: float, *, M: int = 0, N: int = 1,
           theta: float = 0.0, sigma2: float = 0.0) -> float:
    """Denominator g(K) of the bound; the bound is 1/g when g > 0.

    With M > 0 this is L(K) of Appendix G (lazy clients, eq. 38).
    """
    gam = gamma(p, K)
    if gam <= 0 or K <= 0:
        return float("-inf")
    lam_pow = p.lam ** (gam / K)
    h_term = (p.delta * p.xi * K / p.L) * (lam_pow - 1.0) - p.eta * p.xi * p.delta * gam
    g = gam * p.eta * p.phi - h_term / p.epsilon2
    if M > 0:
        g -= (K * p.xi / p.epsilon2) * (M / N * theta + math.sqrt(M) / N * sigma2)
    return g


def loss_bound(p: BoundParams, K: int, **lazy) -> float:
    """G(K) (eq. 4) or lazy G~(K) (eq. 8). +inf when the bound is vacuous."""
    g = g_of_k(p, K, **lazy)
    if g <= 0:
        return float("inf")
    return 1.0 / g


def k_star_closed_form(p: BoundParams) -> float:
    """Theorem 3, eq. (6) — valid when eta*L*gamma/K << 1."""
    return p.t_sum / math.sqrt(
        2.0 * p.alpha * p.beta / (p.eta * p.L) + p.alpha * p.beta + p.beta ** 2)


def k_star_numeric(p: BoundParams, *, k_max: Optional[int] = None,
                   M: int = 0, N: int = 1, theta: float = 0.0,
                   sigma2: float = 0.0) -> int:
    """Integer argmin of the bound over feasible K (tau >= 1)."""
    if k_max is None:
        k_max = int(p.t_sum / (p.alpha + p.beta))  # need tau >= 1
    k_max = max(k_max, 1)
    best_k, best_v = 1, float("inf")
    for k in range(1, k_max + 1):
        if gamma(p, k) / k < 1.0:   # tau < 1: infeasible
            continue
        v = loss_bound(p, k, M=M, N=N, theta=theta, sigma2=sigma2)
        if v < best_v:
            best_k, best_v = k, v
    return best_k


def is_convex_in_k(p: BoundParams, *, k_max: Optional[int] = None, **lazy) -> bool:
    """Empirical convexity check of G(K) on the feasible grid (Theorem 2)."""
    if k_max is None:
        k_max = int(p.t_sum / (p.alpha + p.beta))
    ks = [k for k in range(1, max(k_max, 3) + 1) if gamma(p, k) / k >= 1.0]
    vs = [loss_bound(p, k, **lazy) for k in ks]
    vs = [v for v in vs if math.isfinite(v)]
    if len(vs) < 3:
        return True
    d2 = np.diff(vs, 2)
    return bool(np.all(d2 >= -1e-9 * np.maximum(1.0, np.abs(vs[1:-1]))))


def estimate_constants(loss_curve, grad_norms=None) -> dict:
    """Crude empirical (L, xi, delta) estimates from observed training — used
    by benchmarks to instantiate the bound against experiments (§7)."""
    losses = np.asarray(loss_curve, dtype=np.float64)
    dl = np.abs(np.diff(losses))
    xi = float(np.max(dl)) if dl.size else 1.0
    L = 2.0 * xi
    delta = float(np.std(losses)) if losses.size > 1 else 0.1
    return {"L": max(L, 1e-3), "xi": max(xi, 1e-3), "delta": max(delta, 1e-3)}
