"""Paper §4/§5 analysis: the loss upper bound G(K), its lazy-client variant,
and the optimal number of integrated rounds K*.

Equations (numbers follow the paper):
  (3)  tau = floor((t_sum/K - beta) / alpha)
  (4)  G(K) = 1 / g(K),
       g(K) = gamma*eta*phi - [ (delta*xi*K/L)(lambda^(gamma/K) - 1)
                                - eta*xi*delta*gamma ] / eps^2
       lambda = eta*L + 1,  gamma = (t_sum - K*beta)/alpha  (= K*tau)
  (6)  K* = t_sum / sqrt(2*alpha*beta/(eta*L) + alpha*beta + beta^2)
  (8)  lazy bound: g_lazy(K) = g(K) - (K*xi/eps^2) * (M/N*theta + sqrt(M)/N*sigma^2)

The proofs set eps^2 = delta*xi/phi (Appendix C); we default to that choice.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class BoundParams:
    """Learning-theoretic constants of Theorem 1."""
    eta: float            # learning rate (eta * L < 1 required)
    L: float              # smoothness
    xi: float             # Lipschitz constant of F_i
    delta: float          # gradient divergence (Definition 1)
    alpha: float          # training time per local iteration
    beta: float           # mining time per block
    t_sum: float          # total computing-time budget
    w0_dist: float = 1.0  # ||w^0 - w*||_2
    eps2: Optional[float] = None  # eps^2; None => delta*xi/phi (Appendix C)

    @property
    def phi(self) -> float:
        return (1.0 - self.eta * self.L / 2.0) / self.w0_dist

    @property
    def epsilon2(self) -> float:
        if self.eps2 is not None:
            return self.eps2
        return self.delta * self.xi / self.phi

    @property
    def lam(self) -> float:
        return self.eta * self.L + 1.0


def gamma(p: BoundParams, K: float) -> float:
    """Total local iterations K*tau (continuous relaxation of eq. 3)."""
    return (p.t_sum - K * p.beta) / p.alpha


def g_of_k(p: BoundParams, K: float, *, M: int = 0, N: int = 1,
           theta: float = 0.0, sigma2: float = 0.0) -> float:
    """Denominator g(K) of the bound; the bound is 1/g when g > 0.

    With M > 0 this is L(K) of Appendix G (lazy clients, eq. 38).
    """
    gam = gamma(p, K)
    if gam <= 0 or K <= 0:
        return float("-inf")
    lam_pow = p.lam ** (gam / K)
    h_term = (p.delta * p.xi * K / p.L) * (lam_pow - 1.0) - p.eta * p.xi * p.delta * gam
    g = gam * p.eta * p.phi - h_term / p.epsilon2
    if M > 0:
        g -= (K * p.xi / p.epsilon2) * (M / N * theta + math.sqrt(M) / N * sigma2)
    return g


def loss_bound(p: BoundParams, K: int, **lazy) -> float:
    """G(K) (eq. 4) or lazy G~(K) (eq. 8). +inf when the bound is vacuous."""
    g = g_of_k(p, K, **lazy)
    if g <= 0:
        return float("inf")
    return 1.0 / g


def k_star_closed_form(p: BoundParams) -> float:
    """Theorem 3, eq. (6) — valid when eta*L*gamma/K << 1."""
    return p.t_sum / math.sqrt(
        2.0 * p.alpha * p.beta / (p.eta * p.L) + p.alpha * p.beta + p.beta ** 2)


def k_star_numeric(p: BoundParams, *, k_max: Optional[int] = None,
                   M: int = 0, N: int = 1, theta: float = 0.0,
                   sigma2: float = 0.0) -> int:
    """Integer argmin of the bound over feasible K (tau >= 1)."""
    if k_max is None:
        k_max = int(p.t_sum / (p.alpha + p.beta))  # need tau >= 1
    k_max = max(k_max, 1)
    best_k, best_v = 1, float("inf")
    for k in range(1, k_max + 1):
        if gamma(p, k) / k < 1.0:   # tau < 1: infeasible
            continue
        v = loss_bound(p, k, M=M, N=N, theta=theta, sigma2=sigma2)
        if v < best_v:
            best_k, best_v = k, v
    return best_k


def _finite_runs(vs):
    """Maximal contiguous runs of finite values (each a list)."""
    runs, cur = [], []
    for v in vs:
        if math.isfinite(v):
            cur.append(v)
        elif cur:
            runs.append(cur)
            cur = []
    if cur:
        runs.append(cur)
    return runs


def is_convex_in_k(p: BoundParams, *, k_max: Optional[int] = None, **lazy) -> bool:
    """Empirical convexity check of G(K) on the feasible grid (Theorem 2).

    Vacuous bounds (``G = +inf`` where ``g <= 0``) punch holes in the grid;
    a second difference is only meaningful between ADJACENT feasible Ks, so
    convexity is checked per contiguous finite window. (Filtering the
    non-finite values out first and diffing the concatenation — the old
    behavior — compares Ks across a vacuous gap and mis-reports convexity
    near the feasibility boundary.)"""
    if k_max is None:
        k_max = int(p.t_sum / (p.alpha + p.beta))
    ks = [k for k in range(1, max(k_max, 3) + 1) if gamma(p, k) / k >= 1.0]
    vs = [loss_bound(p, k, **lazy) for k in ks]
    for run in _finite_runs(vs):
        if len(run) < 3:
            continue
        d2 = np.diff(run, 2)
        if not np.all(d2 >= -1e-9 * np.maximum(1.0, np.abs(run[1:-1]))):
            return False
    return True


def estimate_constants(loss_curve, grad_norms=None) -> dict:
    """Crude empirical (L, xi, delta) estimates from observed training — used
    by benchmarks to instantiate the bound against experiments (§7).

    With ``grad_norms`` (per-round gradient-norm observations ``g_t``) the
    estimates use the gradients directly: ``xi`` — the Lipschitz constant of
    F, i.e. a gradient-norm bound — is ``max_t g_t``, and smoothness L comes
    from gradient increments along the GD path: one step moves the iterate
    by ``eta * g_t`` and the loss by ``|Delta l_t| ~= eta * g_t^2``, so
    ``|Delta g_t| <= L * eta * g_t`` gives ``L >= |Delta g_t| * g_t /
    |Delta l_t|`` with the unknown ``eta`` cancelling. Without
    ``grad_norms`` (or with a degenerate curve) it falls back to the
    loss-curve heuristic."""
    losses = np.asarray(loss_curve, dtype=np.float64)
    dl = np.abs(np.diff(losses))
    delta = float(np.std(losses)) if losses.size > 1 else 0.1
    g = (np.asarray(grad_norms, dtype=np.float64).ravel()
         if grad_norms is not None else np.zeros(0))
    if g.size >= 2:
        xi = float(np.max(np.abs(g)))
        dg = np.abs(np.diff(g))
        n = min(dg.size, dl.size)
        # only form the ratio on rounds where the loss actually moved —
        # a plateau round (dl ~ 0) with a nonzero gradient change would
        # otherwise explode the max
        scale = float(np.max(np.abs(losses))) if losses.size else 1.0
        moved = dl[:n] > 1e-9 * max(1.0, scale)
        ratios = dg[:n][moved] * np.abs(g[:n][moved]) / dl[:n][moved]
        ratios = ratios[np.isfinite(ratios) & (ratios > 0)]
        L = float(np.max(ratios)) if ratios.size else 2.0 * xi
    else:
        xi = float(np.max(dl)) if dl.size else 1.0
        L = 2.0 * xi
    return {"L": max(L, 1e-3), "xi": max(xi, 1e-3), "delta": max(delta, 1e-3)}
