"""Blockchain ledger (paper §2.2 / §3.1 Steps 2-5).

Python-level chain used by the simulation driver and by tests; the in-step
JAX state only carries ``prev_hash`` (uint32) and the round counter, and the
driver appends a full Block per integrated round. Validation recomputes the
hash links and the PoW target — a tampered model digest or reordered chain
fails verification (tested in tests/test_chain.py).
"""
from __future__ import annotations

import dataclasses
import hashlib
import struct
from typing import List, Optional


def sha_u32(*words: int) -> int:
    """uint32 digest via sha256 over packed words (ledger-level hash)."""
    payload = struct.pack(f"<{len(words)}I", *[w & 0xFFFFFFFF for w in words])
    return struct.unpack("<I", hashlib.sha256(payload).digest()[:4])[0]


@dataclasses.dataclass(frozen=True)
class Block:
    index: int                 # integrated round k
    prev_hash: int             # uint32
    model_digest: int          # uint32 digest of the aggregated model
    winner: int                # client id that mined the block
    nonce: int                 # winning nonce
    pow_hash: int              # mix-hash achieved by the winner (uint32)

    @property
    def header_hash(self) -> int:
        return sha_u32(self.index, self.prev_hash, self.model_digest,
                       self.winner, self.nonce, self.pow_hash)


GENESIS_HASH = sha_u32(0xB1ADE, 0xF1)


class Ledger:
    """Append-only validated chain; every client in the sim shares one copy
    (consensus is assumed honest-majority per the paper)."""

    def __init__(self, difficulty_bits: int = 0):
        self.blocks: List[Block] = []
        self.difficulty_bits = difficulty_bits

    @property
    def head_hash(self) -> int:
        return self.blocks[-1].header_hash if self.blocks else GENESIS_HASH

    def append(self, block: Block) -> None:
        if not self.validate_block(block, self.head_hash, len(self.blocks)):
            raise ValueError(f"invalid block at index {block.index}")
        self.blocks.append(block)

    def validate_block(self, block: Block, expect_prev: int, expect_idx: int) -> bool:
        if block.index != expect_idx or block.prev_hash != expect_prev:
            return False
        if self.difficulty_bits:
            target = 0xFFFFFFFF >> self.difficulty_bits
            if block.pow_hash > target:
                return False
        return True

    def validate_chain(self) -> bool:
        prev = GENESIS_HASH
        for i, b in enumerate(self.blocks):
            if not self.validate_block(b, prev, i):
                return False
            prev = b.header_hash
        return True

    def tampered_copy(self, index: int, **changes) -> "Ledger":
        """Return a copy with block ``index`` altered (for tamper tests)."""
        out = Ledger(self.difficulty_bits)
        out.blocks = list(self.blocks)
        out.blocks[index] = dataclasses.replace(out.blocks[index], **changes)
        return out


def make_block(index: int, prev_hash: int, model_digest: int, winner: int,
               nonce: int, pow_hash: int) -> Block:
    return Block(index=index, prev_hash=int(prev_hash) & 0xFFFFFFFF,
                 model_digest=int(model_digest) & 0xFFFFFFFF,
                 winner=int(winner), nonce=int(nonce) & 0xFFFFFFFF,
                 pow_hash=int(pow_hash) & 0xFFFFFFFF)


def ledger_from_scan(digests, winners, nonces, pow_hashes,
                     ledger: Optional[Ledger] = None) -> Ledger:
    """Rebuild the host-side ledger from stacked scan outputs.

    The compiled multi-round engine (core/rounds.run_blade_fl_scan) keeps all
    K rounds on device and returns the block-header fields as length-K arrays
    in a single host transfer. This replays them through ``Ledger.append``,
    which re-validates every hash link (and the PoW target when the ledger
    enforces one) — so the scan path produces the exact chain the per-round
    Python driver would have built.
    """
    ledger = ledger if ledger is not None else Ledger()
    start = len(ledger.blocks)
    for i in range(len(digests)):
        block = make_block(
            index=start + i, prev_hash=ledger.head_hash,
            model_digest=int(digests[i]), winner=int(winners[i]),
            nonce=int(nonces[i]), pow_hash=int(pow_hashes[i]))
        ledger.append(block)
    if not ledger.validate_chain():
        raise ValueError("scan-reconstructed ledger failed chain validation")
    return ledger
