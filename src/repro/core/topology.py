"""Communication topologies for the model-broadcast step (paper §3.1 Step 2).

The paper's BLADE-FL broadcasts every model to every client and every client
adopts the same aggregate — a full mesh, i.e. the row-stochastic mixing
matrix ``W = 11^T / C``. Related work (BLADE-FL with lazy clients,
arXiv:2012.02044; blockchain-aided wireless FL, arXiv:2406.00752) studies
regimes where that broadcast is partial or lossy: ring gossip over a sparse
overlay, i.i.d. per-round link dropout on wireless channels, and static
partial participation. This module expresses all of them as one abstraction:

    a ``Topology`` yields a row-stochastic mixing matrix ``W [C, C]``
    per round; client i's post-communication model is
    ``sum_j W[i, j] * model_j`` (``aggregation.mix``).

Every topology is a frozen (hashable) dataclass so it can live inside
``rounds.RoundSpec`` — which is both an ``lru_cache`` key for the compiled
runners and part of the closure of the jitted round. Stochastic topologies
(``RandomGraph``) draw their per-round graph from a PRNG key folded with the
round index, so the compiled ``lax.scan`` engine and the per-round Python
loop see identical matrices round for round.

``FullMesh`` is the paper baseline: ``rounds.make_integrated_round``
dispatches it straight to ``aggregation.fedavg`` so the default behaviour is
bit-for-bit identical to the pre-topology engine (a matmul by ``11^T / C``
would only be float-close).

Mesh lowering hook
------------------

Besides its matrix, every topology advertises HOW its mix should execute on
a client-sharded device mesh: :meth:`Topology.lowering` returns a
:class:`MixLowering` tag the engine's communicate stage dispatches on —
``all_reduce`` (FullMesh: one weighted all-reduce over the client axis),
``neighbor_permute`` (Ring: halo ``collective_permute``s, O(window)
communication independent of C), or ``gather`` (any W: masked all-gather
fallback). The lowered paths live in ``core/aggregation`` and reproduce
their dense twins bit for bit — see that module's docstring for why the
fp32 association is pinned.

One kind opts out of that contract: asked with ``fast_allreduce=True``
(``RoundSpec.fast_allreduce``), ``FullMesh`` — and any deterministic
topology whose mixing matrix has uniform rows (:meth:`Topology.uniform_row`)
— advertises ``psum`` instead: a true in-mesh ``lax.psum`` of locally
pre-weighted rows (``aggregation.mix_psum``) that moves ~C/D× less data but
reassociates fp32. Dense non-uniform matrices keep the ``gather`` kind and
the engine routes them through ``aggregation.mix_psum_dense`` under the
same flag. Both live under the tolerance equivalence tier
(docs/architecture.md §The tolerance tier), not the bitwise one.

Schedules (time-varying topologies)
-----------------------------------

A :class:`Schedule` is a topology whose mixing matrix varies with the round
index — the scheduled-broadcast regimes of wireless blockchain-FL
(arXiv:2406.00752): one-peer gossip rotations (:class:`GossipRotation`),
epoch-alternating overlays (:class:`AlternatingSchedule`, e.g. ring for k
rounds then a full-mesh sync round), and SNR-derived link-quality weighting
(:class:`LinkQualitySchedule`). Every schedule is periodic with period
``P = period(n_clients)``: round ``t`` uses the phase ``t % P``. The engine
compiles a schedule into the single ``lax.scan`` without retracing across
K — deterministic schedules become a static ``[P, C, C]`` matrix table
indexed by the traced round counter (or, for rotations, a ``lax.switch``
over P static permute branches), stochastic ones draw their phase graph
from the carried PRNG key exactly like ``RandomGraph`` — so the compiled
scan and the per-round Python loop stay bit-for-bit equivalent. The
spectral quantity connecting a schedule to the paper's bound — the gap
``1 - |lambda_2(W)|`` and its ergodic product-matrix version — lives in
``core/spectral.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# MixLowering kinds (module constants so the engine can dispatch without
# string literals scattered around).
ALL_REDUCE = "all_reduce"
NEIGHBOR_PERMUTE = "neighbor_permute"
GATHER = "gather"
# Opt-in fast-not-bitwise kind: a true in-mesh psum of locally pre-weighted
# rows (aggregation.mix_psum). Only advertised when the engine asks with
# fast_allreduce=True — it reassociates fp32, so it lives under the
# tolerance equivalence tier, not the bitwise contract
# (docs/architecture.md §The tolerance tier).
PSUM = "psum"
# Sparse kind: per-client neighbor index lists + edge weights
# (:class:`SparseLowering`), mixed by ``aggregation.mix_segment`` — gather +
# ``segment_sum``, O(C·deg) instead of the dense O(C²) matmul. Advertised
# natively by :class:`ExplicitSparse`; the engine also reroutes GATHER
# topologies here when their exported sparse form has max degree ≪ C
# (``rounds.segment_lowering``).
SEGMENT = "segment"
# Two-level kind (:class:`ClusterTopology`): dense intra-cluster mean +
# narrow ring exchange between cluster means, executed by
# ``aggregation.mix_cluster`` — on a cluster-aligned ('pod', 'data') mesh
# the in-cluster reduce stays inside a pod and only the two neighbor
# cluster means cross pods. Bitwise (fixed-order combine), unlike PSUM.
CLUSTER = "cluster"
# Byzantine-robust kind: the spec's ``robust_agg`` overrides the topology's
# advertised lowering with a robust consensus reducer over the FULL
# broadcast set (``aggregation.mix_median`` / ``mix_trimmed`` /
# ``mix_geomedian``) — per-coordinate order statistics are defined over the
# whole client axis, so the topology matrix is not consulted. Lowers as
# all-gather + replicated order statistics; robust reductions are not
# psum-associative, so the kind lives under the TOLERANCE equivalence tier
# (docs/architecture.md §Robust aggregation).
ROBUST = "robust"

# Executor strategies a resolved :class:`MixPlan` selects for the
# communicate stage. Deliberately DISJOINT from the MixLowering kind
# strings above: ``rounds.make_communicate`` switches on ``plan.mode``
# only, so no kind-string comparison exists outside this module (the
# single-decision-surface contract repro-lint rule RL205 enforces).
EXEC_FEDAVG = "exec_fedavg"            # aggregation.mix_all_reduce
EXEC_PSUM = "exec_psum"                # aggregation.mix_psum (tolerance)
EXEC_PSUM_DENSE = "exec_psum_dense"    # aggregation.mix_psum_dense (tol.)
EXEC_SEGMENT = "exec_segment"          # aggregation.mix_segment
EXEC_SHIFT_TABLE = "exec_shift_table"  # lax.switch over per-phase shifts
EXEC_HALO = "exec_halo"                # aggregation.mix_neighbor_halo
EXEC_SHIFT_HALO = "exec_shift_halo"    # aggregation.mix_shift_halo
EXEC_CLUSTER = "exec_cluster"          # aggregation.mix_cluster
EXEC_GATHER = "exec_gather"            # aggregation.mix_gather (needs W)
EXEC_MEDIAN = "exec_median"            # aggregation.mix_median (tolerance)
EXEC_TRIMMED = "exec_trimmed"          # aggregation.mix_trimmed (tol.)
EXEC_GEOMED = "exec_geomed"            # aggregation.mix_geomedian (tol.)

# Auto sparse-mix crossover: reroute a GATHER mix through segment_sum only
# when the padded max degree is ≪ C — degree * 8 <= C keeps every shipped
# small-C config (C <= 20, windows/active sets >= C/8) on its dense bitwise
# path while cohort-scale populations (deg 64, C 10k) go sparse.
SEGMENT_DEGREE_FACTOR = 8

# Largest C for which a sparse topology may be densified back to a [C, C]
# matrix (SparseLowering.to_dense, spectral diagnostics). 4096² fp32 is
# 64 MiB — past that the dense form defeats the point of the sparse path.
DENSIFY_MAX_CLIENTS = 4096


@dataclasses.dataclass(frozen=True)
class MixLowering:
    """How a topology's mix executes on a client-sharded mesh.

    ``kind`` is one of :data:`ALL_REDUCE`, :data:`NEIGHBOR_PERMUTE`,
    :data:`GATHER`, :data:`PSUM` (the opt-in fast-not-bitwise all-reduce,
    only returned when ``lowering`` is asked with ``fast_allreduce=True``).
    ``offsets``/``weight`` are only populated for
    ``neighbor_permute``: client ``i`` adopts
    ``weight * sum_off model[(i + off) % C]``, accumulated in the fixed
    ``offsets`` order (the order is part of the contract — it pins the fp32
    association so dense and sharded execution agree bitwise).

    ``offsets_table`` is the schedule variant: one offsets tuple per phase
    of a periodic schedule (``GossipRotation``), dispatched by the traced
    round counter through a ``lax.switch`` over static permute branches —
    round-dependent offsets with no retrace across K.

    >>> Ring(neighbors=1).lowering(8).kind
    'neighbor_permute'
    >>> Ring(neighbors=1).lowering(8).offsets
    (-1, 0, 1)
    >>> FullMesh().lowering(8).kind
    'all_reduce'
    >>> FullMesh().lowering(8, fast_allreduce=True).kind
    'psum'
    >>> RandomGraph(p_link=0.5).lowering(8).kind
    'gather'
    >>> RandomGraph(p_link=0.5).lowering(8, fast_allreduce=True).kind
    'gather'
    >>> GossipRotation().lowering(4).offsets_table
    ((0, 1), (0, 2), (0, 3))
    """
    kind: str
    offsets: Tuple[int, ...] = ()
    weight: float = 0.0
    offsets_table: Tuple[Tuple[int, ...], ...] = ()


@dataclasses.dataclass(frozen=True, eq=False)
class MixPlan:
    """The fully resolved execution plan for one spec's Steps 2+5 mix.

    Built exclusively by :func:`resolve_mix_plan` — the ONE place where the
    topology's advertised :class:`MixLowering`, the |D_i| data-weight
    reroute, the sparse segment crossover, and the fast-psum / fused-kernel
    tiers are reconciled. ``rounds.make_communicate`` executes the plan by
    switching on :attr:`mode` (an ``EXEC_*`` strategy, disjoint from the
    kind strings so no kind comparison leaks out of this module), and
    ``rounds.dispatch_plan`` reports :attr:`mix` / :attr:`mode` verbatim —
    they cannot drift because neither re-derives anything.

    ``weights`` / ``psum_row`` / ``sparse`` are host-side numpy payloads
    (the executor converts to device arrays at trace time), which is why
    the dataclass is ``eq=False``: plans are per-factory artifacts, never
    cache keys — the hashable ``RoundSpec`` stays the cache key.
    """
    mode: str                   # EXEC_* executor strategy
    kind: str                   # MixLowering kind after reroutes
    mix: str            # dispatch tier: "fused" | "segment" | "robust" | "jnp"
    offsets: Tuple[int, ...] = ()
    weight: float = 0.0
    offsets_table: Tuple[Tuple[int, ...], ...] = ()
    period: int = 1             # schedule period (1 for static topologies)
    n_shards: int = 1           # product of the mesh_axes extents
    fast_diagnostics: bool = False   # psum'd digest/divergence (tolerance)
    use_kernel: bool = False    # fused Pallas mix tier (spec.fused_mix)
    needs_matrix: bool = False  # executor must trace topo.matrix(...)
    n_clusters: int = 0         # EXEC_CLUSTER: G
    inter_weight: float = 0.0   # EXEC_CLUSTER: alpha
    trim: int = 0               # EXEC_TRIMMED: per-tail trim count
    robust_iters: int = 0       # EXEC_GEOMED: static Weiszfeld iterations
    # eq=False (identity hash): a plan is never a static-arg/lru key, so
    # the unhashable-frozen-dataclass concern behind RL102 does not apply
    # repro-lint: disable=RL102
    weights: Optional[np.ndarray] = None    # |D_i| data weights [C]
    # repro-lint: disable=RL102
    psum_row: Optional[np.ndarray] = None   # EXEC_PSUM per-client weighting
    sparse: Optional["SparseLowering"] = None   # EXEC_SEGMENT edge lists


# Default Weiszfeld iteration count for robust_agg="geomed" (static — it
# compiles into the scan; 8 is ample at FL client counts, see
# aggregation.robust_geomedian).
GEOMED_DEFAULT_ITERS = 8


def parse_robust(name: str, n_clients: int) -> Tuple[str, int, int]:
    """Parse a ``RoundSpec.robust_agg`` spec into ``(mode, trim, iters)``.

    ``median`` | ``trimmed[:t]`` (default ``t=1``; needs ``2t < C``) |
    ``geomed[:iters]`` (default 8 Weiszfeld iterations). ``mean`` is
    accepted as the explicit linear baseline and handled by the caller
    (falls through to the normal topology resolution).

    >>> parse_robust("median", 8)
    ('exec_median', 0, 0)
    >>> parse_robust("trimmed:2", 8)
    ('exec_trimmed', 2, 0)
    >>> parse_robust("geomed", 8)
    ('exec_geomed', 0, 8)
    """
    head, _, arg = name.strip().lower().partition(":")
    if head == "median":
        return EXEC_MEDIAN, 0, 0
    if head in ("trimmed", "trim", "trimmed_mean"):
        t = int(arg) if arg else 1
        if not 0 <= 2 * t < n_clients:
            raise ValueError(
                f"robust_agg={name!r}: trim={t} must satisfy "
                f"2*trim < n_clients={n_clients}")
        return EXEC_TRIMMED, t, 0
    if head in ("geomed", "geomedian", "geometric_median"):
        iters = int(arg) if arg else GEOMED_DEFAULT_ITERS
        if iters < 1:
            raise ValueError(f"robust_agg={name!r}: needs >= 1 Weiszfeld "
                             "iteration")
        return EXEC_GEOMED, 0, iters
    raise ValueError(f"unknown robust_agg {name!r} (expected mean | median "
                     "| trimmed[:t] | geomed[:iters])")


def _resolve_robust(spec, c: int, n_shards: int) -> "MixPlan | None":
    """The ROBUST-kind plan when ``spec.robust_agg`` selects one, else None.

    Robust consensus preempts the whole linear decision ladder, and the
    flags that only make sense for linear mixes are rejected loudly rather
    than silently ignored: the psum/fused tiers reassociate a LINEAR
    reduction that no longer exists, a sparse edge list cannot express
    per-coordinate order statistics, and |D_i| row weights have no
    agreed-upon robust semantics (a weighted median would change the
    breakdown point)."""
    robust = getattr(spec, "robust_agg", None)
    if robust in (None, "mean"):
        return None
    mode, trim, iters = parse_robust(robust, c)
    conflicts = [flag for flag, on in (
        ("fast_allreduce", spec.fast_allreduce),
        ("fused_mix", spec.fused_mix),
        ("sparse_mix=True", spec.sparse_mix is True),
        ("data_weights", spec.data_weights is not None)) if on]
    if conflicts:
        raise ValueError(
            f"robust_agg={robust!r} is incompatible with "
            f"{', '.join(conflicts)}: robust reducers are order statistics "
            "over the full broadcast set — no psum/fused linear fast path, "
            "no sparse edge-list form, no |D_i| row reweighting")
    return MixPlan(mode=mode, kind=ROBUST, mix="robust",
                   n_shards=n_shards, trim=trim, robust_iters=iters)


def _resolve_sparse(spec, topo, kind) -> "SparseLowering | None":
    """The SparseLowering this spec mixes through, or None for dense mixes
    (``RoundSpec.sparse_mix`` tri-state; see :func:`resolve_mix_plan`)."""
    if spec.sparse_mix is False:
        return None
    if kind == SEGMENT:
        return topo.sparse_lowering(spec.n_clients)
    if spec.sparse_mix is True:
        sp = topo.sparse_lowering(spec.n_clients)
        if sp is None:
            raise ValueError(
                f"sparse_mix=True but {type(topo).__name__} exports no "
                "static sparse lowering (stochastic topologies and "
                "schedules change their graph per round; very large C "
                "cannot be densified to derive one)")
        return sp
    # auto: only GATHER-kind dense mixes, and never preempt the opt-in
    # psum/fused tiers the user asked for explicitly
    if kind != GATHER or spec.fast_allreduce or spec.fused_mix:
        return None
    sp = topo.sparse_lowering(spec.n_clients)
    if sp is not None and \
            sp.max_degree * SEGMENT_DEGREE_FACTOR <= spec.n_clients:
        return sp
    return None


def resolve_mix_plan(spec, mesh_axes=None) -> MixPlan:
    """Resolve a round spec's mix into a :class:`MixPlan` — the single
    decision surface for HOW Steps 2+5 execute.

    ``spec`` is duck-typed (``rounds.RoundSpec`` in practice): the resolver
    reads ``topology``, ``n_clients``, ``data_weights``, ``fast_allreduce``,
    ``fused_mix``, ``sparse_mix`` and (optionally) ``robust_agg``. ``mesh_axes`` is ``None`` for
    single-device execution or a tuple of ``(axis_name, extent)`` pairs for
    the client-sharded mesh — only the extent product (the shard count,
    which bounds the one-block halo window) feeds the decision; per-axis
    extents are read back from the mesh at trace time by the collectives.

    Decisions folded in (each previously derived independently somewhere in
    ``core/rounds.py``):

      * the |D_i| data-weight reroute: permute/cluster lowerings bake
        uniform weights, so a weighted spec mixes through its dense matrix;
      * the sparse segment crossover (native SEGMENT topologies, forced
        ``sparse_mix=True``, or the auto max-degree ≪ C reroute);
      * the ``fast_allreduce`` psum tier (uniform-row → EXEC_PSUM with the
        pre-weighted row, dense → EXEC_PSUM_DENSE) and its psum'd
        diagnostics;
      * halo feasibility: NEIGHBOR_PERMUTE offsets inside one shard block
        run the two-permute halo (EXEC_HALO), anything else the whole-block
        shift form (EXEC_SHIFT_HALO) — both linearize multi-axis meshes, so
        there is no gather fallback for permute kinds anymore;
      * the Byzantine-robust override (``robust_agg`` — median / trimmed /
        geomed): preempts everything above, rejects the linear-only flags,
        and routes to the ROBUST kind's EXEC_MEDIAN / EXEC_TRIMMED /
        EXEC_GEOMED executor modes (tolerance tier).

    >>> from types import SimpleNamespace
    >>> def _spec(topo, **kw):
    ...     base = dict(topology=topo, n_clients=8, data_weights=None,
    ...                 fast_allreduce=False, fused_mix=False,
    ...                 sparse_mix=None)
    ...     return SimpleNamespace(**{**base, **kw})
    >>> resolve_mix_plan(_spec(FullMesh())).mode
    'exec_fedavg'
    >>> resolve_mix_plan(_spec(Ring(neighbors=1))).mode
    'exec_halo'
    >>> resolve_mix_plan(_spec(Ring(neighbors=2)),
    ...                  (("pod", 2), ("data", 4))).mode
    'exec_shift_halo'
    >>> resolve_mix_plan(_spec(FullMesh(), fast_allreduce=True)).mode
    'exec_psum'
    >>> resolve_mix_plan(_spec(ClusterTopology(n_clusters=2))).mode
    'exec_cluster'
    >>> resolve_mix_plan(_spec(RandomGraph(p_link=0.5))).needs_matrix
    True
    >>> resolve_mix_plan(_spec(Ring(neighbors=1),
    ...                        robust_agg="trimmed:2")).mode
    'exec_trimmed'
    """
    topo = spec.topology
    c = spec.n_clients
    n_shards = 1
    for _, extent in (mesh_axes or ()):
        n_shards *= max(int(extent), 1)
    n_local = c // n_shards

    # Byzantine-robust consensus (spec.robust_agg, duck-typed optional so
    # pre-existing SimpleNamespace specs resolve unchanged) preempts the
    # linear ladder below entirely — the reducer is defined over the full
    # broadcast set and never consults the topology matrix.
    robust_plan = _resolve_robust(spec, c, n_shards)
    if robust_plan is not None:
        return robust_plan

    low = topo.lowering(c, fast_allreduce=spec.fast_allreduce)
    kind = low.kind

    weights = None
    if spec.data_weights is not None:
        if len(spec.data_weights) != c:
            raise ValueError(
                f"data_weights has {len(spec.data_weights)} entries, "
                f"expected n_clients={c}")
        weights = np.asarray(spec.data_weights, np.float32)

    # |D_i| weights reshape each row of W; the permute and cluster lowerings
    # hard-code uniform weights, so weighted mixes go through the matrix.
    if weights is not None and kind in (NEIGHBOR_PERMUTE, CLUSTER):
        kind = GATHER

    sparse = _resolve_sparse(spec, topo, kind)
    if sparse is not None and weights is not None:
        # |D_i| reweighting folds into the edge weights so the traced mix
        # stays one gather + segment_sum
        sparse = sparse.reweighted(weights)

    # the opt-in psum tier covers the dense kinds only (permute lowerings
    # already move O(window) data and stay bitwise); a forced segment mix
    # takes precedence — it moves O(C·deg), less than the psum's O(C)
    fast_dense = (spec.fast_allreduce and sparse is None
                  and kind in (PSUM, GATHER))

    psum_row = None
    if kind == PSUM:
        if topo.is_full_mesh:
            psum_row = weights
        else:
            row = np.asarray(topo.uniform_row(c), np.float32)
            psum_row = row if weights is None else row * weights

    period = topo.period(c) if isinstance(topo, Schedule) else 1

    if fast_dense:
        mode = EXEC_PSUM if kind == PSUM else EXEC_PSUM_DENSE
    elif sparse is not None:
        mode = EXEC_SEGMENT
    elif kind == ALL_REDUCE:
        mode = EXEC_FEDAVG
    elif kind == CLUSTER:
        mode = EXEC_CLUSTER
    elif kind == NEIGHBOR_PERMUTE and low.offsets_table:
        mode = EXEC_SHIFT_TABLE
    elif kind == NEIGHBOR_PERMUTE:
        # the two-permute halo needs the window inside one shard block;
        # larger shifts use the whole-block permute form — both linearize
        # multi-axis meshes, so permute kinds never fall back to a gather
        halo_ok = (low.offsets and -min(low.offsets) <= n_local
                   and max(low.offsets) <= n_local)
        mode = EXEC_HALO if halo_ok else EXEC_SHIFT_HALO
    else:
        mode = EXEC_GATHER

    n_clusters = int(getattr(topo, "n_clusters", 0)) if kind == CLUSTER \
        else 0
    inter_w = float(getattr(topo, "inter_weight", 0.0)) if kind == CLUSTER \
        else 0.0

    return MixPlan(
        mode=mode, kind=kind,
        mix=("fused" if spec.fused_mix
             else "segment" if sparse is not None else "jnp"),
        offsets=low.offsets, weight=low.weight,
        offsets_table=low.offsets_table, period=period, n_shards=n_shards,
        fast_diagnostics=fast_dense, use_kernel=spec.fused_mix,
        needs_matrix=mode in (EXEC_GATHER, EXEC_PSUM_DENSE),
        n_clusters=n_clusters, inter_weight=inter_w,
        weights=weights, psum_row=psum_row, sparse=sparse)


class SparseLowering:
    """Edge-list form of a mixing matrix: ``[C, D]`` neighbor indices + edge
    weights, padded to the max degree ``D`` for ragged safety.

    ``neighbor_idx[i]`` lists the clients whose models client ``i`` mixes,
    ``edge_w[i]`` the matching row weights; rows shorter than ``D`` are
    padded with the client's own index at weight 0 (a harmless self-edge, so
    padded gathers stay in-bounds and contribute nothing). The represented
    dense matrix is ``W[i, neighbor_idx[i, d]] += edge_w[i, d]``, and
    ``aggregation.mix_segment`` applies it in O(C·D) instead of O(C²).

    This is a RUNTIME object, not a spec: it holds raw arrays, is never
    hashed, and is built at stage-build time from a hashable ``Topology``
    (``Topology.sparse_lowering`` / :func:`sparse_from_dense`).

    >>> import numpy as np
    >>> sp = sparse_from_dense(np.asarray(Ring(neighbors=1).matrix(4)))
    >>> sp.n_clients, sp.max_degree
    (4, 3)
    >>> bool(np.allclose(sp.to_dense(), Ring(neighbors=1).matrix(4)))
    True
    """

    __slots__ = ("neighbor_idx", "edge_w")

    def __init__(self, neighbor_idx, edge_w):
        idx = np.asarray(neighbor_idx, np.int32)
        w = np.asarray(edge_w, np.float32)
        if idx.ndim != 2 or idx.shape != w.shape:
            raise ValueError(
                f"neighbor_idx {idx.shape} and edge_w {w.shape} must be "
                "matching [n_clients, max_degree] arrays")
        if idx.shape[1] < 1:
            raise ValueError("SparseLowering needs max_degree >= 1")
        if idx.size and (idx.min() < 0 or idx.max() >= idx.shape[0]):
            raise ValueError(
                f"neighbor indices must lie in [0, {idx.shape[0]}), got "
                f"range [{idx.min()}, {idx.max()}]")
        self.neighbor_idx = idx
        self.edge_w = w

    @property
    def n_clients(self) -> int:
        return self.neighbor_idx.shape[0]

    @property
    def max_degree(self) -> int:
        return self.neighbor_idx.shape[1]

    def to_dense(self, *,
                 max_clients: int = DENSIFY_MAX_CLIENTS) -> np.ndarray:
        """The represented dense ``[C, C]`` matrix — small C only: spectral
        diagnostics and equivalence tests, never the engine's mix path."""
        c = self.n_clients
        if c > max_clients:
            raise ValueError(
                f"refusing to densify a SparseLowering with n_clients={c} > "
                f"{max_clients}: the [C, C] matrix is what the sparse path "
                "exists to avoid (raise max_clients explicitly if you truly "
                "want it)")
        w = np.zeros((c, c), np.float32)
        rows = np.repeat(np.arange(c), self.max_degree)
        np.add.at(w, (rows, self.neighbor_idx.reshape(-1)),
                  self.edge_w.reshape(-1))
        return w

    def reweighted(self, weights) -> "SparseLowering":
        """|D_j| data-size reweighting, the edge-list twin of
        ``aggregation._reweight_rows``: ``w'[i, d] ∝ w[i, d] *
        weights[neighbor_idx[i, d]]``, renormalized per row."""
        wvec = np.asarray(weights, np.float32)
        if wvec.shape != (self.n_clients,):
            raise ValueError(
                f"weights shape {wvec.shape} != ({self.n_clients},)")
        w = self.edge_w * wvec[self.neighbor_idx]
        return SparseLowering(self.neighbor_idx,
                              w / w.sum(axis=1, keepdims=True))


def sparse_from_dense(w, *, min_degree: int = 1) -> SparseLowering:
    """Convert a dense mixing matrix to its edge-list form.

    Each row keeps its nonzero entries in ascending column order — the same
    order the dense matmul's contraction visits them — padded to the max
    row degree (at least ``min_degree``) with weight-0 self-edges.

    >>> import numpy as np
    >>> sp = sparse_from_dense(np.eye(3, dtype=np.float32))
    >>> sp.max_degree
    1
    >>> [int(i) for i in sp.neighbor_idx.ravel()]
    [0, 1, 2]
    """
    w = np.asarray(w, np.float32)
    if w.ndim != 2 or w.shape[0] != w.shape[1]:
        raise ValueError(f"expected a square [C, C] matrix, got {w.shape}")
    c = w.shape[0]
    nz = [np.flatnonzero(w[i]) for i in range(c)]
    d = max(max((len(r) for r in nz), default=0), min_degree, 1)
    idx = np.tile(np.arange(c, dtype=np.int32)[:, None], (1, d))
    ew = np.zeros((c, d), np.float32)
    for i, cols in enumerate(nz):
        idx[i, :len(cols)] = cols
        ew[i, :len(cols)] = w[i, cols]
    return SparseLowering(idx, ew)


@dataclasses.dataclass(frozen=True)
class Topology:
    """Base topology = full mesh. Subclasses override :meth:`matrix`.

    The contract: :meth:`matrix` returns a float32 row-stochastic ``[C, C]``
    array — every entry ``W[i, j] >= 0`` and every row sums to 1 — where
    ``W[i, j]`` is the weight client i puts on client j's broadcast model
    (``aggregation.mix``; row-stochasticity is what keeps the mix a convex
    combination, so a consensus state is a fixed point for every topology).
    ``key`` is only consulted when :attr:`stochastic` is True; ``round_idx``
    additionally selects the phase of a :class:`Schedule` (time-varying
    topologies — deterministic ones read it too). Both may be traced values
    (the engine calls this inside ``lax.scan``).
    """

    @property
    def is_full_mesh(self) -> bool:
        return False

    @property
    def stochastic(self) -> bool:
        """True when the mixing matrix needs per-round randomness."""
        return False

    def matrix(self, n_clients: int, *, key=None, round_idx=None) -> jnp.ndarray:
        raise NotImplementedError

    def uniform_row(self, n_clients: int):
        """The shared row ``r`` when every round's ``W`` has identical rows
        (``W = 1 rᵀ``), else None. Such a mix is rank-1 — every client adopts
        the same r-weighted average — so under ``fast_allreduce`` it lowers
        to a true psum of locally pre-weighted rows (O(1) models moved per
        device instead of O(C)). Host-side, deterministic topologies only."""
        if self.stochastic:
            return None
        try:
            if isinstance(self, Schedule):
                mats = [np.asarray(self.matrix_at(t, n_clients))
                        for t in range(self.period(n_clients))]
            else:
                mats = [np.asarray(self.matrix(n_clients))]
        except NotImplementedError:
            return None
        row = mats[0][0]
        for m in mats:
            if not (m == row[None, :]).all():
                return None
        return row

    def lowering(self, n_clients: int, *,
                 fast_allreduce: bool = False) -> MixLowering:
        """The mesh execution strategy for this topology's mix (see module
        docstring). Default: the masked all-gather fallback, correct for any
        row-stochastic ``W``. With ``fast_allreduce=True`` a deterministic
        topology whose rows are uniform (see :meth:`uniform_row`) advertises
        the reassociating :data:`PSUM` kind instead — tolerance tier, not
        bitwise."""
        if fast_allreduce and self.uniform_row(n_clients) is not None:
            return MixLowering(kind=PSUM)
        return MixLowering(kind=GATHER)

    def sparse_lowering(self, n_clients: int) -> "SparseLowering | None":
        """Edge-list export of this topology's mix, or None when no static
        sparse form exists (stochastic draws, time-varying schedules) or
        densifying to derive one would defeat the sparse path
        (``n_clients > DENSIFY_MAX_CLIENTS``). Subclasses whose structure is
        known analytically (:class:`PartialParticipation`,
        :class:`ExplicitSparse`) override this to build edges directly in
        O(C·deg) without ever touching a ``[C, C]`` matrix."""
        if self.stochastic or isinstance(self, Schedule):
            return None
        if n_clients > DENSIFY_MAX_CLIENTS:
            return None
        try:
            w = np.asarray(self.matrix(n_clients))
        except NotImplementedError:
            return None
        return sparse_from_dense(w)


@dataclasses.dataclass(frozen=True)
class FullMesh(Topology):
    """Paper baseline: every broadcast reaches everyone, ``W = 11^T / C``.

    >>> import numpy as np
    >>> w = np.asarray(FullMesh().matrix(4))
    >>> bool((w == 0.25).all()) and bool(np.allclose(w.sum(axis=1), 1.0))
    True
    """

    @property
    def is_full_mesh(self) -> bool:
        return True

    def matrix(self, n_clients: int, *, key=None, round_idx=None) -> jnp.ndarray:
        return jnp.full((n_clients, n_clients), 1.0 / n_clients, jnp.float32)

    def lowering(self, n_clients: int, *,
                 fast_allreduce: bool = False) -> MixLowering:
        """One weighted all-reduce over the client axis (= ``fedavg``).
        Opted into ``fast_allreduce``, the gather-side all-reduce becomes a
        true in-mesh ``lax.psum`` (:data:`PSUM`) — ~C/D× less data moved,
        fp32 reassociated (tolerance tier)."""
        if fast_allreduce:
            return MixLowering(kind=PSUM)
        return MixLowering(kind=ALL_REDUCE)


@dataclasses.dataclass(frozen=True)
class Ring(Topology):
    """Static ring gossip: each client averages itself with ``neighbors``
    clients on each side, uniformly over the *distinct* window members
    (wrap-around never double-counts a client), so ``neighbors >= C//2``
    degenerates to the full mesh numerically — though still mixed through
    ``aggregation.mix``, not the ``fedavg`` fast path."""
    neighbors: int = 1

    def __post_init__(self):
        if self.neighbors < 1:
            raise ValueError("Ring needs neighbors >= 1")

    def matrix(self, n_clients: int, *, key=None, round_idx=None) -> jnp.ndarray:
        w = np.zeros((n_clients, n_clients), np.float32)
        span = range(-self.neighbors, self.neighbors + 1)
        for i in range(n_clients):
            for off in span:
                w[i, (i + off) % n_clients] = 1.0
        return jnp.asarray(w / w.sum(axis=1, keepdims=True))

    def lowering(self, n_clients: int, *,
                 fast_allreduce: bool = False) -> MixLowering:
        """Neighbor ``collective_permute`` halo when the window is distinct
        (``2·neighbors + 1 <= C``); otherwise the window wraps onto itself,
        the dedup'd :meth:`matrix` is authoritative, and the gather fallback
        applies it. ``fast_allreduce`` is a no-op here — the halo already
        moves O(window) data and stays bitwise."""
        window = 2 * self.neighbors + 1
        if window > n_clients:
            return MixLowering(kind=GATHER)
        offsets = tuple(range(-self.neighbors, self.neighbors + 1))
        return MixLowering(kind=NEIGHBOR_PERMUTE, offsets=offsets,
                           weight=1.0 / window)


@dataclasses.dataclass(frozen=True)
class RandomGraph(Topology):
    """Per-round i.i.d. link dropout: each directed link (i, j != i) delivers
    with probability ``p_link``; the self-link always does. Rows renormalize
    over the delivered set, so ``W`` is row-stochastic for every draw.
    ``p_link = 1`` is numerically the full mesh; ``p_link = 0`` is no
    communication at all (every client keeps its own model)."""
    p_link: float = 0.8

    def __post_init__(self):
        if not 0.0 <= self.p_link <= 1.0:
            raise ValueError("p_link must be in [0, 1]")

    @property
    def stochastic(self) -> bool:
        return True

    def matrix(self, n_clients: int, *, key=None, round_idx=None) -> jnp.ndarray:
        if key is None:
            raise ValueError("RandomGraph.matrix needs a PRNG key")
        if round_idx is not None:
            key = jax.random.fold_in(key, round_idx)
        links = jax.random.bernoulli(
            key, self.p_link, (n_clients, n_clients)).astype(jnp.float32)
        adj = jnp.maximum(links, jnp.eye(n_clients, dtype=jnp.float32))
        return adj / jnp.sum(adj, axis=1, keepdims=True)


@dataclasses.dataclass(frozen=True)
class PartialParticipation(Topology):
    """Static partial participation: only the first ``n_active`` clients take
    part in the broadcast round (they adopt the average over the active set);
    the remaining clients keep their own models untouched."""
    n_active: int

    def __post_init__(self):
        if self.n_active < 1:
            raise ValueError("PartialParticipation needs n_active >= 1")

    def matrix(self, n_clients: int, *, key=None, round_idx=None) -> jnp.ndarray:
        if self.n_active > n_clients:
            raise ValueError(
                f"n_active={self.n_active} exceeds n_clients={n_clients}")
        w = np.eye(n_clients, dtype=np.float32)
        w[:self.n_active, :] = 0.0
        w[:self.n_active, :self.n_active] = 1.0 / self.n_active
        return jnp.asarray(w)

    def sparse_lowering(self, n_clients: int) -> "SparseLowering | None":
        """Edges built directly in O(C·n_active) — no dense [C, C] detour,
        so this stays exportable at any enrolled-population scale. Active
        rows list the active block in ascending order (the dense matmul's
        contraction order); inactive rows are degree-1 self-loops padded
        with weight-0 self-edges."""
        if self.n_active > n_clients:
            raise ValueError(
                f"n_active={self.n_active} exceeds n_clients={n_clients}")
        a, c = self.n_active, n_clients
        idx = np.tile(np.arange(c, dtype=np.int32)[:, None], (1, a))
        ew = np.zeros((c, a), np.float32)
        idx[:a] = np.arange(a, dtype=np.int32)[None, :]
        ew[:a] = 1.0 / a
        ew[a:, 0] = 1.0
        return SparseLowering(idx, ew)

    def uniform_row(self, n_clients: int):
        """Never rank-1 for n_active < n_clients — and deriving that via the
        base class would densify the matrix, which must not happen at
        enrolled-population scale. n_active == n_clients IS the full mesh's
        uniform row (cheap to build directly)."""
        if self.n_active == n_clients:
            return np.full((n_clients,), 1.0 / n_clients, np.float32)
        return None


@dataclasses.dataclass(frozen=True)
class PairShift(Topology):
    """One-peer pairing at a fixed shift: client ``i`` averages itself with
    client ``(i + shift) % C``, each at weight 1/2 — one phase of a gossip
    rotation, also usable standalone. ``shift % C == 0`` degenerates to the
    identity (every client keeps its own model).

    >>> import numpy as np
    >>> w = np.asarray(PairShift(shift=1).matrix(4))
    >>> [float(v) for v in w[0]]
    [0.5, 0.5, 0.0, 0.0]
    >>> bool(np.allclose(w.sum(axis=0), 1.0))    # doubly stochastic
    True
    """
    shift: int = 1

    def __post_init__(self):
        if self.shift < 0:
            raise ValueError("PairShift needs shift >= 0")

    def matrix(self, n_clients: int, *, key=None, round_idx=None) -> jnp.ndarray:
        w = np.zeros((n_clients, n_clients), np.float32)
        for i in range(n_clients):
            w[i, i] += 0.5
            w[i, (i + self.shift) % n_clients] += 0.5
        return jnp.asarray(w)

    def lowering(self, n_clients: int, *,
                 fast_allreduce: bool = False) -> MixLowering:
        """Self + one partner ``collective_permute`` (any shift — the halo
        generalizes to whole-block permutes, see
        ``aggregation.mix_shift_halo``). Already O(1) and bitwise;
        ``fast_allreduce`` changes nothing."""
        return MixLowering(kind=NEIGHBOR_PERMUTE,
                           offsets=(0, self.shift % n_clients), weight=0.5)


@dataclasses.dataclass(frozen=True)
class ClusterTopology(Topology):
    """Two-level hierarchical mix: dense intra-cluster averaging + a sparse
    ring exchange between cluster means (the cluster-then-global aggregation
    of D2D hierarchical FL / two-tier blockchain FL, arXiv:2009.09338 — the
    ~75% traffic-reduction design of SNIPPETS.md Snippet 2).

    The ``n_clusters = G`` contiguous clusters each hold ``S = C / G``
    clients. Every client first adopts its cluster mean, then clusters
    exchange means on a ring: cluster ``g`` keeps weight ``1 - inter_weight``
    on its own mean and puts ``inter_weight / 2`` on each ring neighbor.
    The mixing matrix is the Kronecker product ``W = B ⊗ (J_S / S)`` of the
    cluster-ring circulant ``B`` with the in-cluster averaging block — row
    stochastic by construction, eigenvalues ``(1 - a) + a·cos(2πk/G)``
    (``core/spectral.cluster_spectral_gap`` has the closed form).

    On a cluster-aligned ``('pod', 'data')`` mesh (pod extent == G) the mix
    lowers to an in-pod gather of ``S`` rows plus TWO cross-pod model-sized
    ``ppermute``s — O(S + 2) models moved versus the flat gather's O(C) —
    while staying bitwise (``aggregation.mix_cluster``; fixed-order
    barrier-pinned combine, no psum).

    >>> import numpy as np
    >>> w = np.asarray(ClusterTopology(n_clusters=2,
    ...                                inter_weight=0.5).matrix(4))
    >>> bool(np.allclose(w.sum(axis=1), 1.0))
    True
    >>> [round(float(v), 3) for v in w[0]]
    [0.25, 0.25, 0.25, 0.25]
    >>> ClusterTopology(n_clusters=4).lowering(8).kind
    'cluster'
    """
    n_clusters: int
    inter_weight: float = 0.3

    def __post_init__(self):
        if self.n_clusters < 1:
            raise ValueError("ClusterTopology needs n_clusters >= 1")
        if not 0.0 <= self.inter_weight <= 1.0:
            raise ValueError("inter_weight must be in [0, 1]")

    def _check_divides(self, n_clients: int) -> int:
        if n_clients % self.n_clusters != 0:
            raise ValueError(
                f"n_clients={n_clients} not divisible by "
                f"n_clusters={self.n_clusters}: clusters are contiguous "
                "equal-size client blocks")
        return n_clients // self.n_clusters

    def _cluster_ring(self) -> np.ndarray:
        """The ``[G, G]`` circulant ``B`` over cluster means."""
        g = self.n_clusters
        b = np.zeros((g, g), np.float32)
        for i in range(g):
            b[i, i] += 1.0 - self.inter_weight
            b[i, (i - 1) % g] += self.inter_weight / 2.0
            b[i, (i + 1) % g] += self.inter_weight / 2.0
        return b

    def matrix(self, n_clients: int, *, key=None, round_idx=None) -> jnp.ndarray:
        s = self._check_divides(n_clients)
        w = np.kron(self._cluster_ring(),
                    np.full((s, s), 1.0 / s, np.float32))
        return jnp.asarray(w.astype(np.float32))

    def uniform_row(self, n_clients: int):
        """Constant-row exactly when the cluster circulant ``B`` is
        (G == 1, or the degenerate small-G weights that make every row of
        ``B`` equal) — checked on the tiny ``[G, G]`` block, never by
        densifying ``W`` at population scale."""
        s = self._check_divides(n_clients)
        b = self._cluster_ring()
        if not (b == b[0][None, :]).all():
            return None
        return np.repeat(b[0], s).astype(np.float32) / np.float32(s)

    def lowering(self, n_clients: int, *,
                 fast_allreduce: bool = False) -> MixLowering:
        """Always the :data:`CLUSTER` kind: the two-level mix already moves
        O(S + 2) models and stays bitwise, so ``fast_allreduce`` (a
        reassociating psum that would fork the ledger) changes nothing."""
        self._check_divides(n_clients)
        return MixLowering(kind=CLUSTER, weight=self.inter_weight)


@dataclasses.dataclass(frozen=True)
class ExplicitSparse(Topology):
    """A topology given directly as per-client neighbor lists — the native
    citizen of the sparse path: it advertises the :data:`SEGMENT` kind, its
    :meth:`sparse_lowering` is built straight from the lists (O(C·deg), no
    dense detour), and :meth:`matrix` exists only for small-C diagnostics
    (guarded by ``DENSIFY_MAX_CLIENTS``).

    ``neighbors[i]`` are the clients whose models client ``i`` mixes;
    ``weights[i]`` the matching row weights (default: uniform over the
    listed neighbors). Rows are normalized to sum to 1 at lowering time, so
    the represented matrix is always row-stochastic. Nested tuples keep the
    dataclass hashable — it lives inside ``RoundSpec`` like every topology.

    >>> import numpy as np
    >>> t = ExplicitSparse(neighbors=((0, 1), (0, 1, 2), (1, 2)))
    >>> t.lowering(3).kind
    'segment'
    >>> [float(v) for v in np.asarray(t.matrix(3))[1]]
    [0.3333333432674408, 0.3333333432674408, 0.3333333432674408]
    """
    neighbors: Tuple[Tuple[int, ...], ...]
    weights: Optional[Tuple[Tuple[float, ...], ...]] = None

    def __post_init__(self):
        if not self.neighbors:
            raise ValueError("ExplicitSparse needs at least one client row")
        c = len(self.neighbors)
        for i, row in enumerate(self.neighbors):
            if not row:
                raise ValueError(f"client {i} has an empty neighbor list; "
                                 "give it at least a self-edge (i,)")
            for j in row:
                if not 0 <= j < c:
                    raise ValueError(
                        f"client {i} lists neighbor {j} outside [0, {c})")
        if self.weights is not None:
            if len(self.weights) != c:
                raise ValueError(
                    f"weights has {len(self.weights)} rows, expected {c}")
            for i, (row, wrow) in enumerate(zip(self.neighbors, self.weights)):
                if len(wrow) != len(row):
                    raise ValueError(
                        f"client {i}: {len(wrow)} weights for "
                        f"{len(row)} neighbors")
                if any(w < 0 for w in wrow) or sum(wrow) <= 0:
                    raise ValueError(
                        f"client {i}: row weights must be nonnegative with "
                        "a positive sum")

    @classmethod
    def from_lowering(cls, sparse: SparseLowering) -> "ExplicitSparse":
        """Wrap a runtime :class:`SparseLowering` back into a hashable spec
        (drops weight-0 padding edges)."""
        neighbors, weights = [], []
        for i in range(sparse.n_clients):
            keep = np.flatnonzero(sparse.edge_w[i])
            if keep.size == 0:      # all-zero row: keep a self-loop
                neighbors.append((i,))
                weights.append((1.0,))
                continue
            neighbors.append(tuple(int(j) for j in sparse.neighbor_idx[i, keep]))
            weights.append(tuple(float(w) for w in sparse.edge_w[i, keep]))
        return cls(neighbors=tuple(neighbors), weights=tuple(weights))

    def sparse_lowering(self, n_clients: int) -> SparseLowering:
        if n_clients != len(self.neighbors):
            raise ValueError(
                f"ExplicitSparse defines {len(self.neighbors)} clients but "
                f"the spec asks for n_clients={n_clients}")
        c = n_clients
        d = max(len(row) for row in self.neighbors)
        idx = np.tile(np.arange(c, dtype=np.int32)[:, None], (1, d))
        ew = np.zeros((c, d), np.float32)
        for i, row in enumerate(self.neighbors):
            idx[i, :len(row)] = row
            wrow = (np.ones(len(row), np.float32) if self.weights is None
                    else np.asarray(self.weights[i], np.float32))
            ew[i, :len(row)] = wrow / wrow.sum()
        return SparseLowering(idx, ew)

    def matrix(self, n_clients: int, *, key=None, round_idx=None) -> jnp.ndarray:
        """Dense form for small-C diagnostics only (spectral gaps,
        equivalence tests) — raises past ``DENSIFY_MAX_CLIENTS``."""
        return jnp.asarray(self.sparse_lowering(n_clients).to_dense())

    def lowering(self, n_clients: int, *,
                 fast_allreduce: bool = False) -> MixLowering:
        """Always the :data:`SEGMENT` kind: the gather + ``segment_sum`` mix
        is this topology's canonical execution; ``fast_allreduce`` changes
        nothing (the sparse mix already moves O(C·deg) data)."""
        return MixLowering(kind=SEGMENT)


def ring_neighbors(n_clients: int, neighbors: int = 1
                   ) -> Tuple[Tuple[int, ...], ...]:
    """Neighbor lists of the :class:`Ring` window, for building an
    :class:`ExplicitSparse` ring at populations where the dense ``Ring``
    matrix would be unbuildable. Ascending client order per row (the dense
    contraction order), distinct members only (wrap never double-counts).

    >>> ring_neighbors(5, 1)[0]
    (0, 1, 4)
    """
    if neighbors < 1:
        raise ValueError("ring_neighbors needs neighbors >= 1")
    span = range(-neighbors, neighbors + 1)
    return tuple(
        tuple(sorted({(i + off) % n_clients for off in span}))
        for i in range(n_clients))


# ---------------------------------------------------------------------------
# Schedules: round-indexed (time-varying) topologies
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Schedule(Topology):
    """A time-varying topology: a periodic, round-indexed sequence of mixing
    matrices.

    Subclasses define the schedule through three hooks:

      * :meth:`period` — the cycle length ``P`` (may depend on C);
      * :meth:`topology_at` — the per-phase :class:`Topology` (or override
        :meth:`matrix_at` directly for schedules that construct raw ``W``);
      * the inherited :meth:`matrix` dispatches on the (possibly traced)
        ``round_idx``: deterministic schedules index a static ``[P, C, C]``
        table, stochastic ones ``lax.switch`` into the phase's keyed draw —
        either way one trace covers every round of the compiled scan.

    The engine treats ``round_idx`` as position in the schedule, so the
    per-round Python loop and the ``lax.scan`` engine see identical
    matrices round for round.
    """

    def period(self, n_clients: int) -> int:
        """Cycle length P: round ``t`` uses phase ``t % P``."""
        raise NotImplementedError

    def topology_at(self, t: int, n_clients: int) -> Topology:
        """The topology of phase ``t`` (``0 <= t < P``), host-side."""
        raise NotImplementedError

    def matrix_at(self, t: int, n_clients: int, *, key=None) -> jnp.ndarray:
        """Mixing matrix of phase ``t`` (concrete ``t``)."""
        return self.topology_at(t, n_clients).matrix(
            n_clients, key=key, round_idx=t)

    def matrix(self, n_clients: int, *, key=None, round_idx=None) -> jnp.ndarray:
        p = self.period(n_clients)
        idx = jnp.mod(jnp.asarray(0 if round_idx is None else round_idx,
                                  jnp.int32), p)
        if not self.stochastic:
            table = jnp.stack([self.matrix_at(t, n_clients)
                               for t in range(p)])
            return table[idx]
        if key is None:
            raise ValueError("a stochastic Schedule needs a PRNG key")
        return jax.lax.switch(
            idx, [lambda k, t=t: self.matrix_at(t, n_clients, key=k)
                  for t in range(p)], key)


@dataclasses.dataclass(frozen=True)
class GossipRotation(Schedule):
    """One-peer gossip rotation: at round ``t`` every client pair-averages
    with the partner at shift ``1 + (t * step) % (C - 1)`` — a round-robin
    ``collective_permute`` partner that cycles through every other client
    once per period ``C - 1`` (for ``step`` coprime with ``C - 1``). Each
    round moves one model per client (the cheapest possible broadcast), yet
    the product over a period mixes like a dense graph — the ergodic gap in
    ``core/spectral.py`` makes that precise.

    >>> GossipRotation().period(5)
    4
    >>> [GossipRotation().shift_at(t, 5) for t in range(4)]
    [1, 2, 3, 4]
    >>> GossipRotation(step=2).shift_at(1, 6)
    3
    """
    step: int = 1

    def __post_init__(self):
        if self.step < 1:
            raise ValueError("GossipRotation needs step >= 1")

    def period(self, n_clients: int) -> int:
        return max(n_clients - 1, 1)

    def shift_at(self, t: int, n_clients: int) -> int:
        if n_clients <= 1:
            return 0
        return 1 + (t * self.step) % (n_clients - 1)

    def topology_at(self, t: int, n_clients: int) -> Topology:
        return PairShift(shift=self.shift_at(t, n_clients))

    def lowering(self, n_clients: int, *,
                 fast_allreduce: bool = False) -> MixLowering:
        """Round-dependent ``neighbor_permute``: one offsets pair per phase,
        dispatched by ``lax.switch`` on the round counter. Already O(1)
        communication per round; ``fast_allreduce`` changes nothing."""
        table = tuple((0, self.shift_at(t, n_clients))
                      for t in range(self.period(n_clients)))
        return MixLowering(kind=NEIGHBOR_PERMUTE, weight=0.5,
                           offsets_table=table)


@dataclasses.dataclass(frozen=True)
class AlternatingSchedule(Schedule):
    """Epoch-alternating overlay: cycle through ``phases`` — each a
    ``(topology, n_rounds)`` pair — e.g. ring gossip for k rounds, then one
    full-mesh sync round. Stochastic phase topologies (``RandomGraph``) are
    allowed; the schedule is then stochastic as a whole and draws from the
    engine's per-round topology key.

    >>> s = AlternatingSchedule(((Ring(neighbors=1), 2), (FullMesh(), 1)))
    >>> s.period(8)
    3
    >>> [type(s.topology_at(t, 8)).__name__ for t in range(3)]
    ['Ring', 'Ring', 'FullMesh']
    """
    phases: Tuple[Tuple[Topology, int], ...]

    def __post_init__(self):
        if not self.phases:
            raise ValueError("AlternatingSchedule needs at least one phase")
        for topo, n in self.phases:
            if not isinstance(topo, Topology):
                raise ValueError(f"phase topology {topo!r} is not a Topology")
            if n < 1:
                raise ValueError("phase lengths must be >= 1")

    @property
    def stochastic(self) -> bool:
        return any(t.stochastic for t, _ in self.phases)

    def period(self, n_clients: int) -> int:
        return sum(n for _, n in self.phases)

    def topology_at(self, t: int, n_clients: int) -> Topology:
        t %= self.period(n_clients)
        for topo, n in self.phases:
            if t < n:
                return topo
            t -= n
        raise AssertionError("unreachable: t < period by construction")


@dataclasses.dataclass(frozen=True)
class LinkQualitySchedule(Schedule):
    """SNR-derived link-quality mixing with periodic fading.

    A stylized wireless model on the client ring (arXiv:2406.00752 regime):
    link (i, j) sees ``snr_db - pathloss_db * ring_distance(i, j)`` plus a
    deterministic periodic fading term (period ``fading_period`` rounds,
    per-edge phase), and its weight is the SNR-to-delivery sigmoid
    ``q = snr_lin / (1 + snr_lin)`` — the normalized-capacity / success
    probability proxy. Self links are perfect (``q_ii = 1``) and rows
    renormalize, so every phase matrix is row-stochastic with strictly
    positive entries (ergodic). Per-edge qualities multiply the ``|D_j|``
    data weights when the engine mixes with ``RoundSpec.data_weights``
    (``aggregation.mix(..., weights=)``).

    >>> import numpy as np
    >>> s = LinkQualitySchedule(fading_period=4)
    >>> s.period(6)
    4
    >>> w = np.asarray(s.matrix_at(0, 6))
    >>> bool(np.allclose(w.sum(axis=1), 1.0)) and bool((w > 0).all())
    True
    """
    snr_db: float = 8.0        # reference SNR of a nearest-neighbor link
    pathloss_db: float = 3.0   # attenuation per ring hop
    fading_db: float = 6.0     # peak-to-peak deterministic fading swing
    fading_period: int = 8     # rounds per fading cycle

    def __post_init__(self):
        if self.fading_period < 1:
            raise ValueError("LinkQualitySchedule needs fading_period >= 1")

    def period(self, n_clients: int) -> int:
        return self.fading_period

    def matrix_at(self, t: int, n_clients: int, *, key=None) -> jnp.ndarray:
        i = np.arange(n_clients)[:, None]
        j = np.arange(n_clients)[None, :]
        dist = np.minimum(np.abs(i - j), n_clients - np.abs(i - j))
        # per-edge fading phase so links fade at different rounds
        fade = 0.5 * self.fading_db * np.cos(
            2.0 * np.pi * (t / self.fading_period + (i + j) / n_clients))
        snr_lin = 10.0 ** ((self.snr_db - self.pathloss_db * (dist - 1) + fade)
                           / 10.0)
        q = snr_lin / (1.0 + snr_lin)
        np.fill_diagonal(q, 1.0)
        w = (q / q.sum(axis=1, keepdims=True)).astype(np.float32)
        return jnp.asarray(w)


# ---------------------------------------------------------------------------
# Cohort sampling: active-cohort draws from a large enrolled population
# ---------------------------------------------------------------------------

# fold_in salt deriving the cohort-draw key from the engine's per-round
# k_topo — a dedicated stream so a stochastic INTRA-cohort topology
# (RandomGraph inside the cohort) can keep consuming k_topo itself without
# correlating with the membership draw.
_COHORT_SALT = 0x636F686F  # "coho"


@dataclasses.dataclass(frozen=True)
class CohortSchedule:
    """Per-round active-cohort sampling from an enrolled population.

    Each round, ``cohort_at(k_topo)`` draws ``cohort_size`` distinct clients
    from the ``n_enrolled`` population — the client-scheduling regime of
    arXiv:2406.00752 where only a resource-feasible cohort participates —
    keyed off the engine's per-round ``k_topo`` stream, so
    ``rounds.topology_keys(run_key, K)`` replays the exact membership of
    every round of a run (the same replay contract stochastic topologies
    already honor).

    ``bias`` shapes the selection weights:

      * ``uniform`` — every enrolled client equally likely;
      * ``pareto``  — client ``i`` drawn ∝ ``(i + 1) ** -pareto_alpha``, the
        heavy-tailed participation skew of availability-biased selection
        (Pareto cohort selection per SNIPPETS.md Snippet 2): a head of
        well-connected clients appears nearly every round, the tail rarely;
      * ``prefix``  — deterministically the first ``cohort_size`` clients
        (the :class:`PartialParticipation` association, useful for pinning
        cohort-vs-masked equivalence).

    Weighted sampling WITHOUT replacement is done by the Gumbel top-k trick
    — ``top_k(log w + Gumbel noise)`` draws a distinct k-subset with the
    successive-sampling distribution of ``w`` — which is jit-free,
    shape-static, and O(C_enrolled) per round. The returned cohort is sorted
    ascending so the cohort's intra-round client order (and with it every
    fp32 association downstream) is a pure function of the membership set.

    >>> import jax
    >>> cs = CohortSchedule(n_enrolled=100, cohort_size=8)
    >>> idx = cs.cohort_at(jax.random.key(0))
    >>> int(idx.shape[0]), bool((idx[1:] > idx[:-1]).all())
    (8, True)
    >>> CohortSchedule(10, 3, bias="prefix").cohort_at(jax.random.key(1))
    Array([0, 1, 2], dtype=int32)
    """
    n_enrolled: int
    cohort_size: int
    bias: str = "uniform"
    pareto_alpha: float = 1.1

    def __post_init__(self):
        if self.n_enrolled < 1:
            raise ValueError("CohortSchedule needs n_enrolled >= 1")
        if not 1 <= self.cohort_size <= self.n_enrolled:
            raise ValueError(
                f"cohort_size={self.cohort_size} must lie in "
                f"[1, n_enrolled={self.n_enrolled}]")
        if self.bias not in ("uniform", "pareto", "prefix"):
            raise ValueError(
                f"unknown bias {self.bias!r} "
                "(expected uniform | pareto | prefix)")
        if self.bias == "pareto" and self.pareto_alpha <= 0:
            raise ValueError("pareto bias needs pareto_alpha > 0")

    @classmethod
    def from_spec(cls, n_enrolled: int, cohort_size: int,
                  bias_spec: str = "uniform") -> "CohortSchedule":
        """CLI-friendly constructor: ``bias_spec`` is
        ``uniform | pareto[:alpha] | prefix`` (``--cohort-bias``).

        >>> CohortSchedule.from_spec(100, 8, "pareto:1.5").pareto_alpha
        1.5
        """
        head, _, arg = bias_spec.strip().lower().partition(":")
        if head == "pareto" and arg:
            return cls(n_enrolled, cohort_size, bias="pareto",
                       pareto_alpha=float(arg))
        return cls(n_enrolled, cohort_size, bias=head)

    def weights(self) -> np.ndarray:
        """The normalized per-client selection weights (host-side) — what
        the sampler statistics test checks observed frequencies against."""
        if self.bias == "pareto":
            w = (np.arange(self.n_enrolled, dtype=np.float64) + 1.0) \
                ** -self.pareto_alpha
        elif self.bias == "prefix":
            w = np.zeros(self.n_enrolled, np.float64)
            w[:self.cohort_size] = 1.0
        else:
            w = np.ones(self.n_enrolled, np.float64)
        return w / w.sum()

    def cohort_at(self, k_topo) -> jnp.ndarray:
        """The round's active cohort: ``[cohort_size]`` distinct client ids
        in ascending order, a pure function of the round's ``k_topo``."""
        if self.bias == "prefix":
            return jnp.arange(self.cohort_size, dtype=jnp.int32)
        key = jax.random.fold_in(k_topo, _COHORT_SALT)
        gumbel = jax.random.gumbel(key, (self.n_enrolled,), jnp.float32)
        if self.bias == "pareto":
            scores = gumbel - jnp.float32(self.pareto_alpha) * jnp.log1p(
                jnp.arange(self.n_enrolled, dtype=jnp.float32))
        else:
            scores = gumbel
        _, idx = jax.lax.top_k(scores, self.cohort_size)
        return jnp.sort(idx.astype(jnp.int32))


def from_name(name: str) -> Topology:
    """Parse a CLI-friendly topology / schedule spec.

    Static: ``full`` | ``ring[:neighbors]`` | ``random[:p_link]`` |
    ``partial:n_active`` | ``shift[:s]`` — e.g. ``ring:2``, ``random:0.5``,
    ``partial:10``. Schedules: ``rotate[:step]`` (one-peer gossip rotation)
    | ``alt[:ring_rounds[:mesh_rounds]]`` (ring epochs + full-mesh sync) |
    ``snr[:fading_period]`` (link-quality weighting).

    Hierarchical: ``cluster:n_clusters[:inter_weight]`` — e.g. ``cluster:4``
    or ``cluster:4:0.5`` (contiguous clusters, ring-coupled means).

    >>> from_name("rotate") == GossipRotation()
    True
    >>> from_name("alt:3:1").phases[0]
    (Ring(neighbors=1), 3)
    >>> from_name("snr:4").fading_period
    4
    >>> from_name("cluster:4:0.5")
    ClusterTopology(n_clusters=4, inter_weight=0.5)
    """
    head, _, arg = name.strip().lower().partition(":")
    if head in ("full", "full_mesh", "fullmesh", "mesh"):
        return FullMesh()
    if head == "ring":
        return Ring(neighbors=int(arg) if arg else 1)
    if head in ("random", "dropout", "p"):
        return RandomGraph(p_link=float(arg) if arg else 0.8)
    if head == "partial":
        if not arg:
            raise ValueError("partial topology needs a size: partial:<n_active>")
        return PartialParticipation(n_active=int(arg))
    if head in ("shift", "pair"):
        return PairShift(shift=int(arg) if arg else 1)
    if head in ("rotate", "rotation", "gossip"):
        return GossipRotation(step=int(arg) if arg else 1)
    if head in ("alt", "alternate", "alternating"):
        ring_rounds, _, mesh_rounds = arg.partition(":")
        return AlternatingSchedule((
            (Ring(neighbors=1), int(ring_rounds) if ring_rounds else 3),
            (FullMesh(), int(mesh_rounds) if mesh_rounds else 1)))
    if head in ("snr", "linkquality", "link_quality"):
        return LinkQualitySchedule(
            fading_period=int(arg) if arg else 8)
    if head in ("cluster", "clusters", "hier", "hierarchical"):
        if not arg:
            raise ValueError(
                "cluster topology needs a size: cluster:<n_clusters>[:alpha]")
        g, _, alpha = arg.partition(":")
        return ClusterTopology(n_clusters=int(g),
                               inter_weight=float(alpha) if alpha else 0.3)
    raise ValueError(f"unknown topology {name!r} "
                     "(expected full | ring[:k] | random[:p] | partial:n | "
                     "shift[:s] | cluster:g[:a] | rotate[:step] | "
                     "alt[:k[:m]] | snr[:p])")
