"""Communication topologies for the model-broadcast step (paper §3.1 Step 2).

The paper's BLADE-FL broadcasts every model to every client and every client
adopts the same aggregate — a full mesh, i.e. the row-stochastic mixing
matrix ``W = 11^T / C``. Related work (BLADE-FL with lazy clients,
arXiv:2012.02044; blockchain-aided wireless FL, arXiv:2406.00752) studies
regimes where that broadcast is partial or lossy: ring gossip over a sparse
overlay, i.i.d. per-round link dropout on wireless channels, and static
partial participation. This module expresses all of them as one abstraction:

    a ``Topology`` yields a row-stochastic mixing matrix ``W [C, C]``
    per round; client i's post-communication model is
    ``sum_j W[i, j] * model_j`` (``aggregation.mix``).

Every topology is a frozen (hashable) dataclass so it can live inside
``rounds.RoundSpec`` — which is both an ``lru_cache`` key for the compiled
runners and part of the closure of the jitted round. Stochastic topologies
(``RandomGraph``) draw their per-round graph from a PRNG key folded with the
round index, so the compiled ``lax.scan`` engine and the per-round Python
loop see identical matrices round for round.

``FullMesh`` is the paper baseline: ``rounds.make_integrated_round``
dispatches it straight to ``aggregation.fedavg`` so the default behaviour is
bit-for-bit identical to the pre-topology engine (a matmul by ``11^T / C``
would only be float-close).

Mesh lowering hook
------------------

Besides its matrix, every topology advertises HOW its mix should execute on
a client-sharded device mesh: :meth:`Topology.lowering` returns a
:class:`MixLowering` tag the engine's communicate stage dispatches on —
``all_reduce`` (FullMesh: one weighted all-reduce over the client axis),
``neighbor_permute`` (Ring: halo ``collective_permute``s, O(window)
communication independent of C), or ``gather`` (any W: masked all-gather
fallback). The lowered paths live in ``core/aggregation`` and reproduce
their dense twins bit for bit — see that module's docstring for why the
fp32 association is pinned.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# MixLowering kinds (module constants so the engine can dispatch without
# string literals scattered around).
ALL_REDUCE = "all_reduce"
NEIGHBOR_PERMUTE = "neighbor_permute"
GATHER = "gather"


@dataclasses.dataclass(frozen=True)
class MixLowering:
    """How a topology's mix executes on a client-sharded mesh.

    ``kind`` is one of :data:`ALL_REDUCE`, :data:`NEIGHBOR_PERMUTE`,
    :data:`GATHER`. ``offsets``/``weight`` are only populated for
    ``neighbor_permute``: client ``i`` adopts
    ``weight * sum_off model[(i + off) % C]``, accumulated in the fixed
    ``offsets`` order (the order is part of the contract — it pins the fp32
    association so dense and sharded execution agree bitwise).

    >>> Ring(neighbors=1).lowering(8).kind
    'neighbor_permute'
    >>> Ring(neighbors=1).lowering(8).offsets
    (-1, 0, 1)
    >>> FullMesh().lowering(8).kind
    'all_reduce'
    >>> RandomGraph(p_link=0.5).lowering(8).kind
    'gather'
    """
    kind: str
    offsets: Tuple[int, ...] = ()
    weight: float = 0.0


@dataclasses.dataclass(frozen=True)
class Topology:
    """Base topology = full mesh. Subclasses override :meth:`matrix`.

    The contract: :meth:`matrix` returns a float32 row-stochastic ``[C, C]``
    array — every entry ``W[i, j] >= 0`` and every row sums to 1 — where
    ``W[i, j]`` is the weight client i puts on client j's broadcast model
    (``aggregation.mix``; row-stochasticity is what keeps the mix a convex
    combination, so a consensus state is a fixed point for every topology).
    ``key``/``round_idx`` are only consulted when :attr:`stochastic` is True;
    both may be traced values (the engine calls this inside ``lax.scan``).
    """

    @property
    def is_full_mesh(self) -> bool:
        return False

    @property
    def stochastic(self) -> bool:
        """True when the mixing matrix needs per-round randomness."""
        return False

    def matrix(self, n_clients: int, *, key=None, round_idx=None) -> jnp.ndarray:
        raise NotImplementedError

    def lowering(self, n_clients: int) -> MixLowering:
        """The mesh execution strategy for this topology's mix (see module
        docstring). Default: the masked all-gather fallback, correct for any
        row-stochastic ``W``."""
        return MixLowering(kind=GATHER)


@dataclasses.dataclass(frozen=True)
class FullMesh(Topology):
    """Paper baseline: every broadcast reaches everyone, ``W = 11^T / C``.

    >>> import numpy as np
    >>> w = np.asarray(FullMesh().matrix(4))
    >>> bool((w == 0.25).all()) and bool(np.allclose(w.sum(axis=1), 1.0))
    True
    """

    @property
    def is_full_mesh(self) -> bool:
        return True

    def matrix(self, n_clients: int, *, key=None, round_idx=None) -> jnp.ndarray:
        return jnp.full((n_clients, n_clients), 1.0 / n_clients, jnp.float32)

    def lowering(self, n_clients: int) -> MixLowering:
        """One weighted all-reduce over the client axis (= ``fedavg``)."""
        return MixLowering(kind=ALL_REDUCE)


@dataclasses.dataclass(frozen=True)
class Ring(Topology):
    """Static ring gossip: each client averages itself with ``neighbors``
    clients on each side, uniformly over the *distinct* window members
    (wrap-around never double-counts a client), so ``neighbors >= C//2``
    degenerates to the full mesh numerically — though still mixed through
    ``aggregation.mix``, not the ``fedavg`` fast path."""
    neighbors: int = 1

    def __post_init__(self):
        if self.neighbors < 1:
            raise ValueError("Ring needs neighbors >= 1")

    def matrix(self, n_clients: int, *, key=None, round_idx=None) -> jnp.ndarray:
        w = np.zeros((n_clients, n_clients), np.float32)
        span = range(-self.neighbors, self.neighbors + 1)
        for i in range(n_clients):
            for off in span:
                w[i, (i + off) % n_clients] = 1.0
        return jnp.asarray(w / w.sum(axis=1, keepdims=True))

    def lowering(self, n_clients: int) -> MixLowering:
        """Neighbor ``collective_permute`` halo when the window is distinct
        (``2·neighbors + 1 <= C``); otherwise the window wraps onto itself,
        the dedup'd :meth:`matrix` is authoritative, and the gather fallback
        applies it."""
        window = 2 * self.neighbors + 1
        if window > n_clients:
            return MixLowering(kind=GATHER)
        offsets = tuple(range(-self.neighbors, self.neighbors + 1))
        return MixLowering(kind=NEIGHBOR_PERMUTE, offsets=offsets,
                           weight=1.0 / window)


@dataclasses.dataclass(frozen=True)
class RandomGraph(Topology):
    """Per-round i.i.d. link dropout: each directed link (i, j != i) delivers
    with probability ``p_link``; the self-link always does. Rows renormalize
    over the delivered set, so ``W`` is row-stochastic for every draw.
    ``p_link = 1`` is numerically the full mesh; ``p_link = 0`` is no
    communication at all (every client keeps its own model)."""
    p_link: float = 0.8

    def __post_init__(self):
        if not 0.0 <= self.p_link <= 1.0:
            raise ValueError("p_link must be in [0, 1]")

    @property
    def stochastic(self) -> bool:
        return True

    def matrix(self, n_clients: int, *, key=None, round_idx=None) -> jnp.ndarray:
        if key is None:
            raise ValueError("RandomGraph.matrix needs a PRNG key")
        if round_idx is not None:
            key = jax.random.fold_in(key, round_idx)
        links = jax.random.bernoulli(
            key, self.p_link, (n_clients, n_clients)).astype(jnp.float32)
        adj = jnp.maximum(links, jnp.eye(n_clients, dtype=jnp.float32))
        return adj / jnp.sum(adj, axis=1, keepdims=True)


@dataclasses.dataclass(frozen=True)
class PartialParticipation(Topology):
    """Static partial participation: only the first ``n_active`` clients take
    part in the broadcast round (they adopt the average over the active set);
    the remaining clients keep their own models untouched."""
    n_active: int

    def __post_init__(self):
        if self.n_active < 1:
            raise ValueError("PartialParticipation needs n_active >= 1")

    def matrix(self, n_clients: int, *, key=None, round_idx=None) -> jnp.ndarray:
        if self.n_active > n_clients:
            raise ValueError(
                f"n_active={self.n_active} exceeds n_clients={n_clients}")
        w = np.eye(n_clients, dtype=np.float32)
        w[:self.n_active, :] = 0.0
        w[:self.n_active, :self.n_active] = 1.0 / self.n_active
        return jnp.asarray(w)


def from_name(name: str) -> Topology:
    """Parse a CLI-friendly topology spec.

    ``full`` | ``ring[:neighbors]`` | ``random[:p_link]`` |
    ``partial:n_active`` — e.g. ``ring:2``, ``random:0.5``, ``partial:10``.
    """
    head, _, arg = name.strip().lower().partition(":")
    if head in ("full", "full_mesh", "fullmesh", "mesh"):
        return FullMesh()
    if head == "ring":
        return Ring(neighbors=int(arg) if arg else 1)
    if head in ("random", "dropout", "p"):
        return RandomGraph(p_link=float(arg) if arg else 0.8)
    if head == "partial":
        if not arg:
            raise ValueError("partial topology needs a size: partial:<n_active>")
        return PartialParticipation(n_active=int(arg))
    raise ValueError(f"unknown topology {name!r} "
                     "(expected full | ring[:k] | random[:p] | partial:n)")
