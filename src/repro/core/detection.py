"""Lazy-client detection (beyond-paper: the paper's §8 names this as future
work — "the detection of lazy clients will be addressed in our future work").

Observation: a lazy client's broadcast model is an honest model plus
N(0, sigma^2) noise (eq. 7), so the pairwise distance between the lazy copy
and its source is ~ sigma*sqrt(P) — orders of magnitude below the distance
between two independently-trained non-IID clients (which diverge by the
gradient-divergence delta of Definition 1 times tau*eta). Flagging pairs
whose distance is a small fraction of the cohort median catches plagiarism
without knowing sigma.

Runs on the broadcast models BEFORE aggregation (Step 2 — every client sees
every model, so every client can run detection and vote; consensus on the
flags can ride the existing block validation). Distances are computed on a
deterministic random projection of the flattened models, so the cost is
O(C^2 * sketch) not O(C^2 * P).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def model_sketches(params, sketch_dim: int = 256, seed: int = 0) -> jnp.ndarray:
    """[C, sketch_dim] random-projection sketch of each client's model."""
    leaves = [l.reshape(l.shape[0], -1).astype(jnp.float32)
              for l in jax.tree.leaves(params)]
    flat = jnp.concatenate(leaves, axis=1)              # [C, P]
    key = jax.random.key(seed)
    proj = jax.random.normal(key, (flat.shape[1], sketch_dim)) \
        * (flat.shape[1] ** -0.5)
    return flat @ proj


def pairwise_distances(sketches: jnp.ndarray) -> jnp.ndarray:
    """[C, C] Euclidean distances between client sketches."""
    sq = jnp.sum(sketches ** 2, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2 * sketches @ sketches.T
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def detect_lazy(params, *, threshold_frac: float = 0.2,
                sketch_dim: int = 256, seed: int = 0
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (suspect_mask [C] bool, min_dist_frac [C]).

    A client is flagged when its nearest-neighbour distance is below
    ``threshold_frac`` x median pairwise distance — i.e. its model is a
    near-copy of someone else's. Both members of a plagiarism pair are
    flagged; the protocol-level tie-break (who trained first) is the
    block-timestamp order, outside this function's scope.
    """
    sk = model_sketches(params, sketch_dim, seed)
    d = pairwise_distances(sk)
    c = d.shape[0]
    big = jnp.max(d) + 1.0
    d_offdiag = d + jnp.eye(c) * big
    nearest = jnp.min(d_offdiag, axis=1)                # [C]
    triu = d_offdiag[jnp.triu_indices(c, k=1)]
    median = jnp.median(triu)
    frac = nearest / jnp.maximum(median, 1e-12)
    return frac < threshold_frac, frac


def detect_lazy_round(params, params_ref, *, threshold_frac: float = 0.2,
                      norm_factor: float = 3.0, sketch_dim: int = 256,
                      seed: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Two-sided in-round detector. ``params_ref`` is the previous global
    model (all clients start the round from it, so it's common knowledge).

    Regimes (both real, see tests):
      * sigma*sqrt(P) << honest divergence  -> the copy is anomalously CLOSE
        to its source: nearest-neighbour test (detect_lazy);
      * sigma*sqrt(P) >> honest divergence  -> the isotropic disguise noise
        makes the lazy update anomalously LARGE: update-norm outlier test
        (honest updates are eta*tau*grad-sized; the lazy one carries
        sqrt(sigma^2 * P) extra).
    Returns (suspect_mask, update_norms).
    """
    near_mask, _ = detect_lazy(params, threshold_frac=threshold_frac,
                               sketch_dim=sketch_dim, seed=seed)
    delta = jax.tree.map(
        lambda a, r: a - jnp.broadcast_to(
            r[None] if r.ndim + 1 == a.ndim else r, a.shape).astype(a.dtype),
        params, params_ref)
    sk = model_sketches(delta, sketch_dim, seed)
    norms = jnp.sqrt(jnp.sum(sk.astype(jnp.float32) ** 2, axis=1))
    median = jnp.median(norms)
    outlier_mask = norms > norm_factor * jnp.maximum(median, 1e-12)
    return near_mask | outlier_mask, norms


def detection_metrics(suspect_mask: jnp.ndarray, n_lazy: int) -> dict:
    """Precision/recall against the ground-truth adversarial set (first M
    clients — the shared convention of ``core/lazy.py`` and
    ``core/attacks.py``; note the plagiarism SOURCE is also near its copy,
    so flagged honest sources count against precision — reported, not
    hidden).

    Empty edges use the vacuous-truth convention instead of the old
    guarded-denominator 0.0 (which read as "detector failed" on a clean
    run it handled perfectly): with nothing flagged precision is 1.0, and
    with ``n_lazy == 0`` recall is 1.0 — so a detector that stays quiet on
    an attack-free round scores a perfect (1.0, 1.0), never a
    divide-by-zero artifact (regression-tested in tests/test_lazy_dp.py).
    """
    c = suspect_mask.shape[0]
    truth = jnp.arange(c) < n_lazy
    tp = int(jnp.sum(suspect_mask & truth))
    fp = int(jnp.sum(suspect_mask & ~truth))
    fn = int(jnp.sum(~suspect_mask & truth))
    return {
        "precision": tp / (tp + fp) if tp + fp else 1.0,
        "recall": tp / (tp + fn) if tp + fn else 1.0,
        "flagged": tp + fp,
    }
