from repro.core import (  # noqa: F401
    aggregation,
    allocation,
    bounds,
    chain,
    detection,
    dp,
    lazy,
    mining,
    rounds,
    topology,
)
