"""Spectral-gap diagnostics for mixing topologies and schedules.

The quantity connecting a communication topology to the paper's convergence
bound is the spectral gap ``1 - |lambda_2(W)|`` of the row-stochastic mixing
matrix ``W``: after the Steps 2+5 mix, the clients' disagreement (the
divergence diagnostic of Definition 1, the ``delta`` the bound's h-term is
built from) contracts by a factor ``|lambda_2(W)|`` per round. A full mesh
has gap 1 (consensus in one round, the paper's regime — ``delta`` stays at
its data-heterogeneity floor); a sparse or scheduled topology has gap < 1,
its residual disagreement feeds the bound's divergence term, and the
loss-vs-K optimum shifts (the wireless-scheduling regime of
arXiv:2406.00752).

For a time-varying :class:`~repro.core.topology.Schedule` the per-round gap
undersells the mix: a one-peer gossip rotation contracts little per round
but its PRODUCT over a period mixes like a dense graph. The ergodic gap —
``1 - |lambda_2(W_{T-1} ... W_1 W_0)|^(1/T)``, the per-round geometric rate
of the product matrix — is the right diagnostic, and what
``benchmarks/bench_schedules.py`` correlates with the observed consensus
rate.

Everything here is host-side numpy on small ``[C, C]`` matrices —
diagnostics, not engine code.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core import topology as topology_lib


def _densify(w) -> np.ndarray:
    """Accept a dense matrix or a ``topology.SparseLowering``.

    Sparse topologies densify for the eigen-diagnostics — SMALL C only:
    ``SparseLowering.to_dense`` raises ``ValueError`` past
    ``topology.DENSIFY_MAX_CLIENTS``, because a ``[C, C]`` eigensolve at
    cohort-population scale is exactly what the sparse path exists to
    avoid (diagnose the intra-cohort topology at size A instead)."""
    if isinstance(w, topology_lib.SparseLowering):
        return np.asarray(w.to_dense(), np.float64)
    return np.asarray(w, np.float64)


def lambda2_modulus(w) -> float:
    """|lambda_2|: second-largest eigenvalue modulus of a mixing matrix
    (dense, or a ``topology.SparseLowering`` densified under the small-C
    guard).

    >>> import numpy as np
    >>> round(lambda2_modulus(np.full((4, 4), 0.25)), 6)   # full mesh
    0.0
    >>> round(lambda2_modulus(np.eye(3)), 6)               # no communication
    1.0
    """
    w = _densify(w)
    if w.shape[0] < 2:
        return 0.0
    mags = np.sort(np.abs(np.linalg.eigvals(w)))[::-1]
    return float(mags[1])


def spectral_gap(w) -> float:
    """``1 - |lambda_2(W)|``, clipped to [0, 1] against eigensolver noise.

    1 means one-round consensus (FullMesh), 0 means some disagreement mode
    never contracts (identity, disconnected components, or the untouched
    clients of ``PartialParticipation``).

    >>> from repro.core import topology
    >>> round(spectral_gap(topology.FullMesh().matrix(6)), 6)
    1.0
    >>> round(spectral_gap(topology.PartialParticipation(3).matrix(6)), 6)
    0.0
    """
    return float(np.clip(1.0 - lambda2_modulus(w), 0.0, 1.0))


def cluster_spectral_gap(n_clusters: int, inter_weight: float, *,
                         cluster_size: int = 1) -> float:
    """Closed-form ``spectral_gap`` of ``topology.ClusterTopology``.

    ``W = kron(B, J_S / S)`` factorizes the spectrum: the rank-one
    intra-cluster mean contributes ``S·(G-1) + (S-1)·G`` zero eigenvalues
    (with ``cluster_size`` S > 1 these cap |lambda_2| from below at 0),
    and the cluster-ring circulant ``B`` contributes
    ``(1 - a) + a·cos(2·pi·k / G)`` for k = 0..G-1 — no eigensolve, so the
    two-level consensus rate is diagnosable at any population scale.

    >>> round(cluster_spectral_gap(8, 0.3), 6)
    0.087868
    >>> from repro.core import topology
    >>> w = topology.ClusterTopology(n_clusters=4, inter_weight=0.5).matrix(12)
    >>> abs(cluster_spectral_gap(4, 0.5, cluster_size=3)
    ...     - spectral_gap(w)) < 1e-6
    True
    >>> cluster_spectral_gap(1, 0.5, cluster_size=4)   # one cluster = FedAvg
    1.0
    """
    g = int(n_clusters)
    a = float(inter_weight)
    mags = [abs((1.0 - a) + a * np.cos(2.0 * np.pi * k / g))
            for k in range(1, g)]
    if cluster_size > 1:
        mags.append(0.0)
    if not mags:   # G=1, S=1: a single client, consensus is trivial
        return 1.0
    return float(np.clip(1.0 - max(mags), 0.0, 1.0))


def round_matrices(topo: topology_lib.Topology, n_clients: int,
                   n_rounds: int, *, keys: Optional[Sequence] = None
                   ) -> List[np.ndarray]:
    """The mixing matrices of rounds ``0..n_rounds-1`` as host arrays.

    ``keys`` (one PRNG key per round, e.g. from ``rounds.topology_keys``)
    is required for stochastic topologies/schedules and reproduces the
    exact graphs a run drew; deterministic ones ignore it. ``topo`` may
    also be a raw ``topology.SparseLowering`` — densified once under the
    small-C guard (see :func:`_densify`).
    """
    if isinstance(topo, topology_lib.SparseLowering):
        # a raw edge-list lowering is a static topology: densify once under
        # the small-C guard (to_dense raises ValueError past
        # topology.DENSIFY_MAX_CLIENTS) and repeat it
        if topo.n_clients != n_clients:
            raise ValueError(
                f"SparseLowering has n_clients={topo.n_clients}, the report "
                f"asks for {n_clients}")
        w = topo.to_dense().astype(np.float64)
        return [w for _ in range(int(n_rounds))]
    if topo.stochastic and keys is None:
        raise ValueError(
            f"{type(topo).__name__} is stochastic: pass per-round keys "
            "(rounds.topology_keys reproduces a run's stream)")
    if isinstance(topo, topology_lib.Schedule) and not topo.stochastic:
        # deterministic schedule: build each phase matrix once host-side
        # instead of paying Schedule.matrix's full [P, C, C] table per round
        p = topo.period(n_clients)
        phase = {t: np.asarray(topo.matrix_at(t, n_clients))
                 for t in range(min(p, int(n_rounds)))}
        return [phase[t % p] for t in range(int(n_rounds))]
    return [np.asarray(topo.matrix(
        n_clients, key=keys[t] if keys is not None else None, round_idx=t))
        for t in range(int(n_rounds))]


def per_round_gaps(topo: topology_lib.Topology, n_clients: int,
                   n_rounds: int, *, keys: Optional[Sequence] = None
                   ) -> np.ndarray:
    """``spectral_gap(W_t)`` for each round ``t``.

    >>> from repro.core import topology
    >>> gaps = per_round_gaps(topology.FullMesh(), 6, 3)
    >>> [round(float(g), 6) for g in gaps]
    [1.0, 1.0, 1.0]
    """
    return np.array([spectral_gap(w) for w in round_matrices(
        topo, n_clients, n_rounds, keys=keys)])


def _ergodic_gap_of(ws) -> float:
    """Per-round gap of a concrete matrix sequence's product."""
    prod = np.eye(ws[0].shape[0], dtype=np.float64)
    for w in ws:
        prod = np.asarray(w, np.float64) @ prod
    lam2 = lambda2_modulus(prod)
    # the 1/T-th root amplifies eigensolver noise (1e-17 -> ~1e-2 at T=7);
    # treat anything at fp-noise scale as the exact rank-one product
    lam = 0.0 if lam2 < 1e-12 else lam2 ** (1.0 / len(ws))
    return float(np.clip(1.0 - lam, 0.0, 1.0))


def ergodic_gap(topo: topology_lib.Topology, n_clients: int, *,
                n_rounds: Optional[int] = None,
                keys: Optional[Sequence] = None) -> float:
    """Per-round gap of the round-matrix product over a window.

    ``1 - |lambda_2(W_{T-1} ... W_0)|^(1/T)`` with ``T = n_rounds``
    (default: one schedule period; 1 for static topologies, where this
    equals :func:`spectral_gap`). This is the geometric consensus rate a
    schedule actually achieves per round — for a gossip rotation it far
    exceeds any single phase's gap.

    >>> from repro.core import topology
    >>> rot = topology.GossipRotation()
    >>> one_phase = spectral_gap(topology.PairShift(1).matrix(8))
    >>> ergodic_gap(rot, 8) > one_phase
    True
    """
    if n_rounds is None:
        n_rounds = (topo.period(n_clients)
                    if isinstance(topo, topology_lib.Schedule) else 1)
    return _ergodic_gap_of(round_matrices(topo, n_clients, n_rounds,
                                          keys=keys))


def gap_report(topo: topology_lib.Topology, n_clients: int, n_rounds: int,
               *, keys: Optional[Sequence] = None) -> dict:
    """Run-level spectral summary: per-round gaps + the ergodic gap.

    ``predicted_consensus_rate`` is the per-round contraction factor of the
    disagreement, ``|lambda_2|`` of the product matrix per round — compare
    it against the observed divergence decay of a run
    (``benchmarks/bench_schedules.py`` does exactly that).

    >>> from repro.core import topology
    >>> r = gap_report(topology.FullMesh(), 6, 2)
    >>> sorted(r) == ['ergodic_gap', 'gap_mean', 'gap_min',
    ...               'gap_per_round', 'predicted_consensus_rate']
    True
    >>> round(r['predicted_consensus_rate'], 6)
    0.0
    """
    ws = round_matrices(topo, n_clients, n_rounds, keys=keys)
    gaps = np.array([spectral_gap(w) for w in ws])
    erg = _ergodic_gap_of(ws)
    return {
        "gap_per_round": [float(g) for g in gaps],
        "gap_min": float(gaps.min()),
        "gap_mean": float(gaps.mean()),
        "ergodic_gap": erg,
        "predicted_consensus_rate": float(1.0 - erg),
    }
