"""BLADE-FL integrated round (paper §3.1, Fig. 1) as a single compiled step.

One integrated round =
  Step 1  local training: tau full-batch GD iterations per client
          (lazy clients instead plagiarize + add noise — eq. 7)
  Step 2  model broadcast & verification (digital signature -> digest here)
  Step 3  mining: per-client PoW nonce race over a calibrated attempt budget
  Step 4  block validation: winner's block appended (hash-linked)
  Step 5  local updating: every client adopts the aggregate

On the production mesh the client axis C is sharded over 'data' (x 'pod');
local iterations are collective-free across clients (vmap), the aggregate is
one all-reduce, plagiarism is a collective-permute, and the PoW race is an
argmin over the client axis. The same engine drives the paper-scale MLP
simulation (C=20 on one CPU device) and the 10 assigned architectures on the
512-chip dry-run mesh.

Two multi-round driver paths share the single-round engine:

  * ``run_blade_fl_scan`` — the compiled path. All K integrated rounds run
    inside one ``jax.jit(lax.scan)``; the ``RoundState`` carry (params, PRNG
    key, round counter, prev-hash) never leaves the device (donated on
    accelerator backends), per-round metrics and block-header fields come
    back stacked ``[K]``, and the host sees exactly one end-of-run transfer.
    ``chain.ledger_from_scan`` then replays the stacked headers through the
    validating ledger, so Steps 2-5 blockchain semantics are preserved
    bit-for-bit against the Python loop. Requires the batch to be a static
    pytree — either one ``[C, ...]`` batch reused every round (the paper's
    full-batch GD) or a ``[K, C, ...]`` stack (``stacked=True``, built by
    ``data/pipeline.py`` sources).
  * the Python loop inside ``run_blade_fl`` — one jitted round per
    iteration, a host sync per metric per round. Kept for arbitrary
    per-round batch *callables* (data that cannot be materialized up front)
    and for ``jit=False`` debugging.

``run_blade_fl`` is the single entry point: it dispatches to the scan engine
whenever the batch argument is a static pytree and falls back to the Python
loop for callables. Both paths return the same ``(state, history, ledger)``.

Stage pipeline + topology architecture
--------------------------------------

The integrated round is composed from five named stage functions, each built
once per ``RoundSpec`` by its ``make_*`` factory and individually jittable /
testable:

  ``local_train``   Step 1: tau collective-free GD iterations per client
  ``perturb``       Step 1 (lazy, eq. 7) + §6 DP noise on the broadcast set
  ``communicate``   Steps 2+5: header digest, optional plagiarism screening,
                    divergence diagnostic, then the topology mix
  ``mine``          Steps 3+4: PoW race over the client axis + hash link
  ``finalize``      metrics assembly, strided global-loss eval, next carry

``make_integrated_round`` is now just the composition of those stages — add
a scenario by swapping a stage, not by editing a 70-line closure.

The communication pattern of Steps 2+5 is pluggable via
``RoundSpec.topology`` (``core/topology.py``): a ``Topology`` yields a
row-stochastic mixing matrix ``W [C, C]`` per round and the communicate
stage applies ``aggregation.mix(params, W)``. The default ``FullMesh`` — the
paper's "broadcast to all, everyone adopts the aggregate" — short-circuits
to ``aggregation.fedavg`` so the baseline stays bit-for-bit identical to the
pre-topology engine; ``Ring``, ``RandomGraph`` (per-round i.i.d. link
dropout) and ``PartialParticipation`` open the partial-connectivity regimes
of arXiv:2012.02044 / arXiv:2406.00752. Both driver paths derive the
per-round graph from the same fold of the carried PRNG key, so scan and
Python loop stay exactly equivalent for every topology.

``RoundSpec.eval_every`` strides the in-scan global-loss eval: rounds where
``(round_idx + 1) % eval_every != 0`` skip the eval vmap via ``lax.cond``
and report NaN, so the history keeps a static ``[K]`` layout. The default
``eval_every=1`` keeps the exact pre-stride computation (no cond in the
jaxpr). Choose K divisible by ``eval_every`` when you need
``history[-1]["global_loss"]`` finite.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import (aggregation, chain, detection, dp as dp_lib,
                        lazy as lazy_lib, mining, topology as topology_lib)

LossFn = Callable[[Any, Any], Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]]


@dataclasses.dataclass(frozen=True)
class RoundSpec:
    """Static configuration of one integrated round."""
    n_clients: int
    tau: int                    # local GD iterations (eq. 3)
    eta: float                  # learning rate
    n_lazy: int = 0
    sigma2: float = 0.0         # lazy artificial-noise variance
    dp_sigma: float = 0.0       # DP Gaussian mechanism (§6)
    mine_attempts: int = 1024   # calibrated from beta (allocation.mining_iterations)
    difficulty_bits: int = 8
    microbatches: int = 1       # grad accumulation inside each local iteration
    eval_global_loss: bool = True
    # eval stride: compute global_loss only on rounds with
    # (round_idx + 1) % eval_every == 0 (NaN elsewhere); 1 = every round.
    eval_every: int = 1
    # Steps 2+5 communication pattern (core/topology.py). FullMesh is the
    # paper baseline and dispatches to aggregation.fedavg bit-for-bit.
    topology: topology_lib.Topology = topology_lib.FullMesh()
    # beyond-paper (§8 future work): flag near-duplicate broadcast models
    # before aggregation (core/detection.py); adds n_suspects to metrics.
    detect_lazy: bool = False
    detect_threshold: float = 0.2


class RoundState(NamedTuple):
    params: Any                 # pytree, leading client axis C
    key: jax.Array
    round_idx: jnp.ndarray      # int32
    prev_hash: jnp.ndarray      # uint32


def init_state(params_single, key, n_clients: int) -> RoundState:
    return RoundState(
        params=aggregation.replicate(params_single, n_clients),
        key=key,
        round_idx=jnp.int32(0),
        prev_hash=jnp.uint32(chain.GENESIS_HASH),
    )


def _microbatched_grad(loss_fn: LossFn, n_mb: int):
    """grad of the mean loss over n_mb microbatches (axis-0 split), with
    per-microbatch remat so activation memory is O(batch / n_mb)."""

    def split(batch):
        return jax.tree.map(
            lambda x: x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:]), batch)

    @functools.partial(jax.checkpoint, static_argnums=())
    def one_mb(params, mb):
        loss, _ = loss_fn(params, mb)
        return loss

    def grad_fn(params, batch):
        mbs = split(batch)

        def body(acc, mb):
            l, g = jax.value_and_grad(one_mb)(params, mb)
            return (acc[0] + l, jax.tree.map(jnp.add, acc[1], g)), None

        zero = (jnp.zeros((), jnp.float32), jax.tree.map(jnp.zeros_like, params))
        (loss, grads), _ = jax.lax.scan(body, zero, mbs)
        scale = 1.0 / n_mb
        return loss * scale, jax.tree.map(lambda g: g * scale, grads)

    return grad_fn


# fold_in salt deriving the topology key from k_dp — a fresh stream for
# stochastic topologies that leaves the lazy/DP streams (and therefore the
# FullMesh baseline results) untouched.
_TOPOLOGY_SALT = 0x746F706F  # "topo"


def make_local_train(loss_fn: LossFn, spec: RoundSpec):
    """Step 1 stage: ``(params, batch) -> (params, local_losses [C])`` —
    tau collective-free GD iterations per client. The carried loss is the
    one observed at the last iteration (free — value_and_grad computes it
    anyway)."""
    if spec.microbatches > 1:
        grad_fn = _microbatched_grad(loss_fn, spec.microbatches)
    else:
        def grad_fn(params, batch):
            (loss, _), grads = jax.value_and_grad(
                lambda p, b: loss_fn(p, b), has_aux=True)(params, batch)
            return loss, grads

    per_client_grad = jax.vmap(grad_fn)

    def local_train(params, batch):
        def local_iter(_, carry):
            p, _ = carry
            losses, grads = per_client_grad(p, batch)
            p = jax.tree.map(lambda w, g: w - spec.eta * g.astype(w.dtype),
                             p, grads)
            return (p, losses)

        loss0 = jnp.zeros((spec.n_clients,), jnp.float32)
        return jax.lax.fori_loop(0, spec.tau, local_iter, (params, loss0))

    return local_train


def make_perturb(spec: RoundSpec):
    """Step 1 tail stage: lazy plagiarism + noise (eq. 7), then optional §6
    DP noise on the models about to be broadcast."""

    def perturb(params, k_lazy, k_dp):
        params = lazy_lib.apply_lazy(params, k_lazy, spec.n_clients,
                                     spec.n_lazy, spec.sigma2)
        return dp_lib.privatize(params, k_dp, spec.dp_sigma)

    return perturb


def make_communicate(spec: RoundSpec):
    """Steps 2+5 stage: ``(params, prev_params, k_topo, round_idx) ->
    (mixed_params, digest, divergence, extra_metrics)``.

    Header digest and optional plagiarism screening run on the broadcast set
    (every client sees every *delivered* model; the digest always covers the
    full broadcast so the hash chain is topology-independent), divergence is
    the pre-mix client spread (delta diagnostic, Def. 1), then the topology's
    row-stochastic ``W`` mixes the models. ``FullMesh`` dispatches straight
    to ``fedavg`` — bit-for-bit the paper baseline."""
    topo = spec.topology

    def communicate(params, prev_params, k_topo, round_idx):
        digest = mining.digest_tree(params)
        extra = {}
        if spec.detect_lazy:
            suspects, _ = detection.detect_lazy_round(
                params, prev_params, threshold_frac=spec.detect_threshold)
            extra["n_suspects"] = jnp.sum(suspects).astype(jnp.int32)
        divergence = aggregation.client_divergence(params)
        if topo.is_full_mesh:
            params = aggregation.fedavg(params)
        else:
            w = topo.matrix(spec.n_clients, key=k_topo, round_idx=round_idx)
            params = aggregation.mix(params, w)
        return params, digest, divergence, extra

    return communicate


def make_mine(spec: RoundSpec):
    """Steps 3+4 stage: per-client PoW nonce race, winner argmin, and the
    hash link for the new block header. Returns ``(mine_metrics, new_hash)``."""

    def mine(prev_hash, digest, round_idx):
        client_ids = jnp.arange(spec.n_clients, dtype=jnp.uint32)
        search = jax.vmap(
            lambda cid: mining.pow_search(
                prev_hash, digest, cid, spec.mine_attempts,
                nonce_offset=round_idx.astype(jnp.uint32) * jnp.uint32(1 << 20)))
        best_h, best_n = search(client_ids)
        winner = mining.winner_of(best_h)
        solved = best_h[winner] <= mining.difficulty_threshold(spec.difficulty_bits)
        new_hash = mining.mix_hash(prev_hash, digest, best_n[winner])
        metrics = {
            "winner": winner.astype(jnp.int32),
            "pow_hash": best_h[winner],
            "nonce": best_n[winner],
            "solved": solved,
        }
        return metrics, new_hash

    return mine


def make_finalize(loss_fn: LossFn, spec: RoundSpec):
    """Closing stage: strided global-loss eval + the next ``RoundState``.

    With ``eval_every == 1`` the eval is unconditional — the exact
    pre-stride computation. Otherwise a ``lax.cond`` skips the eval vmap on
    non-eval rounds and reports NaN, keeping the metrics pytree static for
    ``lax.scan``."""

    def eval_loss(params, batch):
        glosses = jax.vmap(lambda p, b: loss_fn(p, b)[0])(params, batch)
        return jnp.mean(glosses)

    def finalize(state, params, key, new_hash, batch, metrics):
        if spec.eval_global_loss:
            if spec.eval_every <= 1:
                metrics["global_loss"] = eval_loss(params, batch)
            else:
                is_eval = (state.round_idx + 1) % spec.eval_every == 0
                metrics["global_loss"] = jax.lax.cond(
                    is_eval, lambda: eval_loss(params, batch),
                    lambda: jnp.full((), jnp.nan, jnp.float32))
        new_state = RoundState(params=params, key=key,
                               round_idx=state.round_idx + 1,
                               prev_hash=new_hash)
        return new_state, metrics

    return finalize


def make_integrated_round(loss_fn: LossFn, spec: RoundSpec):
    """Build the jittable round function: (RoundState, batch) -> (RoundState, metrics).

    ``batch`` leaves have leading client axis [C, local_batch, ...]. The
    round is the composition of the five stage factories above; swap a stage
    to express a new scenario."""
    local_train = make_local_train(loss_fn, spec)
    perturb = make_perturb(spec)
    communicate = make_communicate(spec)
    mine = make_mine(spec)
    finalize = make_finalize(loss_fn, spec)

    def round_fn(state: RoundState, batch) -> Tuple[RoundState, Dict[str, jnp.ndarray]]:
        key, k_lazy, k_dp = jax.random.split(state.key, 3)
        k_topo = jax.random.fold_in(k_dp, _TOPOLOGY_SALT) \
            if spec.topology.stochastic else None

        params, local_losses = local_train(state.params, batch)
        params = perturb(params, k_lazy, k_dp)
        params, digest, divergence, extra = communicate(
            params, state.params, k_topo, state.round_idx)
        mine_metrics, new_hash = mine(state.prev_hash, digest, state.round_idx)

        metrics = {"local_loss_mean": jnp.mean(local_losses), **mine_metrics,
                   "digest": digest, "divergence": divergence, **extra}
        return finalize(state, params, key, new_hash, batch, metrics)

    return round_fn


# How many times each compiled multi-round runner was (re)traced. The
# equivalence test asserts this stays flat in K — the whole point of the
# scan engine is ONE trace for the full horizon, not one per round.
TRACE_COUNTS: Dict[str, int] = {"scan_runner": 0}

# Jitted runners cached on (loss_fn identity, static config). A weakref
# scheme cannot work here — the cached runner's closure chain pins loss_fn,
# so a weak key would never die. A small bounded LRU is the honest tradeoff:
# module-level loss fns (mlp_loss, sweep/benchmark loops at fixed config)
# get cross-call reuse of the compiled executable, while per-call closures
# (launch/train arch paths) pin at most maxsize compiled programs before
# LRU eviction frees them.
@functools.lru_cache(maxsize=16)
def _scan_runner(loss_fn: LossFn, spec: RoundSpec, n_rounds: int,
                 stacked: bool):
    """Build (and cache) the jitted K-round runner for this config."""
    round_fn = make_integrated_round(loss_fn, spec)

    def run(state: RoundState, batch):
        TRACE_COUNTS["scan_runner"] += 1
        if stacked:
            return jax.lax.scan(round_fn, state, batch)
        return jax.lax.scan(lambda s, _: round_fn(s, batch), state, None,
                            length=n_rounds)

    # Donate the carry so params never hold two live copies on accelerator
    # backends; CPU has no donation support and would only warn.
    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(run, donate_argnums=donate)


@functools.lru_cache(maxsize=16)
def _round_runner(loss_fn: LossFn, spec: RoundSpec):
    """Cached jitted single-round step for the Python-loop path, so repeated
    ``run_blade_fl`` calls at the same config (K-sweeps, benchmarks) reuse
    the compiled executable instead of retracing per call."""
    return jax.jit(make_integrated_round(loss_fn, spec))


def run_blade_fl_scan(loss_fn: LossFn, spec: RoundSpec, params_single, batch,
                      key, n_rounds: int,
                      ledger: Optional[chain.Ledger] = None,
                      stacked: bool = False):
    """Compiled driver: all K integrated rounds in one ``jax.jit(lax.scan)``.

    ``batch`` is a static pytree: one ``[C, ...]`` batch reused every round,
    or — with ``stacked=True`` — a ``[K, C, ...]`` stack scanned over as xs.
    The carry stays on device for the whole horizon; metrics and block-header
    fields come back stacked and the single end-of-run ``device_get`` is the
    only host transfer. Returns the same ``(state, history, ledger)`` triple
    as the Python-loop path, with the ledger rebuilt and re-validated by
    ``chain.ledger_from_scan``.
    """
    if callable(batch):
        raise TypeError(
            "run_blade_fl_scan needs a static batch pytree; use "
            "run_blade_fl for per-round batch callables")
    if stacked:
        leads = {x.shape[0] for x in jax.tree.leaves(batch)}
        if leads != {int(n_rounds)}:
            raise ValueError(
                f"stacked batch leading dims {sorted(leads)} != "
                f"n_rounds={int(n_rounds)}; scan takes its length from xs")
    runner = _scan_runner(loss_fn, spec, int(n_rounds), bool(stacked))
    state = init_state(params_single, key, spec.n_clients)
    state, stacked_metrics = runner(state, batch)
    host = jax.device_get(stacked_metrics)   # the one host transfer
    history = [{name: float(v[k]) for name, v in host.items()}
               for k in range(int(n_rounds))]
    ledger = chain.ledger_from_scan(
        host["digest"], host["winner"], host["nonce"], host["pow_hash"],
        ledger=ledger)
    return state, history, ledger


def run_blade_fl(loss_fn: LossFn, spec: RoundSpec, params_single, batches,
                 key, n_rounds: int, ledger: Optional[chain.Ledger] = None,
                 jit: bool = True, stacked: bool = False):
    """Run K integrated rounds; returns (final RoundState, history, ledger).

    Dispatches to the compiled scan engine when ``batches`` is a static
    pytree (see module docstring); falls back to the per-round Python loop
    for callables (``batches(k) -> batch``) or ``jit=False``.
    """
    if jit and not callable(batches):
        return run_blade_fl_scan(loss_fn, spec, params_single, batches, key,
                                 n_rounds, ledger=ledger, stacked=stacked)
    round_fn = _round_runner(loss_fn, spec) if jit \
        else make_integrated_round(loss_fn, spec)
    state = init_state(params_single, key, spec.n_clients)
    ledger = ledger if ledger is not None else chain.Ledger()
    history = []
    for k in range(n_rounds):
        if callable(batches):
            batch = batches(k)
        elif stacked:
            batch = jax.tree.map(lambda x: x[k], batches)
        else:
            batch = batches
        state, metrics = round_fn(state, batch)
        block = chain.make_block(
            index=len(ledger.blocks), prev_hash=ledger.head_hash,
            model_digest=int(metrics["digest"]), winner=int(metrics["winner"]),
            nonce=int(metrics["nonce"]), pow_hash=int(metrics["pow_hash"]))
        ledger.append(block)
        history.append({k2: float(v) for k2, v in metrics.items()})
    return state, history, ledger
