"""BLADE-FL integrated round (paper §3.1, Fig. 1) as a single compiled step.

One integrated round =
  Step 1  local training: tau full-batch GD iterations per client
          (lazy clients instead plagiarize + add noise — eq. 7)
  Step 2  model broadcast & verification (digital signature -> digest here)
  Step 3  mining: per-client PoW nonce race over a calibrated attempt budget
  Step 4  block validation: winner's block appended (hash-linked)
  Step 5  local updating: every client adopts the aggregate

On the production mesh the client axis C is sharded over 'data' (x 'pod');
local iterations are collective-free across clients (vmap), the aggregate is
one all-reduce, plagiarism is a collective-permute, and the PoW race is an
argmin over the client axis. The same engine drives the paper-scale MLP
simulation (C=20 on one CPU device) and the 10 assigned architectures on the
512-chip dry-run mesh.

Two multi-round driver paths share the single-round engine:

  * ``run_blade_fl_scan`` — the compiled path. All K integrated rounds run
    inside one ``jax.jit(lax.scan)``; the ``RoundState`` carry (params, PRNG
    key, round counter, prev-hash) never leaves the device (donated on
    accelerator backends), per-round metrics and block-header fields come
    back stacked ``[K]``, and the host sees exactly one end-of-run transfer.
    ``chain.ledger_from_scan`` then replays the stacked headers through the
    validating ledger, so Steps 2-5 blockchain semantics are preserved
    bit-for-bit against the Python loop. Requires the batch to be a static
    pytree — either one ``[C, ...]`` batch reused every round (the paper's
    full-batch GD) or a ``[K, C, ...]`` stack (``stacked=True``, built by
    ``data/pipeline.py`` sources).
  * the Python loop inside ``run_blade_fl`` — one jitted round per
    iteration, a host sync per metric per round. Kept for arbitrary
    per-round batch *callables* (data that cannot be materialized up front)
    and for ``jit=False`` debugging.

``run_blade_fl`` is the single entry point: it dispatches to the scan engine
whenever the batch argument is a static pytree and falls back to the Python
loop for callables. Both paths return the same ``(state, history, ledger)``.

Stage pipeline + topology architecture
--------------------------------------

The integrated round is composed from five named stage functions, each built
once per ``RoundSpec`` by its ``make_*`` factory and individually jittable /
testable:

  ``local_train``   Step 1: tau collective-free GD iterations per client
  ``perturb``       Step 1 (lazy, eq. 7) + §6 DP noise on the broadcast set
  ``communicate``   Steps 2+5: header digest, optional plagiarism screening,
                    divergence diagnostic, then the topology mix
  ``mine``          Steps 3+4: PoW race over the client axis + hash link
  ``finalize``      metrics assembly, strided global-loss eval, next carry

``make_integrated_round`` is now just the composition of those stages — add
a scenario by swapping a stage, not by editing a 70-line closure.

The communication pattern of Steps 2+5 is pluggable via
``RoundSpec.topology`` (``core/topology.py``): a ``Topology`` yields a
row-stochastic mixing matrix ``W [C, C]`` per round and the communicate
stage applies ``aggregation.mix(params, W)``. The default ``FullMesh`` — the
paper's "broadcast to all, everyone adopts the aggregate" — short-circuits
to ``aggregation.fedavg`` so the baseline stays bit-for-bit identical to the
pre-topology engine; ``Ring``, ``RandomGraph`` (per-round i.i.d. link
dropout) and ``PartialParticipation`` open the partial-connectivity regimes
of arXiv:2012.02044 / arXiv:2406.00752. Both driver paths derive the
per-round graph from the same fold of the carried PRNG key, so scan and
Python loop stay exactly equivalent for every topology.

Time-varying ``Schedule`` topologies (gossip rotations, epoch-alternating
overlays, SNR link-quality fading) compile into the same single scan with
no retrace across K — ``topology.resolve_mix_plan`` is the single surface
that picks the executor mode ``make_communicate`` runs — and
``RoundSpec.data_weights`` threads |D_i| row reweighting
into every dense mix. ``core/spectral.py`` turns any topology/schedule
into its consensus-rate diagnostic (1 - |lambda_2(W)|, ergodic gap).

``RoundSpec.eval_every`` strides the in-scan global-loss eval: rounds where
``(round_idx + 1) % eval_every != 0`` skip the eval vmap via ``lax.cond``
and report NaN, so the history keeps a static ``[K]`` layout. The default
``eval_every=1`` keeps the exact pre-stride computation (no cond in the
jaxpr). Both drivers force an eval on the LAST round even when
``K % eval_every != 0``, so ``history[-1]["global_loss"]`` is always
finite and best-K selection never compares against NaN.

Client-sharded execution (mesh + plan)
--------------------------------------

``run_blade_fl_scan(..., mesh=..., plan=...)`` runs the SAME K-round scan
client-sharded over a device mesh: the whole ``lax.scan`` executes inside a
``shard_map`` whose carry layout comes from
``sharding.plans.scan_carry_plan`` — params and batch split along the
client axis over the plan's mesh axes, PRNG key / round counter / prev-hash
(the ledger link) replicated — so the donated carry never leaves the
devices for the whole horizon and the end-of-run metrics transfer is still
the only host sync. Every stage factory takes ``axis_name``/``n_shards``:
with ``axis_name=None`` (the default) each stage is exactly the
single-device computation; with a mesh axis, per-client work (local GD, the
PoW race) runs on local client blocks and every cross-client step goes
through the collectives in ``core/aggregation`` — the mix via the
``MixLowering`` the topology advertises, the digest / divergence /
global-loss reductions via all-gather + replicated full-width math. That
discipline (never psum partial fp32 sums) is what makes the sharded engine
bit-for-bit equal to the single-device scan — same params, same metrics,
same hash-linked ledger — as ``tests/test_multidevice_scan.py`` asserts on
a 4-device host mesh for every shipped topology. (The bitwise claim is for
a fixed backend; CPU↔TPU still differ, and TPU tiling may reorder
per-client matmuls.)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import (aggregation, attacks as attacks_lib, chain,
                        detection, dp as dp_lib, lazy as lazy_lib, mining,
                        topology as topology_lib)
from repro.sharding import plans as plans_lib

LossFn = Callable[[Any, Any], Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]]


@dataclasses.dataclass(frozen=True)
class RoundSpec:
    """Static configuration of one integrated round."""
    n_clients: int
    tau: int                    # local GD iterations (eq. 3)
    eta: float                  # learning rate
    n_lazy: int = 0
    sigma2: float = 0.0         # lazy artificial-noise variance
    dp_sigma: float = 0.0       # DP Gaussian mechanism (§6)
    mine_attempts: int = 1024   # calibrated from beta (allocation.mining_iterations)
    difficulty_bits: int = 8
    microbatches: int = 1       # grad accumulation inside each local iteration
    eval_global_loss: bool = True
    # eval stride: compute global_loss only on rounds with
    # (round_idx + 1) % eval_every == 0 (NaN elsewhere); 1 = every round.
    eval_every: int = 1
    # Steps 2+5 communication pattern (core/topology.py). FullMesh is the
    # paper baseline and dispatches to aggregation.fedavg bit-for-bit.
    # Schedules (time-varying topologies) are topologies too — the
    # communicate stage compiles their period into the scan.
    topology: topology_lib.Topology = topology_lib.FullMesh()
    # |D_i| data sizes (length n_clients); reweight each mix row as
    # W'[i, j] ∝ W[i, j] * data_weights[j] (aggregation.mix weights). A
    # tuple so the spec stays hashable; None = unweighted (paper baseline).
    data_weights: Optional[Tuple[float, ...]] = None
    # beyond-paper (§8 future work): flag near-duplicate broadcast models
    # before aggregation (core/detection.py); adds n_suspects to metrics.
    detect_lazy: bool = False
    detect_threshold: float = 0.2
    # opt-in fast path: lower dense mixes to true in-mesh psums of locally
    # pre-weighted rows (aggregation.mix_psum / mix_psum_dense) and finish
    # the digest/divergence diagnostics with psums instead of the broadcast
    # gather. Moves ~C/D× less data for FullMesh but REASSOCIATES fp32:
    # results hold to the tolerance tier (rtol ≈ 1e-5 over a K-round run,
    # tests/test_fast_allreduce.py), not the bitwise contract, and the
    # sharded ledger hashes fork from the single-device chain (both chains
    # still self-validate). Default False keeps every path bit-for-bit.
    fast_allreduce: bool = False
    # Pallas kernel tier (docs/architecture.md §Kernel dispatch):
    #   use_kernel — Steps 3+4 PoW race runs on the kernels/pow_hash 2-D
    #     (clients × nonce-chunk) grid instead of the per-client
    #     vmap(fori_loop). Bitwise-identical (best_hash, best_nonce, winner,
    #     ledger hashes) at every (mine_attempts, mine_chunk) — same budget
    #     masking, same client_salt nonce spaces — so the ledger does NOT
    #     fork. run_blade_fl's auto dispatch downgrades it below
    #     _KERNEL_MIN_ATTEMPTS where grid overhead beats the fori_loop.
    #   fused_mix — dense mixes contract through the fused kernels/fedavg
    #     row-block matmul (mix_gather / mix_psum_dense use_kernel=True) and
    #     the digest + divergence diagnostics share ONE fused sweep of the
    #     broadcast set. Tolerance tier like fast_allreduce: tile-partial
    #     fp32 sums reassociate the digest, so ledger hashes fork
    #     deterministically (both chains still self-validate).
    #   kernel_interpret — None runs Pallas natively on TPU backends and in
    #     interpret mode everywhere else; tests pin True for the CPU
    #     equivalence sweeps.
    #   mine_chunk — nonce chunk (fori_loop) / grid tile (kernel) size,
    #     shared so both paths charge identical budget masks; results are
    #     chunk-invariant (running min + first-tie argmin == full argmin).
    use_kernel: bool = False
    fused_mix: bool = False
    kernel_interpret: Optional[bool] = None
    mine_chunk: int = 1024
    # Sparse mix dispatch (docs/architecture.md §Sparse lowering):
    #   None (auto) — GATHER-kind topologies whose exported SparseLowering
    #     has padded max degree ≪ C (max_degree * topology
    #     .SEGMENT_DEGREE_FACTOR <= n_clients) reroute their mix through
    #     aggregation.mix_segment —
    #     O(C·deg) gather + segment_sum instead of the dense O(C²) matmul.
    #     ExplicitSparse topologies (SEGMENT kind) always mix here. Every
    #     shipped small-C config keeps its dense path (and its bits).
    #   True — force the segment mix (ValueError when the topology exports
    #     no static sparse form). Sparse-vs-dense agreement is tolerance
    #     tier (segment_sum's scatter order replaces the matmul's
    #     contraction order), so forcing it forks ledger hashes
    #     deterministically, like fast_allreduce.
    #   False — never, even for ExplicitSparse (its small-C dense fallback).
    sparse_mix: Optional[bool] = None
    # Byzantine attack stage (core/attacks.py; CLI --attack/--attackers):
    # a pure keyed transform on the pre-broadcast params — the adversary's
    # first-M clients replace their broadcasts (sign-flip, scaled noise,
    # ALIE, model replacement) right after the perturb stage, so the
    # digest / detection / mix all see what a real adversary publishes.
    # None (no attack) is the exact baseline computation.
    attack: Optional[attacks_lib.Attack] = None
    # Byzantine-robust aggregation (docs/architecture.md §Robust
    # aggregation; CLI --robust): override the topology's linear mix with a
    # robust consensus reducer over the full broadcast set — "median" |
    # "trimmed[:t]" | "geomed[:iters]" (topology.parse_robust; "mean"/None
    # keep the linear mix). Breakdown point ⌊(C-1)/2⌋ for median/geomed, t
    # per tail for trimmed — versus 0 for every linear mix. Robust
    # reductions are not psum-associative, so sharded execution agrees with
    # single-device to the TOLERANCE tier (rtol ≈ 1e-5), not bitwise, and
    # the linear-only flags (fast_allreduce / fused_mix / sparse_mix=True /
    # data_weights) are rejected by the resolver.
    robust_agg: Optional[str] = None


class RoundState(NamedTuple):
    params: Any                 # pytree, leading client axis C
    key: jax.Array
    round_idx: jnp.ndarray      # int32
    prev_hash: jnp.ndarray      # uint32


def init_state(params_single, key, n_clients: int) -> RoundState:
    return RoundState(
        params=aggregation.replicate(params_single, n_clients),
        key=key,
        round_idx=jnp.int32(0),
        prev_hash=jnp.uint32(chain.GENESIS_HASH),
    )


def _microbatched_grad(loss_fn: LossFn, n_mb: int):
    """grad of the mean loss over n_mb microbatches (axis-0 split), with
    per-microbatch remat so activation memory is O(batch / n_mb)."""

    def split(batch):
        return jax.tree.map(
            lambda x: x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:]), batch)

    @functools.partial(jax.checkpoint, static_argnums=())
    def one_mb(params, mb):
        loss, _ = loss_fn(params, mb)
        return loss

    def grad_fn(params, batch):
        mbs = split(batch)

        def body(acc, mb):
            l, g = jax.value_and_grad(one_mb)(params, mb)
            return (acc[0] + l, jax.tree.map(jnp.add, acc[1], g)), None

        zero = (jnp.zeros((), jnp.float32), jax.tree.map(jnp.zeros_like, params))
        (loss, grads), _ = jax.lax.scan(body, zero, mbs)
        scale = 1.0 / n_mb
        return loss * scale, jax.tree.map(lambda g: g * scale, grads)

    return grad_fn


# fold_in salt deriving the topology key from k_dp — a fresh stream for
# stochastic topologies that leaves the lazy/DP streams (and therefore the
# FullMesh baseline results) untouched.
_TOPOLOGY_SALT = 0x746F706F  # "topo"


def topology_keys(key, n_rounds: int):
    """Host-side replica of the engine's per-round topology PRNG stream.

    Returns the list of ``k_topo`` keys rounds ``0..n_rounds-1`` fold their
    stochastic graphs from, given the run key passed to the drivers — the
    same split chain the round body performs, so diagnostics
    (``core/spectral.py``) can reconstruct the EXACT per-round mixing
    matrices a stochastic topology/schedule used in a run."""
    out = []
    for _ in range(int(n_rounds)):
        key, _k_lazy, k_dp = jax.random.split(key, 3)
        out.append(jax.random.fold_in(k_dp, _TOPOLOGY_SALT))
    return out


def make_local_train(loss_fn: LossFn, spec: RoundSpec, n_shards: int = 1):
    """Step 1 stage factory: tau local GD iterations per client, eq. 3.

    Returns ``local_train(params, batch) -> (params, local_losses)``. Both
    inputs carry a leading client axis — the full ``C`` single-device, or
    this shard's ``C / n_shards`` block inside ``shard_map`` — and the stage
    is collective-free either way: clients never talk during Step 1, which
    is exactly why the client axis shards cleanly. Each iteration is one
    full-batch ``value_and_grad`` per client (``spec.microbatches > 1``
    splits it into remat'd grad-accumulation microbatches); the carried
    per-client loss is the one observed at the last iteration (free —
    ``value_and_grad`` computes it anyway)."""
    if spec.microbatches > 1:
        grad_fn = _microbatched_grad(loss_fn, spec.microbatches)
    else:
        def grad_fn(params, batch):
            (loss, _), grads = jax.value_and_grad(
                lambda p, b: loss_fn(p, b), has_aux=True)(params, batch)
            return loss, grads

    per_client_grad = jax.vmap(grad_fn)
    n_local = spec.n_clients // n_shards

    def local_train(params, batch):
        def local_iter(_, carry):
            p, _ = carry
            # pin the iteration inputs: without this, XLA fuses the
            # batch-mean inside value_and_grad with whatever surrounds the
            # loop (the scan engine peels its first iteration), and the
            # materialized per-client loss drifts a ULP between the scan
            # and per-round engines on lane-vectorized CPU builds
            p, b = jax.lax.optimization_barrier((p, batch))
            losses, grads = per_client_grad(p, b)
            p = jax.tree.map(lambda w, g: w - spec.eta * g.astype(w.dtype),
                             p, grads)
            return (p, losses)

        loss0 = jnp.zeros((n_local,), jnp.float32)
        return jax.lax.fori_loop(0, spec.tau, local_iter, (params, loss0))

    return local_train


def make_perturb(spec: RoundSpec, axis_name=None, n_shards: int = 1):
    """Step 1 tail stage factory: what each client broadcasts instead of its
    honest model.

    Returns ``perturb(params, k_lazy, k_dp)``: lazy clients plagiarize their
    source client's fresh model and add N(0, sigma^2) disguise noise
    (eq. 7), then every client optionally adds §6 DP Gaussian noise to the
    model it is about to broadcast. With ``n_lazy == 0`` and
    ``dp_sigma == 0`` the stage is the identity.

    Sharded, plagiarism is a cross-shard gather (a lazy client's source may
    live on another device) and the noise draws must equal the
    single-device ones — so the stage all-gathers the client axis, applies
    the IDENTICAL full-``[C, ...]`` transform (same per-leaf key split, same
    noise shapes — bitwise the same draws), and slices this shard's rows
    back out. Cost: one params gather per round, only when the stage is
    active — and that gathered tree is returned as ``full`` (None when the
    stage was a no-op) so the communicate stage reuses it instead of
    re-gathering the model it just materialized."""
    active = spec.n_lazy > 0 or spec.dp_sigma > 0.0

    def perturb(params, k_lazy, k_dp):
        if not active:
            return params, None
        full = aggregation.client_all_gather(params, axis_name)
        full = lazy_lib.apply_lazy(full, k_lazy, spec.n_clients,
                                   spec.n_lazy, spec.sigma2)
        full = dp_lib.privatize(full, k_dp, spec.dp_sigma)
        return aggregation.client_local_rows(full, axis_name, n_shards), full

    return perturb


# fold_in salt deriving the attack key from k_dp — its own stream (disjoint
# from _TOPOLOGY_SALT) so adding an attack never perturbs the lazy/DP/
# topology draws, and an attack-free spec is the exact baseline.
_ATTACK_SALT = 0x6174746B  # "attk"


def make_attack(spec: RoundSpec, axis_name=None, n_shards: int = 1):
    """Byzantine attack stage factory (core/attacks.py), composed right
    after ``perturb``: what the adversary's first-M clients broadcast
    instead of their (possibly lazy/DP-perturbed) models.

    Returns ``attack(params, k_dp, full=None) -> (params, full)`` with the
    same gather discipline as ``make_perturb``: sharded, it all-gathers the
    client axis — or reuses the perturb stage's ``full`` tree when that
    stage already gathered — applies the IDENTICAL full-``[C, ...]`` keyed
    transform (``Attack.apply``; the attack key folds from ``k_dp`` with
    :data:`_ATTACK_SALT`, so the draws match bitwise across engines), and
    slices the local rows back out. The transformed ``full`` is returned so
    the communicate stage's digest / detection / mix see the post-attack
    broadcast set without re-gathering. ``spec.attack=None`` (or zero
    attackers) is the identity and adds nothing to the trace."""
    atk = spec.attack
    active = atk is not None and atk.active
    if active:
        atk._validate(spec.n_clients)   # fail at build time, not in-trace

    def attack(params, k_dp, full=None):
        if not active:
            return params, full
        if full is None:
            full = aggregation.client_all_gather(params, axis_name)
        k_att = jax.random.fold_in(k_dp, _ATTACK_SALT)
        full = atk.apply(full, k_att, spec.n_clients)
        return aggregation.client_local_rows(full, axis_name, n_shards), full

    return attack


# Back-compat alias: the auto sparse-mix crossover now lives with the rest
# of the mix dispatch in core/topology.py (resolve_mix_plan).
_SEGMENT_DEGREE_FACTOR = topology_lib.SEGMENT_DEGREE_FACTOR


def segment_lowering(spec: RoundSpec
                     ) -> Optional[topology_lib.SparseLowering]:
    """The SparseLowering the communicate stage will mix through, or None
    when this spec mixes densely (see ``RoundSpec.sparse_mix``). Thin view
    over ``topology.resolve_mix_plan`` — the mix decisions live there, this
    just exposes the sparse payload (|D_i| reweighting already folded in)."""
    return topology_lib.resolve_mix_plan(spec).sparse


def _mesh_axes_of(axis_name, n_shards: int, axis_sizes=()):
    """``resolve_mix_plan``'s ``mesh_axes`` from a stage factory's
    ``(axis_name, n_shards, axis_sizes)``: ``None`` single-device, else
    ``((name, extent), ...)``. When per-axis extents are unknown (a caller
    that predates ``ScanCarryPlan.axis_sizes``) only the total shard count
    is attributed — which is all the resolver consumes; the collectives
    read real extents from the mesh at trace time."""
    if axis_name is None:
        return None
    names = ((axis_name,) if isinstance(axis_name, str)
             else tuple(axis_name))
    sizes = tuple(int(s) for s in axis_sizes)
    if len(sizes) != len(names):
        sizes = (1,) * (len(names) - 1) + (int(n_shards),)
    return tuple(zip(names, sizes))


def make_communicate(spec: RoundSpec, axis_name=None, n_shards: int = 1,
                     axis_sizes=()):
    """Steps 2+5 stage factory: ``(params, prev_params, k_topo, round_idx)
    -> (mixed_params, digest, divergence, extra_metrics)``.

    Header digest and optional plagiarism screening run on the broadcast set
    (every client sees every *delivered* model; the digest always covers the
    full broadcast so the hash chain is topology-independent), divergence is
    the pre-mix client spread (delta diagnostic, Def. 1), then the
    topology's row-stochastic ``W`` mixes the models — through the
    executor mode a single :func:`~repro.core.topology.resolve_mix_plan`
    call picks (FedAvg mean, halo ``collective_permute`` window, cluster
    two-level exchange, sparse segment-sum, psum tier, or the dense
    all-gather matmul). This factory is a thin executor over that
    :class:`~repro.core.topology.MixPlan` — it holds NO lowering-kind
    logic of its own, so ``dispatch_plan``'s report and the traced mix
    cannot drift.

    Sharded, the digest / divergence / detection diagnostics all-gather the
    broadcast set and run the identical full-width math (the digest folds a
    cross-client fp32 sum per leaf — partial psums would change its bits and
    with it every downstream hash link); the FullMesh and gather mixes reuse
    that same gathered tree, so diagnostics add no extra collective. When
    the perturb stage already gathered the broadcast set, its ``full`` tree
    is accepted (re-barriered, so the digest reduce stays fusion-pinned)
    instead of gathering twice.

    Schedules compile into the traced body with no retrace across K: a
    deterministic schedule's matrices become a static ``[P, C, C]`` table
    indexed by the traced round counter; a :class:`GossipRotation`'s
    round-dependent offsets become a ``lax.switch`` over P static permute
    branches (``mix_shift_halo`` — its linearized permutes cover compound
    ``('pod','data')`` client axes too — or rolls off-mesh);
    stochastic schedules draw their phase graph from ``k_topo`` like
    ``RandomGraph``. ``spec.data_weights`` (|D_i| row reweighting) rides the
    dense-matrix paths — permute lowerings bake uniform window weights, so a
    weighted spec routes ``neighbor_permute`` topologies through their
    matrices instead.

    ``spec.fast_allreduce`` reroutes the DENSE kinds onto the reassociating
    psum tier: a ``psum`` lowering (FullMesh / uniform-row topologies) mixes
    via ``aggregation.mix_psum`` (one model-sized psum, ~C/D× less data), a
    ``gather`` kind via ``aggregation.mix_psum_dense`` (local column-block
    matmul + psum), and the digest / divergence diagnostics are finished
    with psums of local partials instead of the broadcast-set gather — the
    fast round never materializes the full client axis (except for lazy
    detection, which keeps its exact gathered math). Permute lowerings are
    already O(window) and stay bitwise under the flag.

    ``spec.fused_mix`` routes the dense mixes through the fused Pallas
    row-block matmul (``aggregation.mix_gather`` / ``mix_psum_dense`` with
    ``use_kernel=True``) and computes digest + divergence in ONE fused sweep
    of the broadcast set (``kernels/fedavg.digest_divergence_tree``) instead
    of two jnp traversals. Tolerance tier, same contract as
    ``fast_allreduce``: the fp32 reassociation forks the ledger hashes
    deterministically. FullMesh's all-reduce mix and the permute lowerings
    are untouched (one mean / O(window) moves — nothing for a matmul kernel
    to win), as are the psum'd diagnostics of the fast_dense path (the fused
    sweep needs the client axis resident, psum partials don't)."""
    topo = spec.topology
    plan = topology_lib.resolve_mix_plan(
        spec, _mesh_axes_of(axis_name, n_shards, axis_sizes))
    mode = plan.mode
    # plan payloads → device constants baked into the trace. Edge lists /
    # weight rows are static host arrays, so no retrace across K rounds.
    weights = (jnp.asarray(plan.weights, jnp.float32)
               if plan.weights is not None else None)
    psum_row = (jnp.asarray(plan.psum_row, jnp.float32)
                if plan.psum_row is not None else None)
    seg = plan.sparse
    seg_idx = seg.neighbor_idx if seg is not None else None
    seg_w = seg.edge_w if seg is not None else None

    def mix_scheduled_shifts(params, phase):
        """Rotation dispatch: lax.switch over one static branch per phase."""
        if axis_name is None:
            return jax.lax.switch(
                phase,
                [lambda p, o=o: aggregation.mix_rolls(p, o, plan.weight)
                 for o in plan.offsets_table], params)
        return jax.lax.switch(
            phase, [lambda p, o=o: aggregation.mix_shift_halo(
                p, o, plan.weight, axis_name) for o in plan.offsets_table],
            params)

    def communicate(params, prev_params, k_topo, round_idx, full=None):
        if plan.fast_diagnostics:
            # tolerance tier: psum'd diagnostics + mix, no broadcast gather.
            # The digest reassociates fp32 under shard_map, so the ledger
            # hashes fork from the bitwise engine (documented + tested).
            digest = mining.digest_tree(params, axis_name=axis_name)
            divergence = aggregation.client_divergence_psum(
                params, axis_name, n_shards)
            extra = {}
            if spec.detect_lazy:
                det_full = (aggregation.client_all_gather(params, axis_name)
                            if full is None
                            else jax.lax.optimization_barrier(full))
                prev_full = aggregation.client_all_gather(prev_params,
                                                          axis_name)
                suspects, _ = detection.detect_lazy_round(
                    det_full, prev_full, threshold_frac=spec.detect_threshold)
                extra["n_suspects"] = jnp.sum(suspects).astype(jnp.int32)
            if mode == topology_lib.EXEC_PSUM:
                params = aggregation.mix_psum(params, psum_row,
                                              axis_name=axis_name,
                                              n_shards=n_shards)
            else:
                w = topo.matrix(spec.n_clients, key=k_topo,
                                round_idx=round_idx)
                params = aggregation.mix_psum_dense(
                    params, w, weights, axis_name=axis_name,
                    n_shards=n_shards, use_kernel=plan.use_kernel,
                    interpret=spec.kernel_interpret)
            return params, digest, divergence, extra
        if full is None:
            full = aggregation.client_all_gather(params, axis_name)
        else:
            full = jax.lax.optimization_barrier(full)
        extra = {}
        if spec.fused_mix:
            # one fused sweep of the broadcast set computes digest AND
            # divergence (kernels/fedavg.digest_divergence_tree) — the jnp
            # path below traverses it twice. Tolerance tier: the tile-partial
            # leaf sums fork the digest (and the ledger) deterministically.
            from repro.kernels.fedavg import ops as fedavg_ops
            digest, divergence = fedavg_ops.digest_divergence_tree(
                full, interpret=spec.kernel_interpret)
        else:
            digest = mining.digest_tree(full)
            divergence = aggregation.client_divergence(full)
        if spec.detect_lazy:
            prev_full = aggregation.client_all_gather(prev_params, axis_name)
            suspects, _ = detection.detect_lazy_round(
                full, prev_full, threshold_frac=spec.detect_threshold)
            extra["n_suspects"] = jnp.sum(suspects).astype(jnp.int32)
        if mode == topology_lib.EXEC_SEGMENT:
            # sparse segment mix: O(C·deg) gather + segment_sum over the
            # broadcast set (reuses the diagnostics gather); |D_i| weights
            # were folded into seg_w by the resolver
            params = aggregation.mix_segment(params, seg_idx, seg_w,
                                             axis_name=axis_name,
                                             n_shards=n_shards, full=full)
        elif mode == topology_lib.EXEC_FEDAVG:
            params = aggregation.mix_all_reduce(params, weights,
                                                axis_name=axis_name,
                                                n_shards=n_shards, full=full)
        elif mode == topology_lib.EXEC_SHIFT_TABLE:
            phase = jnp.mod(jnp.asarray(round_idx, jnp.int32), plan.period)
            params = mix_scheduled_shifts(params, phase)
        elif mode == topology_lib.EXEC_CLUSTER:
            params = aggregation.mix_cluster(params, plan.n_clusters,
                                             plan.inter_weight, axis_name,
                                             n_shards=n_shards, full=full)
        elif mode == topology_lib.EXEC_HALO:
            params = aggregation.mix_neighbor_halo(params, plan.offsets,
                                                   plan.weight, axis_name)
        elif mode == topology_lib.EXEC_SHIFT_HALO:
            params = aggregation.mix_shift_halo(params, plan.offsets,
                                                plan.weight, axis_name)
        elif mode == topology_lib.EXEC_MEDIAN:
            params = aggregation.mix_median(params, axis_name=axis_name,
                                            n_shards=n_shards, full=full)
        elif mode == topology_lib.EXEC_TRIMMED:
            params = aggregation.mix_trimmed(params, plan.trim,
                                             axis_name=axis_name,
                                             n_shards=n_shards, full=full)
        elif mode == topology_lib.EXEC_GEOMED:
            params = aggregation.mix_geomedian(params, plan.robust_iters,
                                               axis_name=axis_name,
                                               n_shards=n_shards, full=full)
        else:
            w = topo.matrix(spec.n_clients, key=k_topo, round_idx=round_idx)
            params = aggregation.mix_gather(params, w, weights,
                                            axis_name=axis_name,
                                            n_shards=n_shards, full=full,
                                            use_kernel=plan.use_kernel,
                                            interpret=spec.kernel_interpret)
        return params, digest, divergence, extra

    communicate.plan = plan
    return communicate


def make_mine(spec: RoundSpec, axis_name=None, n_shards: int = 1):
    """Steps 3+4 stage factory: the PoW race and the hash link.

    Returns ``mine(prev_hash, digest, round_idx) -> (mine_metrics,
    new_hash)``. Every client searches its own salted nonce space over the
    calibrated attempt budget (eq. 1 accounting); the winner is the argmin
    hash across the client axis — the decentralized "first to find" — and
    the winner's nonce seals the new block header onto ``prev_hash``.

    Sharded, each shard races only its local client block (ids offset by
    the shard index so the global salt assignment is unchanged), then the
    per-client best hashes/nonces — uint32, so gather order cannot perturb
    them — are all-gathered for the replicated argmin.

    ``spec.use_kernel`` dispatches the race to the Pallas 2-D
    (clients × nonce chunks) grid (``kernels/pow_hash``) instead of the
    per-client ``vmap(fori_loop)``: same ``client_salt`` nonce spaces, same
    tail-chunk budget mask charging exactly ``mine_attempts`` nonces, so
    every output — and therefore the hash-linked ledger — is bitwise
    identical to the fori_loop path at any ``(mine_attempts, mine_chunk)``
    (tests/test_kernels.py pins this including non-divisible budgets)."""
    n_local = spec.n_clients // n_shards
    if spec.use_kernel:
        from repro.kernels.pow_hash import ops as pow_ops

    def mine(prev_hash, digest, round_idx):
        client_ids = jnp.arange(n_local, dtype=jnp.uint32)
        if axis_name is not None:
            shard = aggregation.client_shard_index(axis_name).astype(jnp.uint32)
            client_ids = client_ids + shard * jnp.uint32(n_local)
        nonce_offset = round_idx.astype(jnp.uint32) * jnp.uint32(1 << 20)
        if spec.use_kernel:
            best_h, best_n = pow_ops.pow_race(
                prev_hash, digest, client_ids, spec.mine_attempts,
                nonce_offset=nonce_offset, chunk=spec.mine_chunk,
                interpret=spec.kernel_interpret)
        else:
            search = jax.vmap(
                lambda cid: mining.pow_search(
                    prev_hash, digest, cid, spec.mine_attempts,
                    nonce_offset=nonce_offset, chunk=spec.mine_chunk))
            best_h, best_n = search(client_ids)
        best_h = aggregation.client_all_gather(best_h, axis_name)
        best_n = aggregation.client_all_gather(best_n, axis_name)
        winner = mining.winner_of(best_h)
        solved = best_h[winner] <= mining.difficulty_threshold(spec.difficulty_bits)
        new_hash = mining.mix_hash(prev_hash, digest, best_n[winner])
        metrics = {
            "winner": winner.astype(jnp.int32),
            "pow_hash": best_h[winner],
            "nonce": best_n[winner],
            "solved": solved,
        }
        return metrics, new_hash

    return mine


def make_finalize(loss_fn: LossFn, spec: RoundSpec, axis_name=None,
                  n_rounds: Optional[int] = None):
    """Closing stage factory: strided global-loss eval + the next carry.

    Returns ``finalize(state, params, key, new_hash, batch, metrics) ->
    (RoundState, metrics)``. The global loss is the mean over clients of
    each post-mix model's loss on its own shard, NaN-masked by the
    ``eval_every`` stride: with ``eval_every == 1`` the eval is
    unconditional — the exact pre-stride computation, no cond in the jaxpr
    — otherwise a ``lax.cond`` skips the eval vmap on rounds where
    ``(round_idx + 1) % eval_every != 0`` and reports a NaN row, keeping
    the metrics pytree static for ``lax.scan`` (the history layout stays
    ``[K]``; downstream consumers take the last *finite* entry).

    ``n_rounds`` (the horizon, when the driver knows it) forces an eval on
    the LAST round even when ``K % eval_every != 0`` — otherwise the run
    would end on a NaN ``global_loss`` and poison every downstream
    best-K/`final_loss` consumer (the sweep_k / bench_topology selection
    bug this closes).

    The stage emits the PER-CLIENT eval vector ``[C]`` (sharded: local
    blocks all-gathered, so every engine sees the identical vector); the
    drivers reduce it to the scalar ``history[k]["global_loss"]`` with the
    same host-side ``np.mean``. The final mean deliberately does NOT run on
    device: a ``[C] -> scalar`` fp32 reduce is vectorized with lane-partial
    accumulators whose association shifts with XLA fusion context, which is
    exactly the kind of last-ulp drift the sharded engine's bit-for-bit
    contract forbids."""

    def eval_glosses(params, batch):
        # The input barrier bounds the eval subgraph identically in the
        # sharded and single-device programs: the per-client loss ends in a
        # full reduce to a scalar whose XLA:CPU association would otherwise
        # depend on what the forward pass fuses with.
        params, batch = jax.lax.optimization_barrier((params, batch))
        glosses = jax.vmap(lambda p, b: loss_fn(p, b)[0])(params, batch)
        return aggregation.client_all_gather(glosses, axis_name)

    def finalize(state, params, key, new_hash, batch, metrics):
        if spec.eval_global_loss:
            if spec.eval_every <= 1:
                metrics["global_loss"] = eval_glosses(params, batch)
            else:
                is_eval = (state.round_idx + 1) % spec.eval_every == 0
                if n_rounds is not None:
                    is_eval = jnp.logical_or(
                        is_eval, state.round_idx + 1 == n_rounds)
                metrics["global_loss"] = jax.lax.cond(
                    is_eval, lambda: eval_glosses(params, batch),
                    lambda: jnp.full((spec.n_clients,), jnp.nan, jnp.float32))
        new_state = RoundState(params=params, key=key,
                               round_idx=state.round_idx + 1,
                               prev_hash=new_hash)
        return new_state, metrics

    return finalize


def make_integrated_round(loss_fn: LossFn, spec: RoundSpec, axis_name=None,
                          n_shards: int = 1,
                          n_rounds: Optional[int] = None,
                          axis_sizes=()):
    """Build the jittable round function: (RoundState, batch) -> (RoundState, metrics).

    ``batch`` leaves have leading client axis [C, local_batch, ...]. The
    round is the composition of the stage factories above (local_train,
    perturb, the optional Byzantine attack stage, communicate, mine,
    finalize); swap a stage to express a new scenario.

    With ``axis_name`` set (a mesh axis name or tuple of names) the round
    body is written for ``shard_map``: the leading axis of params/batch is
    this shard's ``C / n_shards`` client block and cross-client steps use
    collectives (see each stage factory). ``axis_name=None`` is the exact
    single-device computation. ``n_rounds`` (when the driver knows the
    horizon) lets the finalize stage force a global-loss eval on the last
    round regardless of the ``eval_every`` stride. ``axis_sizes`` (the
    mesh's per-axis extents, ``ScanCarryPlan.axis_sizes``) refines the mix
    resolution on compound client axes; when omitted only the total
    ``n_shards`` is attributed."""
    local_train = make_local_train(loss_fn, spec, n_shards)
    perturb = make_perturb(spec, axis_name, n_shards)
    attack = make_attack(spec, axis_name, n_shards)
    communicate = make_communicate(spec, axis_name, n_shards,
                                   axis_sizes=axis_sizes)
    mine = make_mine(spec, axis_name, n_shards)
    finalize = make_finalize(loss_fn, spec, axis_name, n_rounds)

    def round_fn(state: RoundState, batch) -> Tuple[RoundState, Dict[str, jnp.ndarray]]:
        key, k_lazy, k_dp = jax.random.split(state.key, 3)
        k_topo = jax.random.fold_in(k_dp, _TOPOLOGY_SALT) \
            if spec.topology.stochastic else None

        params, local_losses = local_train(state.params, batch)
        params, broadcast_full = perturb(params, k_lazy, k_dp)
        params, broadcast_full = attack(params, k_dp, full=broadcast_full)
        params, digest, divergence, extra = communicate(
            params, state.params, k_topo, state.round_idx,
            full=broadcast_full)
        mine_metrics, new_hash = mine(state.prev_hash, digest, state.round_idx)

        # per-client [C] vector; the drivers np.mean it on host — a device
        # `jnp.mean` here is a fusion-context-sensitive scalar reduce over
        # the gathered axis (same discipline as global_loss, RL301)
        local_losses = aggregation.client_all_gather(local_losses, axis_name)
        metrics = {"local_loss": local_losses, **mine_metrics,
                   "digest": digest, "divergence": divergence, **extra}
        return finalize(state, params, key, new_hash, batch, metrics)

    return round_fn


# How many times each compiled multi-round runner was (re)traced. The
# equivalence test asserts this stays flat in K — the whole point of the
# scan engine is ONE trace for the full horizon, not one per round.
TRACE_COUNTS: Dict[str, int] = {"scan_runner": 0}

# Problem-size crossovers for run_blade_fl's automatic dispatch, measured on
# XLA:CPU (benchmarks/bench_rounds.py; docs/architecture.md §Kernel
# dispatch). Micro-sims at or below BOTH micro bounds run faster on the
# per-round driver than nested in the scan's while loop, and a PoW grid
# under _KERNEL_MIN_ATTEMPTS costs more in kernel launch/grid overhead than
# the fori_loop it replaces.
_MICRO_MAX_CLIENTS = 4
_MICRO_MAX_SAMPLES = 32
_KERNEL_MIN_ATTEMPTS = 512

# The last decision run_blade_fl's auto dispatch took (driver/pow/mix +
# reason) — module-level like TRACE_COUNTS so benchmarks can record the
# chosen lowering in their CSV notes without re-deriving it.
LAST_DISPATCH: Dict[str, str] = {}


def dispatch_plan(spec: RoundSpec, batches, n_rounds: int, *,
                  jit: bool = True, stacked: bool = False,
                  mesh: Optional[Mesh] = None) -> Dict[str, str]:
    """Pick the (driver, pow, mix) lowerings for this problem size.

    Pure function of the call signature — ``run_blade_fl`` applies it and
    records the result in :data:`LAST_DISPATCH`; benches call it directly to
    annotate their CSV lines. Keys:

      ``driver`` — ``"scan"`` (all K rounds in one jitted ``lax.scan``) or
        ``"loop"`` (per-round jitted driver). Callables and ``jit=False``
        force the loop; static micro-sims at or below the measured CPU
        crossover (C <= 4 AND <= 32 samples per client, single device,
        non-stacked) dispatch to the loop too — the results are bitwise
        identical either way, only wall-clock differs.
      ``pow`` — ``"kernel"`` (Pallas 2-D grid) when ``spec.use_kernel`` and
        the budget amortizes the grid (``mine_attempts >=
        _KERNEL_MIN_ATTEMPTS``), else ``"fori_loop"``. Bitwise identical
        either way.
      ``mix`` — ``"fused"`` (Pallas row-block matmul + one-sweep
        diagnostics, tolerance tier) when ``spec.fused_mix``;
        ``"segment"`` when the resolver reroutes the mix through the
        sparse gather + ``segment_sum`` path (ExplicitSparse topologies,
        low-degree GATHER mixes, or ``spec.sparse_mix=True``);
        ``"robust"`` when ``spec.robust_agg`` overrides the linear mix
        with a Byzantine-robust consensus reducer; else ``"jnp"``.
      ``mix_mode`` — the resolved ``MixPlan.mode`` executor strategy
        (``topology.EXEC_*``). Reported from the SAME
        :func:`topology.resolve_mix_plan` call ``make_communicate``
        executes, so report and trace cannot drift (pinned in
        tests/test_hierarchy.py).
      ``reason`` — one phrase saying why the driver was chosen.
    """
    plan: Dict[str, str] = {}
    if callable(batches):
        plan.update(driver="loop", reason="per-round batch callable")
    elif not jit:
        plan.update(driver="loop", reason="jit=False debugging path")
    else:
        samples = 0
        if not stacked:
            leaves = jax.tree.leaves(batches)
            samples = max((x.shape[1] for x in leaves if x.ndim > 1),
                          default=0)
        micro = (mesh is None and not stacked
                 and spec.n_clients <= _MICRO_MAX_CLIENTS
                 and samples <= _MICRO_MAX_SAMPLES)
        if micro:
            plan.update(driver="loop",
                        reason=f"micro-sim C={spec.n_clients} samples="
                               f"{samples} below scan crossover")
        else:
            plan.update(driver="scan", reason="static batch at/above "
                                              "scan crossover")
    if spec.use_kernel and spec.mine_attempts < _KERNEL_MIN_ATTEMPTS:
        plan["pow"] = "fori_loop"
    else:
        plan["pow"] = "kernel" if spec.use_kernel else "fori_loop"
    mplan = topology_lib.resolve_mix_plan(spec)
    plan["mix"] = mplan.mix
    plan["mix_mode"] = mplan.mode
    return plan

# Jitted runners cached on (loss_fn identity, static config). A weakref
# scheme cannot work here — the cached runner's closure chain pins loss_fn,
# so a weak key would never die. A small bounded LRU is the honest tradeoff:
# module-level loss fns (mlp_loss, sweep/benchmark loops at fixed config)
# get cross-call reuse of the compiled executable, while per-call closures
# (launch/train arch paths) pin at most maxsize compiled programs before
# LRU eviction frees them.
@functools.lru_cache(maxsize=16)
def _scan_runner(loss_fn: LossFn, spec: RoundSpec, n_rounds: int,
                 stacked: bool, mesh: Optional[Mesh] = None,
                 plan: Optional["plans_lib.ScanCarryPlan"] = None):
    """Build (and cache) the jitted K-round runner for this config.

    With ``mesh``/``plan`` the whole scan runs inside ``shard_map``: the
    carry enters with the plan's layout (params client-sharded, ledger
    link/key/counter replicated), stays sharded across all K rounds, and
    the stacked metrics come out replicated — XLA never reshards the
    donated carry between rounds."""
    axis_name = plan.client_axes if mesh is not None else None
    n_shards = plan.n_shards if mesh is not None else 1
    axis_sizes = plan.axis_sizes if mesh is not None else ()
    round_fn = make_integrated_round(loss_fn, spec, axis_name=axis_name,
                                     n_shards=n_shards, n_rounds=n_rounds,
                                     axis_sizes=axis_sizes)

    def run(state: RoundState, batch):
        TRACE_COUNTS["scan_runner"] += 1
        if stacked:
            return jax.lax.scan(round_fn, state, batch)
        return jax.lax.scan(lambda s, _: round_fn(s, batch), state, None,
                            length=n_rounds)

    if mesh is not None:
        from jax.experimental.shard_map import shard_map

        state_specs = RoundState(params=plan.client_spec(), key=P(),
                                 round_idx=P(), prev_hash=P())
        run = shard_map(run, mesh=mesh,
                        in_specs=(state_specs, plan.batch_spec(stacked)),
                        out_specs=(state_specs, P()),
                        check_rep=False)

    # Donate the carry so params never hold two live copies on accelerator
    # backends; CPU has no donation support and would only warn.
    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(run, donate_argnums=donate)


@functools.lru_cache(maxsize=16)
def _round_runner(loss_fn: LossFn, spec: RoundSpec,
                  n_rounds: Optional[int] = None):
    """Cached jitted single-round step for the Python-loop path, so repeated
    ``run_blade_fl`` calls at the same config (K-sweeps, benchmarks) reuse
    the compiled executable instead of retracing per call. ``n_rounds``
    mirrors the scan runner's forced last-round eval (part of the cache key
    only when ``eval_every > 1`` actually consults it)."""
    return jax.jit(make_integrated_round(loss_fn, spec, n_rounds=n_rounds))


def run_blade_fl_scan(loss_fn: LossFn, spec: RoundSpec, params_single, batch,
                      key, n_rounds: int,
                      ledger: Optional[chain.Ledger] = None,
                      stacked: bool = False,
                      mesh: Optional[Mesh] = None,
                      plan: Optional["plans_lib.ScanCarryPlan"] = None):
    """Compiled driver: all K integrated rounds in one ``jax.jit(lax.scan)``.

    ``batch`` is a static pytree: one ``[C, ...]`` batch reused every round,
    or — with ``stacked=True`` — a ``[K, C, ...]`` stack scanned over as xs.
    The carry stays on device for the whole horizon; metrics and block-header
    fields come back stacked and the single end-of-run ``device_get`` is the
    only host transfer. Returns the same ``(state, history, ledger)`` triple
    as the Python-loop path, with the ledger rebuilt and re-validated by
    ``chain.ledger_from_scan``.

    Pass ``mesh`` (and optionally a ``sharding.plans.scan_carry_plan``) to
    run the scan client-sharded: the carry is laid out per the plan, the
    whole K-round horizon executes inside ``shard_map``, and the results —
    params, metrics, ledger hash links — are bit-for-bit those of the
    single-device scan (see module docstring).
    """
    if callable(batch):
        raise TypeError(
            "run_blade_fl_scan needs a static batch pytree; use "
            "run_blade_fl for per-round batch callables")
    if stacked:
        leads = {x.shape[0] for x in jax.tree.leaves(batch)}
        if leads != {int(n_rounds)}:
            raise ValueError(
                f"stacked batch leading dims {sorted(leads)} != "
                f"n_rounds={int(n_rounds)}; scan takes its length from xs")
    if mesh is not None and plan is None:
        plan = plans_lib.scan_carry_plan(mesh, spec.n_clients)
    runner = _scan_runner(loss_fn, spec, int(n_rounds), bool(stacked),
                          mesh, plan)
    state = init_state(params_single, key, spec.n_clients)
    state, stacked_metrics = runner(state, batch)
    host = jax.device_get(stacked_metrics)   # the one host transfer
    # the engine emits per-client losses [K, C]; the scalar means are
    # reduced here on host (see make_finalize / make_integrated_round)
    glosses = host.pop("global_loss", None)
    llosses = host.pop("local_loss")
    history = [{name: float(v[k]) for name, v in host.items()}
               for k in range(int(n_rounds))]
    for k in range(int(n_rounds)):
        history[k]["local_loss_mean"] = float(np.mean(llosses[k]))
    if glosses is not None:
        for k in range(int(n_rounds)):
            history[k]["global_loss"] = float(np.mean(glosses[k]))
    ledger = chain.ledger_from_scan(
        host["digest"], host["winner"], host["nonce"], host["pow_hash"],
        ledger=ledger)
    return state, history, ledger


def run_blade_fl(loss_fn: LossFn, spec: RoundSpec, params_single, batches,
                 key, n_rounds: int, ledger: Optional[chain.Ledger] = None,
                 jit: bool = True, stacked: bool = False,
                 mesh: Optional[Mesh] = None,
                 plan: Optional["plans_lib.ScanCarryPlan"] = None):
    """Run K integrated rounds; returns (final RoundState, history, ledger).

    Dispatches to the compiled scan engine when ``batches`` is a static
    pytree (see module docstring); falls back to the per-round Python loop
    for callables (``batches(k) -> batch``), ``jit=False``, and static
    micro-sims below the scan crossover (:func:`dispatch_plan` — results are
    bitwise identical on either driver, this only picks the faster one).
    The same plan downgrades ``spec.use_kernel`` when the mining budget is
    too small to amortize the Pallas grid; the decision taken is recorded in
    :data:`LAST_DISPATCH`. ``mesh`` (+ optional ``plan``) selects the
    client-sharded scan engine and therefore requires the static-batch path.
    """
    decision = dispatch_plan(spec, batches, n_rounds, jit=jit,
                             stacked=stacked, mesh=mesh)
    LAST_DISPATCH.clear()
    LAST_DISPATCH.update(decision)
    if spec.use_kernel and decision["pow"] == "fori_loop":
        spec = dataclasses.replace(spec, use_kernel=False)
    if decision["driver"] == "scan":
        return run_blade_fl_scan(loss_fn, spec, params_single, batches, key,
                                 n_rounds, ledger=ledger, stacked=stacked,
                                 mesh=mesh, plan=plan)
    if mesh is not None:
        raise ValueError(
            "mesh-sharded execution needs the compiled scan engine: pass a "
            "static batch pytree and jit=True (per-round batch callables "
            "would reshard the carry every round)")
    # the horizon only matters to the forced last-round eval; keep it out of
    # the runner cache key when eval_every == 1 so K-sweeps share one
    # compiled round
    horizon = int(n_rounds) if spec.eval_every > 1 else None
    round_fn = _round_runner(loss_fn, spec, horizon) if jit \
        else make_integrated_round(loss_fn, spec, n_rounds=horizon)
    state = init_state(params_single, key, spec.n_clients)
    ledger = ledger if ledger is not None else chain.Ledger()
    history = []
    for k in range(n_rounds):
        if callable(batches):
            batch = batches(k)
        elif stacked:
            batch = jax.tree.map(lambda x: x[k], batches)
        else:
            batch = batches
        state, metrics = round_fn(state, batch)
        block = chain.make_block(
            index=len(ledger.blocks), prev_hash=ledger.head_hash,
            model_digest=int(metrics["digest"]), winner=int(metrics["winner"]),
            nonce=int(metrics["nonce"]), pow_hash=int(metrics["pow_hash"]))
        ledger.append(block)
        metrics = dict(metrics)
        glosses = metrics.pop("global_loss", None)
        llosses = metrics.pop("local_loss")
        entry = {k2: float(v) for k2, v in metrics.items()}
        # identical host-side reductions to the scan driver's
        entry["local_loss_mean"] = float(np.mean(np.asarray(llosses)))
        if glosses is not None:
            entry["global_loss"] = float(np.mean(np.asarray(glosses)))
        history.append(entry)
    return state, history, ledger


# ---------------------------------------------------------------------------
# Cohort-sampled population driver (enrolled C >> active A)
# ---------------------------------------------------------------------------


class PopulationStore:
    """Host-side parameter store for the enrolled population.

    The cohort driver's memory contract: devices only ever hold the
    ``[A, ...]`` active-cohort stack; the ``C_enrolled`` population lives
    here, LAZILY — every client starts as a reference to the shared init
    model and only materializes its own row after a round it participated
    in scatters back. Host memory is therefore
    O(model + touched · model), never O(C_enrolled · model): a
    10k-population run that ever activates 400 distinct clients stores 401
    model copies.

    ``gather(idx)`` stacks the cohort's rows into device arrays;
    ``scatter(idx, cohort_params)`` writes a round's post-mix cohort back
    (one ``device_get``, rows copied out so no stacked device buffer is
    pinned).
    """

    def __init__(self, params_single, n_enrolled: int):
        if n_enrolled < 1:
            raise ValueError("PopulationStore needs n_enrolled >= 1")
        self.n_enrolled = int(n_enrolled)
        self._init = jax.tree.map(lambda x: np.asarray(x), params_single)
        self._rows: Dict[int, Any] = {}

    @property
    def touched(self) -> int:
        """How many clients have materialized their own row."""
        return len(self._rows)

    def materialized_bytes(self) -> int:
        """Host bytes held beyond the shared init model."""
        row_bytes = sum(x.nbytes for x in jax.tree.leaves(self._init))
        return row_bytes * self.touched

    def _check_idx(self, idx: np.ndarray) -> np.ndarray:
        idx = np.asarray(idx)
        if idx.ndim != 1:
            raise ValueError(f"cohort index must be 1-D, got {idx.shape}")
        if idx.size and (idx.min() < 0 or idx.max() >= self.n_enrolled):
            raise ValueError(
                f"cohort indices must lie in [0, {self.n_enrolled}), got "
                f"range [{idx.min()}, {idx.max()}]")
        return idx

    def gather(self, idx) -> Any:
        """Stack rows ``idx`` into a ``[len(idx), ...]`` device pytree."""
        idx = self._check_idx(idx)
        rows = [self._rows.get(int(i), self._init) for i in idx]
        return jax.tree.map(lambda *xs: jnp.asarray(np.stack(xs)), *rows)

    def scatter(self, idx, cohort_params) -> None:
        """Write a round's post-mix ``[len(idx), ...]`` cohort stack back."""
        idx = self._check_idx(idx)
        host = jax.device_get(cohort_params)
        leads = {x.shape[0] for x in jax.tree.leaves(host)}
        if leads != {idx.size}:
            raise ValueError(
                f"cohort_params leading dims {sorted(leads)} != "
                f"len(idx)={idx.size}")
        for a, i in enumerate(idx):
            self._rows[int(i)] = jax.tree.map(lambda x: np.array(x[a]), host)


@functools.lru_cache(maxsize=16)
def _cohort_round_runner(loss_fn: LossFn, spec: RoundSpec,
                         n_rounds: Optional[int],
                         mesh: Optional[Mesh] = None,
                         plan: Optional["plans_lib.CohortCarryPlan"] = None):
    """Cached jitted single-round step for the cohort driver. Identical to
    :func:`_round_runner` single-device (so an ``A == C_enrolled`` cohort
    run is bitwise the loop driver); with ``mesh``/``plan`` the round body
    runs inside ``shard_map`` with the ``[A, ...]`` cohort stack sharded
    over the plan's client axes — the enrolled population never has a
    device layout at all."""
    if mesh is None:
        return _round_runner(loss_fn, spec, n_rounds)
    from jax.experimental.shard_map import shard_map

    round_fn = make_integrated_round(loss_fn, spec,
                                     axis_name=plan.client_axes,
                                     n_shards=plan.n_shards,
                                     n_rounds=n_rounds,
                                     axis_sizes=plan.axis_sizes)
    state_specs = RoundState(params=plan.client_spec(), key=P(),
                             round_idx=P(), prev_hash=P())
    fn = shard_map(round_fn, mesh=mesh,
                   in_specs=(state_specs, plan.batch_spec(False)),
                   out_specs=(state_specs, P()),
                   check_rep=False)
    return jax.jit(fn)


def run_blade_fl_cohort(loss_fn: LossFn, spec: RoundSpec, params_single,
                        batches, key, n_rounds: int,
                        cohort: topology_lib.CohortSchedule,
                        ledger: Optional[chain.Ledger] = None,
                        store: Optional[PopulationStore] = None,
                        mesh: Optional[Mesh] = None,
                        plan: Optional["plans_lib.CohortCarryPlan"] = None):
    """Cohort-sampled population driver: K rounds over ``C_enrolled``
    clients of which only an active cohort of ``A = spec.n_clients``
    participates per round.

    Per round: draw the cohort from the engine's per-round ``k_topo``
    stream (``cohort.cohort_at`` — so ``topology_keys(key, K)`` replays the
    memberships), gather the cohort's rows out of the host-side
    :class:`PopulationStore`, run ONE integrated round — training, lazy/DP
    perturbation, digest, the intra-cohort topology mix, the PoW race and
    the hash link, all at cohort size ``A`` — and scatter the post-mix
    cohort back. The device working set is O(A·model) + the mix's
    O(A·deg), independent of ``C_enrolled``; nothing of shape
    ``[C_enrolled, ...]`` (let alone ``[C, C]``) ever exists on device.

    ``spec`` describes the INTRA-cohort round (``spec.n_clients`` must
    equal ``cohort.cohort_size``): ``spec.topology`` mixes within the
    round's cohort, lazy/DP/mining semantics are unchanged. The ledger is
    global — one hash-linked chain across rounds exactly like the other
    drivers, with the device-side ``prev_hash`` carry crossing rounds
    through the host mirror. ``PartialParticipation`` population semantics
    are ``CohortSchedule(..., bias="prefix")`` + ``FullMesh`` intra-cohort:
    the first ``A`` enrolled clients mix every round and the rest idle —
    now at O(A) cost instead of a masked dense ``[C, C]`` mix.

    ``batches`` is either a callable ``(round_idx, cohort_idx) ->
    [A, ...]`` batch pytree (the scalable form — build only the cohort's
    data) or a static ``[C_enrolled, ...]`` pytree indexed host-side per
    round. ``key`` follows the exact split chain of the other drivers.

    Returns ``(store, history, ledger)``; each history entry additionally
    records the round's cohort as ``entry["cohort"]``.
    """
    if cohort.cohort_size != spec.n_clients:
        raise ValueError(
            f"spec.n_clients={spec.n_clients} must equal "
            f"cohort.cohort_size={cohort.cohort_size}: the round engine "
            "runs at cohort size")
    if store is None:
        store = PopulationStore(params_single, cohort.n_enrolled)
    if store.n_enrolled != cohort.n_enrolled:
        raise ValueError(
            f"store holds n_enrolled={store.n_enrolled} but the schedule "
            f"samples from {cohort.n_enrolled}")
    if callable(batches):
        batch_fn = batches
    else:
        leads = {x.shape[0] for x in jax.tree.leaves(batches)}
        if leads != {cohort.n_enrolled}:
            raise ValueError(
                f"static batches leading dims {sorted(leads)} != "
                f"n_enrolled={cohort.n_enrolled} (pass a callable "
                "(round_idx, cohort_idx) -> batch to build per-cohort data)")
        host_batches = jax.tree.map(np.asarray, batches)

        def batch_fn(k, idx):
            return jax.tree.map(lambda x: jnp.asarray(x[np.asarray(idx)]),
                                host_batches)

    if mesh is not None and plan is None:
        plan = plans_lib.cohort_carry_plan(mesh, cohort.n_enrolled,
                                           spec.n_clients)
    decision = dispatch_plan(spec, batches, n_rounds, mesh=mesh)
    decision.update(driver="cohort",
                    reason=f"cohort A={cohort.cohort_size} over "
                           f"C_enrolled={cohort.n_enrolled}")
    LAST_DISPATCH.clear()
    LAST_DISPATCH.update(decision)
    # mirror run_blade_fl's horizon handling so A == C_enrolled cohort runs
    # reuse (and bitwise match) the loop driver's cached runner
    horizon = int(n_rounds) if spec.eval_every > 1 else None
    runner = _cohort_round_runner(loss_fn, spec, horizon, mesh, plan)
    ledger = ledger if ledger is not None else chain.Ledger()
    history = []
    host_key = key
    prev_hash = jnp.uint32(chain.GENESIS_HASH)
    for k in range(int(n_rounds)):
        # host mirror of the round body's split chain (= topology_keys)
        next_key, _k_lazy, k_dp = jax.random.split(host_key, 3)
        k_topo = jax.random.fold_in(k_dp, _TOPOLOGY_SALT)
        idx = np.asarray(cohort.cohort_at(k_topo))
        state = RoundState(params=store.gather(idx), key=host_key,
                           round_idx=jnp.int32(k), prev_hash=prev_hash)
        state, metrics = runner(state, batch_fn(k, idx))
        store.scatter(idx, state.params)
        prev_hash = state.prev_hash
        host_key = next_key
        block = chain.make_block(
            index=len(ledger.blocks), prev_hash=ledger.head_hash,
            model_digest=int(metrics["digest"]), winner=int(metrics["winner"]),
            nonce=int(metrics["nonce"]), pow_hash=int(metrics["pow_hash"]))
        ledger.append(block)
        metrics = dict(metrics)
        glosses = metrics.pop("global_loss", None)
        llosses = metrics.pop("local_loss")
        entry = {k2: float(v) for k2, v in metrics.items()}
        entry["local_loss_mean"] = float(np.mean(np.asarray(llosses)))
        if glosses is not None:
            entry["global_loss"] = float(np.mean(np.asarray(glosses)))
        entry["cohort"] = [int(i) for i in idx]
        history.append(entry)
    return store, history, ledger
