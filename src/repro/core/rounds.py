"""BLADE-FL integrated round (paper §3.1, Fig. 1) as a single compiled step.

One integrated round =
  Step 1  local training: tau full-batch GD iterations per client
          (lazy clients instead plagiarize + add noise — eq. 7)
  Step 2  model broadcast & verification (digital signature -> digest here)
  Step 3  mining: per-client PoW nonce race over a calibrated attempt budget
  Step 4  block validation: winner's block appended (hash-linked)
  Step 5  local updating: every client adopts the aggregate

On the production mesh the client axis C is sharded over 'data' (x 'pod');
local iterations are collective-free across clients (vmap), the aggregate is
one all-reduce, plagiarism is a collective-permute, and the PoW race is an
argmin over the client axis. The same engine drives the paper-scale MLP
simulation (C=20 on one CPU device) and the 10 assigned architectures on the
512-chip dry-run mesh.

Two multi-round driver paths share the single-round engine:

  * ``run_blade_fl_scan`` — the compiled path. All K integrated rounds run
    inside one ``jax.jit(lax.scan)``; the ``RoundState`` carry (params, PRNG
    key, round counter, prev-hash) never leaves the device (donated on
    accelerator backends), per-round metrics and block-header fields come
    back stacked ``[K]``, and the host sees exactly one end-of-run transfer.
    ``chain.ledger_from_scan`` then replays the stacked headers through the
    validating ledger, so Steps 2-5 blockchain semantics are preserved
    bit-for-bit against the Python loop. Requires the batch to be a static
    pytree — either one ``[C, ...]`` batch reused every round (the paper's
    full-batch GD) or a ``[K, C, ...]`` stack (``stacked=True``, built by
    ``data/pipeline.py`` sources).
  * the Python loop inside ``run_blade_fl`` — one jitted round per
    iteration, a host sync per metric per round. Kept for arbitrary
    per-round batch *callables* (data that cannot be materialized up front)
    and for ``jit=False`` debugging.

``run_blade_fl`` is the single entry point: it dispatches to the scan engine
whenever the batch argument is a static pytree and falls back to the Python
loop for callables. Both paths return the same ``(state, history, ledger)``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import aggregation, chain, dp as dp_lib, lazy as lazy_lib, mining

LossFn = Callable[[Any, Any], Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]]


@dataclasses.dataclass(frozen=True)
class RoundSpec:
    """Static configuration of one integrated round."""
    n_clients: int
    tau: int                    # local GD iterations (eq. 3)
    eta: float                  # learning rate
    n_lazy: int = 0
    sigma2: float = 0.0         # lazy artificial-noise variance
    dp_sigma: float = 0.0       # DP Gaussian mechanism (§6)
    mine_attempts: int = 1024   # calibrated from beta (allocation.mining_iterations)
    difficulty_bits: int = 8
    microbatches: int = 1       # grad accumulation inside each local iteration
    eval_global_loss: bool = True
    # beyond-paper (§8 future work): flag near-duplicate broadcast models
    # before aggregation (core/detection.py); adds n_suspects to metrics.
    detect_lazy: bool = False
    detect_threshold: float = 0.2


class RoundState(NamedTuple):
    params: Any                 # pytree, leading client axis C
    key: jax.Array
    round_idx: jnp.ndarray      # int32
    prev_hash: jnp.ndarray      # uint32


def init_state(params_single, key, n_clients: int) -> RoundState:
    return RoundState(
        params=aggregation.replicate(params_single, n_clients),
        key=key,
        round_idx=jnp.int32(0),
        prev_hash=jnp.uint32(chain.GENESIS_HASH),
    )


def _microbatched_grad(loss_fn: LossFn, n_mb: int):
    """grad of the mean loss over n_mb microbatches (axis-0 split), with
    per-microbatch remat so activation memory is O(batch / n_mb)."""

    def split(batch):
        return jax.tree.map(
            lambda x: x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:]), batch)

    @functools.partial(jax.checkpoint, static_argnums=())
    def one_mb(params, mb):
        loss, _ = loss_fn(params, mb)
        return loss

    def grad_fn(params, batch):
        mbs = split(batch)

        def body(acc, mb):
            l, g = jax.value_and_grad(one_mb)(params, mb)
            return (acc[0] + l, jax.tree.map(jnp.add, acc[1], g)), None

        zero = (jnp.zeros((), jnp.float32), jax.tree.map(jnp.zeros_like, params))
        (loss, grads), _ = jax.lax.scan(body, zero, mbs)
        scale = 1.0 / n_mb
        return loss * scale, jax.tree.map(lambda g: g * scale, grads)

    return grad_fn


def make_integrated_round(loss_fn: LossFn, spec: RoundSpec):
    """Build the jittable round function: (RoundState, batch) -> (RoundState, metrics).

    ``batch`` leaves have leading client axis [C, local_batch, ...].
    """
    if spec.microbatches > 1:
        grad_fn = _microbatched_grad(loss_fn, spec.microbatches)
    else:
        def grad_fn(params, batch):
            (loss, _), grads = jax.value_and_grad(
                lambda p, b: loss_fn(p, b), has_aux=True)(params, batch)
            return loss, grads

    per_client_grad = jax.vmap(grad_fn)

    def round_fn(state: RoundState, batch) -> Tuple[RoundState, Dict[str, jnp.ndarray]]:
        key, k_lazy, k_dp = jax.random.split(state.key, 3)
        params = state.params

        # Step 1 — local training: tau collective-free GD iterations / client.
        # The carried loss is the one observed at the last iteration (free —
        # value_and_grad computes it anyway).
        def local_iter(_, carry):
            p, _ = carry
            losses, grads = per_client_grad(p, batch)
            p = jax.tree.map(lambda w, g: w - spec.eta * g.astype(w.dtype),
                             p, grads)
            return (p, losses)

        loss0 = jnp.zeros((spec.n_clients,), jnp.float32)
        params, local_losses = jax.lax.fori_loop(
            0, spec.tau, local_iter, (params, loss0))

        # Step 1 (lazy clients) — plagiarize + artificial noise (eq. 7)
        params = lazy_lib.apply_lazy(params, k_lazy, spec.n_clients,
                                     spec.n_lazy, spec.sigma2)
        # §6 — optional DP noise on the broadcast models
        params = dp_lib.privatize(params, k_dp, spec.dp_sigma)

        # Step 2 — broadcast & verification: header digest of shared models;
        # optional plagiarism screening on the broadcast set (every client
        # sees every model, so every client can vote the same flags)
        digest = mining.digest_tree(params)
        if spec.detect_lazy:
            from repro.core import detection
            suspects, _ = detection.detect_lazy_round(
                params, state.params, threshold_frac=spec.detect_threshold)

        # Step 3 — mining race over the client axis
        client_ids = jnp.arange(spec.n_clients, dtype=jnp.uint32)
        search = jax.vmap(
            lambda cid: mining.pow_search(
                state.prev_hash, digest, cid, spec.mine_attempts,
                nonce_offset=state.round_idx.astype(jnp.uint32) * jnp.uint32(1 << 20)))
        best_h, best_n = search(client_ids)
        winner = mining.winner_of(best_h)
        solved = best_h[winner] <= mining.difficulty_threshold(spec.difficulty_bits)

        # Step 4 — block validation: hash-link the new block header
        new_hash = mining.mix_hash(state.prev_hash, digest, best_n[winner])

        # client-model spread BEFORE aggregation (diagnostic for delta, Def. 1)
        divergence = aggregation.client_divergence(params)

        # Step 5 — local updating: every client adopts the aggregate
        params = aggregation.fedavg(params)

        metrics = {
            "local_loss_mean": jnp.mean(local_losses),
            "winner": winner.astype(jnp.int32),
            "pow_hash": best_h[winner],
            "nonce": best_n[winner],
            "solved": solved,
            "digest": digest,
            "divergence": divergence,
        }
        if spec.detect_lazy:
            metrics["n_suspects"] = jnp.sum(suspects).astype(jnp.int32)
        if spec.eval_global_loss:
            glosses = jax.vmap(lambda p, b: loss_fn(p, b)[0])(params, batch)
            metrics["global_loss"] = jnp.mean(glosses)

        new_state = RoundState(params=params, key=key,
                               round_idx=state.round_idx + 1,
                               prev_hash=new_hash)
        return new_state, metrics

    return round_fn


# How many times each compiled multi-round runner was (re)traced. The
# equivalence test asserts this stays flat in K — the whole point of the
# scan engine is ONE trace for the full horizon, not one per round.
TRACE_COUNTS: Dict[str, int] = {"scan_runner": 0}

# Jitted runners cached on (loss_fn identity, static config). A weakref
# scheme cannot work here — the cached runner's closure chain pins loss_fn,
# so a weak key would never die. A small bounded LRU is the honest tradeoff:
# module-level loss fns (mlp_loss, sweep/benchmark loops at fixed config)
# get cross-call reuse of the compiled executable, while per-call closures
# (launch/train arch paths) pin at most maxsize compiled programs before
# LRU eviction frees them.
@functools.lru_cache(maxsize=16)
def _scan_runner(loss_fn: LossFn, spec: RoundSpec, n_rounds: int,
                 stacked: bool):
    """Build (and cache) the jitted K-round runner for this config."""
    round_fn = make_integrated_round(loss_fn, spec)

    def run(state: RoundState, batch):
        TRACE_COUNTS["scan_runner"] += 1
        if stacked:
            return jax.lax.scan(round_fn, state, batch)
        return jax.lax.scan(lambda s, _: round_fn(s, batch), state, None,
                            length=n_rounds)

    # Donate the carry so params never hold two live copies on accelerator
    # backends; CPU has no donation support and would only warn.
    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(run, donate_argnums=donate)


@functools.lru_cache(maxsize=16)
def _round_runner(loss_fn: LossFn, spec: RoundSpec):
    """Cached jitted single-round step for the Python-loop path, so repeated
    ``run_blade_fl`` calls at the same config (K-sweeps, benchmarks) reuse
    the compiled executable instead of retracing per call."""
    return jax.jit(make_integrated_round(loss_fn, spec))


def run_blade_fl_scan(loss_fn: LossFn, spec: RoundSpec, params_single, batch,
                      key, n_rounds: int,
                      ledger: Optional[chain.Ledger] = None,
                      stacked: bool = False):
    """Compiled driver: all K integrated rounds in one ``jax.jit(lax.scan)``.

    ``batch`` is a static pytree: one ``[C, ...]`` batch reused every round,
    or — with ``stacked=True`` — a ``[K, C, ...]`` stack scanned over as xs.
    The carry stays on device for the whole horizon; metrics and block-header
    fields come back stacked and the single end-of-run ``device_get`` is the
    only host transfer. Returns the same ``(state, history, ledger)`` triple
    as the Python-loop path, with the ledger rebuilt and re-validated by
    ``chain.ledger_from_scan``.
    """
    if callable(batch):
        raise TypeError(
            "run_blade_fl_scan needs a static batch pytree; use "
            "run_blade_fl for per-round batch callables")
    if stacked:
        leads = {x.shape[0] for x in jax.tree.leaves(batch)}
        if leads != {int(n_rounds)}:
            raise ValueError(
                f"stacked batch leading dims {sorted(leads)} != "
                f"n_rounds={int(n_rounds)}; scan takes its length from xs")
    runner = _scan_runner(loss_fn, spec, int(n_rounds), bool(stacked))
    state = init_state(params_single, key, spec.n_clients)
    state, stacked_metrics = runner(state, batch)
    host = jax.device_get(stacked_metrics)   # the one host transfer
    history = [{name: float(v[k]) for name, v in host.items()}
               for k in range(int(n_rounds))]
    ledger = chain.ledger_from_scan(
        host["digest"], host["winner"], host["nonce"], host["pow_hash"],
        ledger=ledger)
    return state, history, ledger


def run_blade_fl(loss_fn: LossFn, spec: RoundSpec, params_single, batches,
                 key, n_rounds: int, ledger: Optional[chain.Ledger] = None,
                 jit: bool = True, stacked: bool = False):
    """Run K integrated rounds; returns (final RoundState, history, ledger).

    Dispatches to the compiled scan engine when ``batches`` is a static
    pytree (see module docstring); falls back to the per-round Python loop
    for callables (``batches(k) -> batch``) or ``jit=False``.
    """
    if jit and not callable(batches):
        return run_blade_fl_scan(loss_fn, spec, params_single, batches, key,
                                 n_rounds, ledger=ledger, stacked=stacked)
    round_fn = _round_runner(loss_fn, spec) if jit \
        else make_integrated_round(loss_fn, spec)
    state = init_state(params_single, key, spec.n_clients)
    ledger = ledger if ledger is not None else chain.Ledger()
    history = []
    for k in range(n_rounds):
        if callable(batches):
            batch = batches(k)
        elif stacked:
            batch = jax.tree.map(lambda x: x[k], batches)
        else:
            batch = batches
        state, metrics = round_fn(state, batch)
        block = chain.make_block(
            index=len(ledger.blocks), prev_hash=ledger.head_hash,
            model_digest=int(metrics["digest"]), winner=int(metrics["winner"]),
            nonce=int(metrics["nonce"]), pow_hash=int(metrics["pow_hash"]))
        ledger.append(block)
        history.append({k2: float(v) for k2, v in metrics.items()})
    return state, history, ledger
