"""Proof-of-Work simulation (paper §2.2, §3.1 Step 3).

The real Bitcoin-style PoW (SHA-256 preimage search) is replaced by an
integer mixing hash (xorshift-mult avalanche) searched over a calibrated
number of nonce attempts — the computing-budget accounting (eq. 1) is what
matters to the paper, not cryptographic strength. The same mix is implemented
three ways:

  * ``mix_hash``            — vectorized jnp (reference / CPU sim)
  * kernels/pow_hash        — Pallas TPU kernel (nonce grid in VMEM tiles)
  * ``mine_block_py``       — python/hashlib (ledger-level, core/chain.py)

Each client searches its own nonce space; the winner is the argmin hash
across the client axis (a psum/argmin collective on the mesh — the
decentralized analogue of "first to find").
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# numpy scalars, NOT jnp arrays: creating a jnp array at import time would
# initialize the backend and lock the device count before the dry-run can
# request its 512 placeholder devices.
_M1 = np.uint32(2654435761)   # Knuth multiplicative
_M2 = np.uint32(2246822519)
_M3 = np.uint32(3266489917)


def _avalanche(h):
    h = h ^ (h >> np.uint32(15))
    h = h * _M2
    h = h ^ (h >> np.uint32(13))
    h = h * _M3
    h = h ^ (h >> np.uint32(16))
    return h


def mix_hash(prev_hash: jnp.ndarray, payload: jnp.ndarray,
             nonce: jnp.ndarray) -> jnp.ndarray:
    """uint32 hash of (prev_hash, payload, nonce); broadcasts over nonce."""
    h = prev_hash.astype(jnp.uint32) * _M1
    h = _avalanche(h ^ payload.astype(jnp.uint32))
    h = _avalanche(h ^ nonce.astype(jnp.uint32))
    return h


def client_salt(client_id) -> jnp.ndarray:
    """Per-client payload salt defining the disjoint nonce spaces of the
    blockchain race (paper §3.1 Step 3). ONE definition shared by
    :func:`pow_search` and the Pallas grid path (``kernels/pow_hash``) — the
    bitwise ledger contract depends on both paths salting identically.
    Broadcasts over a vector of client ids."""
    return _avalanche(jnp.asarray(client_id, jnp.uint32) * _M2)


# Initial accumulator of the per-leaf digest fold (golden-ratio constant).
DIGEST_INIT = np.uint32(0x9E3779B9)


def fold_digest(acc: jnp.ndarray, leaf_sum: jnp.ndarray) -> jnp.ndarray:
    """Fold one leaf's fp32 sum into the running uint32 digest accumulator —
    the per-leaf step of :func:`digest_tree`, shared with the fused
    digest+divergence sweep (``kernels/fedavg``) so the fold itself cannot
    drift between the jnp and kernel paths (their digests still differ
    whenever the leaf SUMS are associated differently)."""
    bits = jax.lax.bitcast_convert_type(
        jnp.asarray(leaf_sum, jnp.float32), jnp.uint32)
    return _avalanche(acc ^ bits)


def digest_tree(tree, axis_name=None) -> jnp.ndarray:
    """Cheap uint32 digest of a pytree of arrays (model fingerprint for the
    block header). Deterministic, differentiation-free.

    With ``axis_name`` (inside ``shard_map``, fast-allreduce mode) the tree
    holds only this shard's client rows and each per-leaf sum is finished
    with a ``lax.psum`` — no full-axis gather, but the reassociated fp32 sum
    means the digest (and every downstream ledger hash) FORKS from the
    bitwise engine's value. The default ``axis_name=None`` full-width sum is
    the bitwise-contract path."""
    leaves = jax.tree.leaves(tree)
    acc = jnp.uint32(DIGEST_INIT)
    for leaf in leaves:
        x = leaf
        s = jnp.asarray(
            jnp.sum(x.astype(jnp.float32)) if jnp.issubdtype(x.dtype, jnp.floating)
            else jnp.sum(x.astype(jnp.int32)).astype(jnp.float32))
        if axis_name is not None:
            s = jax.lax.psum(s, axis_name)
        acc = fold_digest(acc, s)
    return acc


def pow_search(prev_hash: jnp.ndarray, payload: jnp.ndarray, client_id: jnp.ndarray,
               n_attempts: int, nonce_offset: jnp.ndarray | int = 0,
               chunk: int = 1024) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Search ``n_attempts`` nonces; return (best_hash, best_nonce).

    Each client salts its nonce space with its id (disjoint search — the
    blockchain race). Runs in fixed-size chunks via fori_loop so the HLO and
    memory stay O(chunk) regardless of the calibrated mining budget. When
    ``n_attempts % chunk != 0`` the tail chunk is masked so exactly
    ``n_attempts`` nonces are charged against the eq.-1 computing budget.
    """
    n_attempts = int(n_attempts)
    chunk = min(chunk, n_attempts)
    n_chunks = -(-n_attempts // chunk)
    salt = client_salt(client_id)
    base = jnp.asarray(nonce_offset, jnp.uint32)

    def body(i, best):
        best_h, best_n = best
        attempt_idx = jnp.uint32(i) * jnp.uint32(chunk) + jnp.arange(chunk, dtype=jnp.uint32)
        nonces = base + attempt_idx
        hs = mix_hash(prev_hash, payload ^ salt, nonces)
        hs = jnp.where(attempt_idx < jnp.uint32(n_attempts), hs,
                       jnp.uint32(0xFFFFFFFF))
        idx = jnp.argmin(hs)
        h, n = hs[idx], nonces[idx]
        take = h < best_h
        return (jnp.where(take, h, best_h), jnp.where(take, n, best_n))

    init = (jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
    return jax.lax.fori_loop(0, n_chunks, body, init)


def difficulty_threshold(difficulty_bits: int) -> jnp.ndarray:
    """Hash must be below this to 'solve' the block."""
    return jnp.uint32(0xFFFFFFFF >> difficulty_bits)


def winner_of(best_hashes: jnp.ndarray) -> jnp.ndarray:
    """argmin over the client axis = first solver in the race."""
    return jnp.argmin(best_hashes)
