"""Byzantine attack stages (beyond-paper: the §5 lazy-client model is the
mildest point on the adversarial spectrum; arXiv:2012.02044 and the
blockchained-FL survey arXiv:2110.02182 frame Byzantine updates + robust
aggregation as the defining robustness axis for decentralized FL).

An :class:`Attack` is a pure keyed transform on the pre-broadcast params —
the full ``[C, ...]`` client-stacked tree every client is about to publish
to the chain. The adversary controls the first ``n_attackers`` clients
(same first-M convention as ``core/lazy.py``), is omniscient (it sees every
honest broadcast before choosing its own, the strongest standard threat
model), and replaces only its own rows. Honest rows pass through bitwise
untouched, so ``n_attackers == 0`` degenerates to the identity and the
baseline results are unchanged.

Shipped attacks (each a frozen dataclass, hashable so it can live on the
hashable ``RoundSpec``):

  :class:`SignFlip`          broadcast ``-scale * w_i`` — the classic
                             direction-reversing Byzantine update.
  :class:`ScaledNoise`       broadcast ``scale * w_i + N(0, sigma2)`` —
                             keyed Gaussian garbage, the only stochastic
                             attack (draws fold from the round's attack
                             key, identical on every shard).
  :class:`ALIE`              "A Little Is Enough": broadcast
                             ``mu_honest - z * sd_honest`` per coordinate —
                             stays inside the honest variance envelope, so
                             it evades norm/distance outlier detection
                             while still biasing the mean.
  :class:`ModelReplacement`  deviation boosting ``mu + boost*(w_i - mu)``
                             (boost defaults to C): under the linear mean a
                             single attacker substitutes its own model for
                             the aggregate, the backdoor-insertion scaling.

``rounds.make_attack`` composes the selected attack into the round as a
stage right after ``perturb``: sharded it all-gathers the client axis (or
reuses the perturb stage's gather), applies the IDENTICAL full-``[C, ...]``
transform, and slices the local rows back out — the same discipline that
keeps the sharded engine bitwise with the single-device scan. The digest /
detection / mix all run on the post-attack broadcast set, exactly what a
real adversary publishes to the chain.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Attack:
    """Base: which clients the adversary controls. Subclasses implement
    ``apply(full, key, n_clients) -> full`` on the gathered broadcast set."""
    n_attackers: int = 1

    @property
    def active(self) -> bool:
        return self.n_attackers > 0

    def _validate(self, n_clients: int) -> None:
        if not 0 <= self.n_attackers < n_clients:
            raise ValueError(
                f"n_attackers={self.n_attackers} must leave at least one "
                f"honest client (n_clients={n_clients})")

    def _mask(self, n_clients: int, leaf) -> jnp.ndarray:
        sel = jnp.arange(n_clients) < self.n_attackers
        return sel.reshape((n_clients,) + (1,) * (leaf.ndim - 1))

    def apply(self, full, key, n_clients: int):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class SignFlip(Attack):
    """Attacker ``i`` broadcasts ``-scale * w_i``."""
    scale: float = 1.0

    def apply(self, full, key, n_clients: int):
        self._validate(n_clients)

        def one(leaf):
            flipped = (-jnp.float32(self.scale)
                       * leaf.astype(jnp.float32)).astype(leaf.dtype)
            return jnp.where(self._mask(n_clients, leaf), flipped, leaf)

        return jax.tree.map(one, full)


@dataclasses.dataclass(frozen=True)
class ScaledNoise(Attack):
    """Attacker ``i`` broadcasts ``scale * w_i + N(0, sigma2)``. The noise
    draws fold from the round's attack key with the same per-leaf split as
    ``lazy.apply_lazy`` — full-``[C, ...]`` shapes, so the sharded and
    single-device engines draw bitwise-identical noise."""
    scale: float = 1.0
    sigma2: float = 1.0

    def apply(self, full, key, n_clients: int):
        self._validate(n_clients)
        std = float(self.sigma2) ** 0.5
        leaves, treedef = jax.tree.flatten(full)
        keys = jax.random.split(key, len(leaves))

        def one(leaf, k):
            bad = jnp.float32(self.scale) * leaf.astype(jnp.float32)
            if std > 0.0:
                bad = bad + jax.random.normal(k, leaf.shape,
                                              jnp.float32) * std
            return jnp.where(self._mask(n_clients, leaf),
                             bad.astype(leaf.dtype), leaf)

        return jax.tree.unflatten(
            treedef, [one(leaf, k) for leaf, k in zip(leaves, keys)])


@dataclasses.dataclass(frozen=True)
class ALIE(Attack):
    """"A Little Is Enough" (Baruch et al.): every attacker broadcasts the
    per-coordinate ``mu_honest - z * sd_honest`` — inside the honest
    variance envelope (undetectable by distance/norm outlier tests for
    moderate ``z``) yet biasing every coordinate of the linear mean by
    ``(m/C) * z * sd``. Omniscient: the honest statistics are computed from
    the honest rows of the very broadcast set being attacked."""
    z: float = 1.5

    def apply(self, full, key, n_clients: int):
        self._validate(n_clients)
        m = self.n_attackers

        def one(leaf):
            honest = leaf[m:].astype(jnp.float32)       # static slice
            mu = jnp.mean(honest, axis=0)
            sd = jnp.std(honest, axis=0)
            bad = jnp.broadcast_to(mu - jnp.float32(self.z) * sd, leaf.shape)
            return jnp.where(self._mask(n_clients, leaf),
                             bad.astype(leaf.dtype), leaf)

        return jax.tree.map(one, full)


@dataclasses.dataclass(frozen=True)
class ModelReplacement(Attack):
    """Deviation boosting / model replacement: attacker ``i`` broadcasts
    ``mu_all + boost * (w_i - mu_all)``. With the default ``boost = C`` a
    single attacker makes the linear mean land (approximately) on its own
    model — the classic backdoor-insertion scaling."""
    boost: float = 0.0   # 0.0 -> n_clients at apply time

    def apply(self, full, key, n_clients: int):
        self._validate(n_clients)
        boost = float(self.boost) if self.boost else float(n_clients)

        def one(leaf):
            f32 = leaf.astype(jnp.float32)
            mu = jnp.mean(f32, axis=0)
            bad = mu + jnp.float32(boost) * (f32 - mu)
            return jnp.where(self._mask(n_clients, leaf),
                             bad.astype(leaf.dtype), leaf)

        return jax.tree.map(one, full)


def from_name(name: str, n_attackers: int = 1) -> Attack:
    """Parse a CLI-friendly attack spec (``launch/train --attack``).

    ``signflip[:scale]`` | ``noise[:sigma2[:scale]]`` | ``alie[:z]`` |
    ``replace[:boost]`` — e.g. ``signflip:2``, ``noise:0.5``, ``alie:1.2``.

    >>> from_name("signflip", 2)
    SignFlip(n_attackers=2, scale=1.0)
    >>> from_name("alie:1.2").z
    1.2
    >>> from_name("replace").boost
    0.0
    """
    head, _, arg = name.strip().lower().partition(":")
    m = int(n_attackers)
    if head in ("signflip", "sign_flip", "sign"):
        return SignFlip(n_attackers=m, scale=float(arg) if arg else 1.0)
    if head in ("noise", "scalednoise", "scaled_noise", "gauss"):
        sigma2, _, scale = arg.partition(":")
        return ScaledNoise(n_attackers=m,
                           sigma2=float(sigma2) if sigma2 else 1.0,
                           scale=float(scale) if scale else 1.0)
    if head == "alie":
        return ALIE(n_attackers=m, z=float(arg) if arg else 1.5)
    if head in ("replace", "replacement", "model_replacement", "boost"):
        return ModelReplacement(n_attackers=m,
                                boost=float(arg) if arg else 0.0)
    raise ValueError(f"unknown attack {name!r} (expected signflip[:scale] | "
                     "noise[:sigma2[:scale]] | alie[:z] | replace[:boost])")
