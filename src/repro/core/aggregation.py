"""Decentralized model aggregation (paper §3.1 Steps 2+5).

In BLADE-FL every client broadcasts its model and every client computes the
same aggregate — on a TPU mesh with the client axis sharded over 'data'
(x 'pod'), the broadcast+aggregate pair is exactly one all-reduce (mean over
the leading client axis, re-broadcast to every client slot). The fixed point
is identical to N gossip broadcasts; the ICI ring plays the gossip network.

``aggregate`` is the pure-jnp path; ``repro.kernels.fedavg`` provides the
fused Pallas kernel (aggregate + DP/lazy noise in one VMEM pass) selected by
``use_kernel=True``.

Mesh lowerings (the ``mix_*`` family)
-------------------------------------

Every ``mix_*`` function takes an optional ``axis_name``. With
``axis_name=None`` it is the plain device-local math; with a mesh axis name
(or tuple of names) it is the same computation expressed with collectives,
meant to run inside ``shard_map`` with the client axis sharded over that
axis. The engine (``core/rounds``) picks the lowering through the
:class:`repro.core.topology.MixLowering` each ``Topology`` advertises:

  ``mix_all_reduce``      FullMesh — one weighted all-reduce over the client
                          axis (all-gather + replicated reduce).
  ``mix_neighbor_halo``   Ring — two neighbor ``collective_permute``s build a
                          halo; each client window-averages locally.
  ``mix_gather``          general / sparse ``W`` — masked gather fallback:
                          all-gather the broadcast set, apply the dense
                          mixing matrix, keep the local rows.

Bit-for-bit contract: the sharded path of each lowering reproduces its dense
path EXACTLY, not just to float tolerance. Cross-client fp32 reductions are
therefore never computed as a psum of per-shard partial sums (that reorders
the fp32 association and would change the model digest, breaking the hash
chain) — instead the full client axis is materialized (all-gather is itself
a permute pattern on the ICI ring) and the reduction runs replicated with
the identical HLO the single-device engine executes. The neighbor-halo path
accumulates offsets in the same fixed order as its dense roll-based twin, so
it too is bitwise stable. A true psum would move ~C/D× less data for the
full mesh; it is deliberately not the default — the hash-linked ledger is
the ground truth the sharded engine must reproduce.

The opt-in fast tier (``mix_psum`` / ``mix_psum_dense``)
--------------------------------------------------------

``RoundSpec.fast_allreduce=True`` trades the bitwise contract for exactly
that saved data movement:

  ``mix_psum``        rank-1 (uniform-row) mixes — FullMesh and any
                      ``W = 1 rᵀ``: each shard pre-weights its local client
                      rows, ONE model-sized ``lax.psum`` produces the shared
                      aggregate, every client adopts it. O(1) models moved
                      per device instead of O(C).
  ``mix_psum_dense``  any dense ``W``: each shard contracts its local client
                      block against its column block of ``W`` and psums the
                      ``[C, ...]`` partial products (the SUMMA-style variant
                      the bitwise tier refuses) — same O(C) volume as the
                      gather but no materialized full client axis, and the
                      reduce can ride the ICI all-reduce lanes.

Both reassociate the cross-client fp32 reduction, so their results agree
with the gathered paths only to float tolerance (rtol ≈ 1e-5 over a K-round
run) and the model digest — hence every downstream ledger hash — forks from
the bitwise engine's chain. That is the tolerance equivalence tier:
``tests/equivalence.py`` holds the assertion helpers,
``tests/test_fast_allreduce.py`` pins psum-vs-gather agreement, and
docs/architecture.md §The tolerance tier documents the contract.

Robust consensus reducers (``mix_median`` / ``mix_trimmed`` /
``mix_geomedian``)
------------------------------------------------------------------

Byzantine-tolerant alternatives to the linear mix family, selected via
``RoundSpec.robust_agg`` (docs/architecture.md §Robust aggregation):
coordinate-wise median, coordinate-wise trimmed mean, and a
fixed-iteration Weiszfeld geometric median — all vectorized inside the
scan, all lowering onto the mesh as all-gather + replicated order
statistics (robust reductions are not psum-associative, so they live in
the tolerance tier; see the section comment at their definitions).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

AxisName = Union[str, Tuple[str, ...], None]


def fedavg(params, weights: Optional[jnp.ndarray] = None):
    """Mean (optionally weighted by |D_i|) over leading client axis C,
    broadcast back to every client: returns same-shaped pytree.

    >>> import jax.numpy as jnp
    >>> out = fedavg({"w": jnp.array([[0.0], [2.0], [4.0]])})
    >>> [float(v) for v in out["w"].ravel()]
    [2.0, 2.0, 2.0]
    """

    def one(leaf):
        if weights is None:
            agg = jnp.mean(leaf.astype(jnp.float32), axis=0)
        else:
            w = (weights / jnp.sum(weights)).astype(jnp.float32)
            agg = jnp.tensordot(w, leaf.astype(jnp.float32), axes=(0, 0))
        return jnp.broadcast_to(agg, leaf.shape).astype(leaf.dtype)

    return jax.tree.map(one, params)


def _reweight_rows(W: jnp.ndarray,
                   weights: Optional[jnp.ndarray]) -> jnp.ndarray:
    """|D_i| row reweighting shared by every dense mix path:
    ``W'[i, j] ∝ W[i, j] * weights[j]``, renormalized per row. One helper so
    the bitwise ``mix`` and the psum fast tier cannot drift apart."""
    W = jnp.asarray(W, jnp.float32)
    if weights is None:
        return W
    W = W * jnp.asarray(weights, jnp.float32)[None, :]
    return W / jnp.sum(W, axis=1, keepdims=True)


def mix(params, W: jnp.ndarray, weights: Optional[jnp.ndarray] = None):
    """Generalized Steps 2+5: client i adopts ``sum_j W[i, j] * params_j``.

    ``W [C, C]`` is a row-stochastic mixing matrix from ``core.topology``
    (full mesh ``11^T/C`` recovers ``fedavg`` up to float association order;
    the identity matrix is a no-communication round). Optional ``weights``
    (|D_i| data sizes) reweight each row's contributions —
    ``W'[i, j] ∝ W[i, j] * weights[j]``, renormalized per row — so the
    full-mesh W with weights equals weighted ``fedavg``. Accumulation is in
    float32; each leaf round-trips back to its own dtype.
    """
    W = _reweight_rows(W, weights)

    def one(leaf):
        flat = leaf.astype(jnp.float32).reshape((leaf.shape[0], -1))
        return (W @ flat).reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree.map(one, params)


def aggregate_once(params, weights: Optional[jnp.ndarray] = None):
    """Mean over client axis WITHOUT re-broadcast (single global model)."""

    def one(leaf):
        if weights is None:
            return jnp.mean(leaf.astype(jnp.float32), axis=0).astype(leaf.dtype)
        w = (weights / jnp.sum(weights)).astype(jnp.float32)
        return jnp.tensordot(w, leaf.astype(jnp.float32), axes=(0, 0)).astype(leaf.dtype)

    return jax.tree.map(one, params)


def replicate(params, n_clients: int):
    """Lift a single model to the client axis (round-0 initialization)."""
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_clients,) + a.shape), params)


# ---------------------------------------------------------------------------
# Client-axis collectives (shard_map helpers)
# ---------------------------------------------------------------------------


def _axis_tuple(axis_name: AxisName) -> Tuple[str, ...]:
    return (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)


def client_all_gather(tree, axis_name: AxisName):
    """Materialize the full client axis on every shard.

    Identity-plus-barrier when ``axis_name`` is None (single-device: the
    tree already holds all C clients). Inside ``shard_map`` this turns every
    ``[C/D, ...]`` leaf into the full ``[C, ...]`` leaf, concatenated in
    shard order — so the result is bitwise identical to the array the
    single-device engine holds.

    The ``optimization_barrier`` (applied in BOTH modes) is load-bearing for
    the bitwise contract: downstream full reductions to a scalar (the model
    digest's per-leaf sum, the per-client ``global_loss``/``local_loss``
    vectors the drivers ``np.mean`` on host, the
    divergence diagnostic) are vectorized by XLA:CPU with lane-partial
    accumulators whose association can change with the fusion context. The
    barrier pins the reduction input to a materialized buffer in the sharded
    and single-device programs alike, so both emit the identical standalone
    reduce. Axis-0-only reductions (``fedavg``'s mean, the mix matmul) keep
    a fixed per-column order regardless and don't need this.
    """
    if axis_name is None:
        return jax.lax.optimization_barrier(tree)
    gathered = jax.tree.map(
        lambda x: jax.lax.all_gather(x, axis_name, axis=0, tiled=True), tree)
    return jax.lax.optimization_barrier(gathered)


def client_shard_index(axis_name: AxisName) -> jnp.ndarray:
    """Linear index of this shard along the (possibly compound) client axis,
    matching the order ``all_gather(..., tiled=True)`` concatenates shards."""
    idx = jnp.int32(0)
    for name in _axis_tuple(axis_name):
        idx = idx * jax.lax.psum(1, name) + jax.lax.axis_index(name)
    return idx


def client_local_rows(full_tree, axis_name: AxisName, n_shards: int):
    """Slice this shard's block of clients back out of full ``[C, ...]``
    leaves (inverse of :func:`client_all_gather`). Identity when
    ``axis_name`` is None or ``n_shards == 1`` outside ``shard_map``."""
    if axis_name is None:
        return full_tree
    idx = client_shard_index(axis_name)

    def one(leaf):
        local = leaf.shape[0] // n_shards
        return jax.lax.dynamic_slice_in_dim(leaf, idx * local, local, axis=0)

    return jax.tree.map(one, full_tree)


# ---------------------------------------------------------------------------
# Topology-keyed mix lowerings (see module docstring for the bitwise contract)
# ---------------------------------------------------------------------------


def mix_all_reduce(params, weights: Optional[jnp.ndarray] = None, *,
                   axis_name: AxisName = None, n_shards: int = 1, full=None):
    """FullMesh lowering: one weighted all-reduce over the client axis.

    Dense (``axis_name=None``) this IS :func:`fedavg`. Sharded, the
    all-reduce is realized gather-side — all-gather the client axis (pass a
    pre-gathered ``full`` tree to reuse the communicate stage's gather),
    run the IDENTICAL :func:`fedavg` replicated on every shard, and keep
    the local client block — so the result matches the single-device
    ``fedavg`` bit for bit (one shared implementation, nothing to drift).
    """
    if axis_name is None:
        return fedavg(params, weights)
    full = client_all_gather(params, axis_name) if full is None else full
    return client_local_rows(fedavg(full, weights), axis_name, n_shards)


def mix_rolls(params, offsets: Sequence[int], weight: float):
    """Dense twin of the neighbor-halo lowering: client ``i`` adopts
    ``weight * sum_off params[(i + off) % C]`` with the offsets accumulated
    in the given (fixed) order. For ``Ring(k)`` with window ``2k+1 <= C``
    this equals ``mix(params, Ring(k).matrix(C))`` up to fp32 association —
    the roll form is the canonical one because the halo path can reproduce
    it bitwise with two ``collective_permute``s.

    The window sum accumulates RAW terms and scales by ``weight`` once at
    the end: a per-term ``acc + w * x`` chain invites XLA to contract the
    multiply into an FMA, and whether it does varies with fusion context —
    exactly the last-ulp drift the bitwise contract forbids. Plain add
    chains have no multiply to contract, so dense and halo stay stable.

    >>> import jax.numpy as jnp
    >>> p = {"w": jnp.arange(4.0).reshape(4, 1)}
    >>> out = mix_rolls(p, offsets=(-1, 0, 1), weight=1.0 / 3.0)
    >>> [round(float(v), 4) for v in out["w"].ravel()]
    [1.3333, 1.0, 2.0, 1.6667]
    """
    w = jnp.float32(weight)

    def one(leaf):
        x = leaf.astype(jnp.float32)
        acc = jnp.roll(x, -offsets[0], axis=0)
        for off in offsets[1:]:
            acc = acc + jnp.roll(x, -off, axis=0)
        return (acc * w).astype(leaf.dtype)

    return jax.tree.map(one, params)


def _linear_axis(axis_name: AxisName):
    """``(ppermute target, total extent)`` for a possibly-compound client
    axis: the shard index linearizes row-major over the axis tuple
    (``idx = idx * extent + axis_index`` per name — the same order
    :func:`client_shard_index` computes and ``all_gather(..., tiled=True)``
    concatenates), so a multi-axis ``('pod', 'data')`` mesh permutes like a
    single flat axis of ``n_pod * n_data`` devices. Extents fold to concrete
    Python ints under ``shard_map``, so the permute lists stay static."""
    names = _axis_tuple(axis_name)
    n_dev = 1
    for nm in names:
        n_dev *= jax.lax.psum(1, nm)
    return (names[0] if len(names) == 1 else names), n_dev


def mix_neighbor_halo(params, offsets: Sequence[int], weight: float,
                      axis_name: AxisName):
    """Ring lowering on the mesh: neighbor ``collective_permute``s.

    Each shard exchanges its client block with its two ring neighbors (one
    ``ppermute`` per direction), assembles the ``[3·C/D, ...]`` halo, and
    window-averages its own clients locally — communication is
    O(window), independent of C, versus the all-gather fallback's O(C).
    Accumulation order and fp32 math match :func:`mix_rolls` exactly, so
    dense and sharded Ring mixes are bitwise identical. Requires
    ``max(|off|) <= C/D`` (one-block halo). A compound client axis
    (``('pod', 'data')``) is linearized row-major (:func:`_linear_axis`) —
    the ring's cross-pod wrap is just one more permute edge, no gather.
    """
    if axis_name is None:
        return mix_rolls(params, offsets, weight)
    name, n_dev = _linear_axis(axis_name)
    fwd = [((j + 1) % n_dev, j) for j in range(n_dev)]   # nxt[j] = block j+1
    bwd = [((j - 1) % n_dev, j) for j in range(n_dev)]   # prv[j] = block j-1
    w = jnp.float32(weight)

    def one(leaf):
        x = leaf.astype(jnp.float32)
        local = x.shape[0]
        nxt = jax.lax.ppermute(x, name, fwd)
        prv = jax.lax.ppermute(x, name, bwd)
        ext = jnp.concatenate([prv, x, nxt], axis=0)     # rows -local..2·local
        # raw-sum-then-scale, mirroring mix_rolls (FMA-contraction safety)
        acc = jax.lax.dynamic_slice_in_dim(
            ext, local + offsets[0], local, axis=0)
        for off in offsets[1:]:
            acc = acc + jax.lax.dynamic_slice_in_dim(
                ext, local + off, local, axis=0)
        return (acc * w).astype(leaf.dtype)

    return jax.tree.map(one, params)


def mix_shift_halo(params, offsets: Sequence[int], weight: float,
                   axis_name: AxisName):
    """Arbitrary-shift generalization of :func:`mix_neighbor_halo`.

    Client ``i`` adopts ``weight * sum_off params[(i + off) % C]`` for any
    static offsets — not just offsets inside one neighbor block. Each offset
    ``s`` decomposes as ``s = q * L + m`` over the per-shard block size
    ``L``: the rows client ``i`` needs live in the blocks of devices
    ``d + q`` and ``d + q + 1``, so the lowering is (at most) two
    whole-block ``ppermute``s plus a static slice per offset — O(1) blocks
    moved per offset, independent of C, which is what lets a gossip
    *rotation* keep its one-partner communication volume on the mesh.

    Bitwise contract: pure data movement plus the same fixed-order
    raw-sum-then-scale accumulation as :func:`mix_rolls`, so the sharded
    result equals the dense roll form bit for bit. A compound client axis
    is linearized row-major (:func:`_linear_axis`) — shifts that cross pod
    boundaries or wrap the whole population stay two whole-block permutes;
    with ``axis_name=None`` it IS :func:`mix_rolls`.
    """
    if axis_name is None:
        return mix_rolls(params, offsets, weight)
    name, n_dev = _linear_axis(axis_name)
    w = jnp.float32(weight)

    def block_from(x, q):
        q = q % n_dev
        if q == 0:
            return x
        # dest d receives the block of source (d + q) % D
        perm = [(j, (j - q) % n_dev) for j in range(n_dev)]
        return jax.lax.ppermute(x, name, perm)

    def rows_at(x, s):
        local = x.shape[0]
        q, m = divmod(s % (local * n_dev), local)
        if m == 0:
            return block_from(x, q)
        ext = jnp.concatenate([block_from(x, q), block_from(x, q + 1)], axis=0)
        return jax.lax.slice_in_dim(ext, m, m + local, axis=0)

    def one(leaf):
        x = leaf.astype(jnp.float32)
        acc = rows_at(x, offsets[0])
        for off in offsets[1:]:
            acc = acc + rows_at(x, off)
        return (acc * w).astype(leaf.dtype)

    return jax.tree.map(one, params)


def _kernel_mix_tree(params, w_rows, interpret):
    """Route a tree's leaf matmuls through the fused Pallas row-block kernel
    (``kernels.fedavg.mix_rows_flat``). Imported lazily so importing
    ``core.aggregation`` never pulls the pallas machinery (the dry-run
    imports this module before locking its device count)."""
    from repro.kernels.fedavg import ops as fedavg_ops
    return fedavg_ops.mix_rows_tree(params, w_rows, interpret=interpret)


def mix_gather(params, W: jnp.ndarray, weights: Optional[jnp.ndarray] = None,
               *, axis_name: AxisName = None, n_shards: int = 1, full=None,
               use_kernel: bool = False, interpret: Optional[bool] = None):
    """General/sparse-``W`` fallback: masked gather pattern.

    All-gather the broadcast set (a permute pattern on the ring; pass a
    pre-gathered ``full`` tree to reuse the communicate stage's gather),
    apply the dense row-stochastic mask ``W`` with the identical full-width
    matmul the single-device engine runs (bitwise equal — same HLO on the
    same ``[C, ...]`` input), and keep only this shard's client rows. A
    SUMMA-style permute-and-accumulate over shard blocks would halve peak
    memory but reorders the fp32 contraction, so it is not used.

    ``use_kernel=True`` (RoundSpec.fused_mix) contracts through the fused
    Pallas row-block kernel instead: the shard's ROW block of the reweighted
    ``W`` is sliced first and only the local output rows are ever computed —
    the weighted gather, matmul and local-row-select fuse into one kernel.
    Tolerance tier (the kernel's contraction order replaces XLA's), like the
    psum fast tier. ``interpret`` threads RoundSpec.kernel_interpret
    (None = interpret everywhere except real TPU backends).
    """
    if use_kernel:
        w_rows = _reweight_rows(W, weights)
        if axis_name is not None:
            full = client_all_gather(params, axis_name) if full is None \
                else full
            idx = client_shard_index(axis_name)
            local = w_rows.shape[0] // n_shards
            w_rows = jax.lax.dynamic_slice_in_dim(w_rows, idx * local, local,
                                                  axis=0)
            return _kernel_mix_tree(full, w_rows, interpret)
        return _kernel_mix_tree(params, w_rows, interpret)
    if axis_name is None:
        return mix(params, W, weights)
    full = client_all_gather(params, axis_name) if full is None else full
    mixed = mix(full, W, weights)
    return client_local_rows(mixed, axis_name, n_shards)


def mix_segment(params, neighbor_idx, edge_w, *, axis_name: AxisName = None,
                n_shards: int = 1, full=None):
    """Sparse-topology mix: neighbor gather + ``jax.ops.segment_sum``.

    ``neighbor_idx``/``edge_w`` are the FULL ``[C, D]`` edge-list form of the
    mixing matrix (``topology.SparseLowering``, padded to max degree ``D``
    with weight-0 self-edges): client ``i`` adopts
    ``sum_d edge_w[i, d] * params[neighbor_idx[i, d]]``. Work and the
    gathered working set are O(C·D) — for a topology whose degree is ≪ C
    this replaces the dense ``mix`` matmul's O(C²) row contraction, which is
    what lets cohort populations scale past toy C.

    Sharded, each shard slices its local ROW block of the edge lists (same
    shard-index slicing as ``mix_psum_dense``), gathers only the flattened
    neighbor rows it references out of the broadcast set (``full`` reuses
    the communicate stage's gather), and segment-sums into its own
    ``C/D_shards`` outputs — no cross-shard reduction at all, so unlike the
    psum tier there is no partial-sum reassociation: each output row's sum
    runs in the same ascending-neighbor order on every shard layout. Like
    every mix, accumulation is fp32 with a round-trip to the leaf dtype.

    Association caveat: XLA's scatter-add (`segment_sum`) does not promise
    the dense matmul's contraction order, so sparse-vs-dense agreement is
    pinned at the TOLERANCE tier (tests/test_sparse_mix.py); sharded-vs-
    single-device sparse agreement is bitwise (identical per-row segment
    reductions either way).

    >>> import jax.numpy as jnp
    >>> p = {"w": jnp.arange(3.0).reshape(3, 1)}
    >>> idx = jnp.array([[0, 1], [0, 1], [2, 2]])
    >>> ew = jnp.array([[0.5, 0.5], [0.5, 0.5], [1.0, 0.0]])
    >>> [float(v) for v in mix_segment(p, idx, ew)["w"].ravel()]
    [0.5, 0.5, 2.0]
    """
    idx_full = jnp.asarray(neighbor_idx, jnp.int32)
    w_full = jnp.asarray(edge_w, jnp.float32)
    c, d = idx_full.shape
    if axis_name is None:
        source = params if full is None else full
        idx_loc, w_loc = idx_full, w_full
        n_rows = c
    else:
        source = client_all_gather(params, axis_name) if full is None \
            else full
        shard = client_shard_index(axis_name)
        n_rows = c // n_shards
        idx_loc = jax.lax.dynamic_slice_in_dim(idx_full, shard * n_rows,
                                               n_rows, axis=0)
        w_loc = jax.lax.dynamic_slice_in_dim(w_full, shard * n_rows,
                                             n_rows, axis=0)
    seg_ids = jnp.repeat(jnp.arange(n_rows, dtype=jnp.int32), d)
    src_rows = idx_loc.reshape(-1)
    w_flat = w_loc.reshape(-1)

    def one(p_leaf, s_leaf):
        flat = s_leaf.astype(jnp.float32).reshape((s_leaf.shape[0], -1))
        gathered = jnp.take(flat, src_rows, axis=0)       # [n_rows·D, F]
        mixed = jax.ops.segment_sum(gathered * w_flat[:, None], seg_ids,
                                    num_segments=n_rows)
        return mixed.reshape(p_leaf.shape).astype(p_leaf.dtype)

    return jax.tree.map(one, params, source)


def mix_cluster(params, n_clusters: int, inter_weight: float,
                axis_name: AxisName = None, *, n_shards: int = 1,
                full=None):
    """Two-level ``ClusterTopology`` mix: intra-cluster mean + ring-coupled
    cluster means (``W = B ⊗ J_S/S``; see ``topology.ClusterTopology``).

    Dense (``axis_name=None``): reshape ``[C, ...]`` to ``[G, S, ...]``,
    reduce each cluster to its mean (raw-sum-then-scale, FMA safety), roll
    the means one step each way, and recombine ``[w_self, w_nbr, w_nbr]``
    against the stacked ``[self, prev, next]`` terms as ONE ``dot_general``.
    The dot is the load-bearing choice: scaled adds get FMA-contracted
    differently per fusion context (``optimization_barrier`` does NOT block
    contraction) and the bits fork between the dense and sharded programs,
    while a dot has a single deterministic lowering everywhere — the same
    reason ``mix_gather``/``mix_psum_dense`` combine via matmul. Every
    client in a cluster broadcasts the same mixed mean, so the result is
    exactly rank-G.

    Cluster-aligned sharded path — a two-axis client mesh whose FIRST axis
    extent equals ``n_clusters`` (the ``('pod', 'data')`` layout
    ``sharding.plans.scan_carry_plan`` produces): the cluster sum is an
    in-pod ``all_gather`` over the second axis (``S`` rows, never leaves the
    pod) reduced with the same ``[1, S, ...]`` sum structure as the dense
    ``[G, S, ...]`` reduce, and the roll becomes TWO model-sized cross-pod
    ``ppermute``s of the cluster mean — O(S + 2) models moved versus the
    flat gather's O(C), and still bitwise (same sums, same combine order;
    no psum anywhere).

    Any other layout (single axis, pod extent != G) falls back to the
    gathered dense math + local-rows slice — bitwise by construction, the
    alignment only buys communication volume.

    >>> import jax.numpy as jnp
    >>> p = {"w": jnp.arange(4.0).reshape(4, 1)}
    >>> out = mix_cluster(p, n_clusters=2, inter_weight=0.5)
    >>> [float(v) for v in out["w"].ravel()]
    [1.5, 1.5, 1.5, 1.5]
    >>> out = mix_cluster(p, n_clusters=2, inter_weight=0.0)
    >>> [float(v) for v in out["w"].ravel()]
    [0.5, 0.5, 2.5, 2.5]
    """
    g = int(n_clusters)
    w_row = jnp.array([1.0 - inter_weight, inter_weight / 2.0,
                       inter_weight / 2.0], jnp.float32)

    def combine(m, prv, nxt):
        # one dot_general, never scaled adds: see the docstring's FMA note
        return jnp.tensordot(w_row, jnp.stack([m, prv, nxt], axis=0), axes=1)

    def dense(tree):
        def one(leaf):
            x = leaf.astype(jnp.float32)
            s = x.shape[0] // g
            grp = x.reshape((g, s) + x.shape[1:])
            # one [1, S, ...] reduce PER CLUSTER — the exact operand shape
            # the aligned sharded path reduces, because XLA associates a
            # reduce differently for [G, S, ...] vs [1, S, ...] operands on
            # some leaf ranks and that forks the bits. The barrier pins the
            # scaled mean so the combine multiplies see the same value in
            # every fusion context.
            m = jnp.concatenate([
                grp[i:i + 1].sum(axis=1) for i in range(g)])  # [G, ...]
            m = jax.lax.optimization_barrier(m * jnp.float32(1.0 / s))
            out = combine(m, jnp.roll(m, 1, axis=0), jnp.roll(m, -1, axis=0))
            # pin the stage output: downstream consumers (next round's loss)
            # must see the same fusion boundary in both programs
            out = jax.lax.optimization_barrier(out)
            return jnp.broadcast_to(
                out[:, None], grp.shape).reshape(x.shape).astype(leaf.dtype)
        return jax.tree.map(one, tree)

    if axis_name is None:
        return dense(params)
    names = _axis_tuple(axis_name)
    aligned = len(names) == 2 and jax.lax.psum(1, names[0]) == g
    if not aligned:
        src = client_all_gather(params, axis_name) if full is None else full
        return client_local_rows(dense(src), axis_name, n_shards)
    pod_axis, data_axis = names
    fwd = [((j + 1) % g, j) for j in range(g)]   # nxt[p] = mean of pod p+1
    bwd = [((j - 1) % g, j) for j in range(g)]   # prv[p] = mean of pod p-1

    def one(leaf):
        x = leaf.astype(jnp.float32)
        blk = jax.lax.all_gather(x, data_axis, axis=0, tiled=True)
        blk = jax.lax.optimization_barrier(blk)   # in-pod rows: [S, ...]
        s = blk.shape[0]
        # [1, S, ...] sum(axis=1) mirrors the dense [G, S, ...] reduce
        # structure, so the cluster sum is bitwise the dense one; same
        # barrier pin on the scaled mean as the dense path
        m = jax.lax.optimization_barrier(
            blk.reshape((1, s) + blk.shape[1:]).sum(axis=1)[0]
            * jnp.float32(1.0 / s))
        nxt = jax.lax.ppermute(m, pod_axis, fwd)
        prv = jax.lax.ppermute(m, pod_axis, bwd)
        out = jax.lax.optimization_barrier(combine(m, prv, nxt))
        return jnp.broadcast_to(out[None], x.shape).astype(leaf.dtype)

    return jax.tree.map(one, params)


# ---------------------------------------------------------------------------
# Robust consensus reducers (Byzantine-tolerant alternatives to the linear
# mix; selected via RoundSpec.robust_agg -> topology.resolve_mix_plan)
# ---------------------------------------------------------------------------
#
# Each reducer maps the broadcast set [C, ...] to ONE aggregate that every
# client adopts (rank-1, like FullMesh) — a robust consensus primitive over
# the full broadcast set, deliberately independent of the round's topology
# matrix: a Byzantine row must be EXCLUDED per coordinate, not merely
# down-weighted, and the per-coordinate order statistics that do that are
# defined over the whole client axis. Breakdown points (max attackers
# tolerated): median and the Weiszfeld geometric median ⌊(C-1)/2⌋,
# trimmed(t) exactly t per tail — versus 0 for every linear mix, where one
# sign-flipping client corrupts all C models (tests/test_robust_mix.py pins
# both sides).
#
# Sharded, each lowers as all-gather + replicated per-coordinate order
# statistics over the full client axis + keep-local-rows — robust
# reductions are NOT psum-associative (a median of medians is not the
# median), so there is no partial-sum fast path and the family lives under
# the TOLERANCE equivalence tier (rtol ≈ 1e-5, tests/test_robust_mix.py)
# rather than the bitwise contract: sort/selection networks and the
# Weiszfeld reweighting are fusion-context-sensitive in ways the
# barrier-pinned linear reductions are not, and pinning every comparator is
# not worth freezing the implementation.


def robust_median(full_tree):
    """Coordinate-wise median over the leading client axis, broadcast back
    to every client slot (rank-1 aggregate).

    >>> import jax.numpy as jnp
    >>> out = robust_median({"w": jnp.array([[0.0], [1.0], [100.0]])})
    >>> [float(v) for v in out["w"].ravel()]
    [1.0, 1.0, 1.0]
    """

    def one(leaf):
        agg = jnp.median(leaf.astype(jnp.float32), axis=0)
        return jnp.broadcast_to(agg, leaf.shape).astype(leaf.dtype)

    return jax.tree.map(one, full_tree)


def robust_trimmed(full_tree, trim: int):
    """Coordinate-wise trimmed mean: sort each coordinate over the client
    axis, drop the ``trim`` smallest and ``trim`` largest values, average
    the surviving ``C - 2*trim``. ``trim=0`` is the plain mean (up to fp32
    association of the sorted sum — ULP-bound, tests/test_property.py).

    >>> import jax.numpy as jnp
    >>> out = robust_trimmed({"w": jnp.array([[0.0], [1.0], [2.0],
    ...                                       [1000.0]])}, trim=1)
    >>> [float(v) for v in out["w"].ravel()]
    [1.5, 1.5, 1.5, 1.5]
    """
    t = int(trim)

    def one(leaf):
        c = leaf.shape[0]
        if not 0 <= 2 * t < c:
            raise ValueError(f"trim={t} must satisfy 2*trim < C={c}")
        kept = jnp.sort(leaf.astype(jnp.float32), axis=0)[t:c - t]
        agg = jnp.sum(kept, axis=0) / jnp.float32(c - 2 * t)
        return jnp.broadcast_to(agg, leaf.shape).astype(leaf.dtype)

    return jax.tree.map(one, full_tree)


def robust_geomedian(full_tree, n_iters: int = 8, eps: float = 1e-6):
    """Geometric median of the flattened client models by Weiszfeld
    iteration with a STATIC iteration count — a fixed ``fori_loop``, so the
    reducer is jax-traceable and compiles into the scan with no per-round
    retrace (no data-dependent convergence test; ``n_iters`` in the 5-10
    range is ample at FL scales, and the eps floor guards the reweighting
    when the iterate lands on a client point).

    Unlike the coordinate-wise reducers this is a MODEL-space median: the
    minimizer of ``sum_i ||x_i - y||_2`` over the concatenated leaves,
    which no coordinate-wise attack can drag further than the honest
    diameter while a majority of clients is honest (breakdown ⌊(C-1)/2⌋).
    """
    leaves, treedef = jax.tree.flatten(full_tree)
    c = leaves[0].shape[0]
    flat = jnp.concatenate(
        [leaf.astype(jnp.float32).reshape(c, -1) for leaf in leaves], axis=1)

    def body(_, y):
        d = jnp.sqrt(jnp.sum((flat - y[None]) ** 2, axis=1))   # [C]
        w = 1.0 / jnp.maximum(d, jnp.float32(eps))
        w = w / jnp.sum(w)
        return jnp.tensordot(w, flat, axes=(0, 0))

    y = jax.lax.fori_loop(0, int(n_iters), body, jnp.mean(flat, axis=0))

    out, offset = [], 0
    for leaf in leaves:
        size = 1
        for d in leaf.shape[1:]:
            size *= int(d)
        agg = jax.lax.dynamic_slice_in_dim(y, offset, size, axis=0)
        offset += size
        out.append(jnp.broadcast_to(agg.reshape(leaf.shape[1:]),
                                    leaf.shape).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)


def _mix_robust(params, reduce_full, *, axis_name: AxisName, n_shards: int,
                full):
    """Shared mesh lowering of the robust family: gather the client axis
    (reusing the communicate stage's ``full`` when it already gathered),
    run the replicated full-width reducer, keep the local rows."""
    if axis_name is None:
        return reduce_full(params if full is None else full)
    full = client_all_gather(params, axis_name) if full is None else full
    return client_local_rows(reduce_full(full), axis_name, n_shards)


def mix_median(params, *, axis_name: AxisName = None, n_shards: int = 1,
               full=None):
    """Coordinate-wise-median mix (see :func:`robust_median`). Tolerance
    tier on the mesh — see the section comment above."""
    return _mix_robust(params, robust_median, axis_name=axis_name,
                       n_shards=n_shards, full=full)


def mix_trimmed(params, trim: int, *, axis_name: AxisName = None,
                n_shards: int = 1, full=None):
    """Trimmed-mean mix (see :func:`robust_trimmed`). Tolerance tier on the
    mesh — see the section comment above."""
    return _mix_robust(params, lambda t: robust_trimmed(t, trim),
                       axis_name=axis_name, n_shards=n_shards, full=full)


def mix_geomedian(params, n_iters: int = 8, *, eps: float = 1e-6,
                  axis_name: AxisName = None, n_shards: int = 1, full=None):
    """Weiszfeld geometric-median mix (see :func:`robust_geomedian`).
    Tolerance tier on the mesh — see the section comment above."""
    return _mix_robust(params,
                       lambda t: robust_geomedian(t, n_iters, eps=eps),
                       axis_name=axis_name, n_shards=n_shards, full=full)


# ---------------------------------------------------------------------------
# Opt-in psum fast tier (reassociates fp32 — tolerance tier, not bitwise)
# ---------------------------------------------------------------------------


def mix_psum(params, weights: Optional[jnp.ndarray] = None, *,
             axis_name: AxisName = None, n_shards: int = 1):
    """Rank-1 mix as a true in-mesh psum of locally pre-weighted rows.

    Every client adopts the same aggregate ``sum_j w_j x_j / sum_j w_j``
    (uniform ``w`` = ``fedavg``; ``weights`` may be the |D_i| data sizes, a
    uniform-row topology's shared row, or their product — any nonnegative
    per-client weighting). Sharded, each device contracts only its local
    client block and ONE model-sized ``lax.psum`` finishes the reduction —
    ~C/D× less data than the gather-side all-reduce, which is the whole
    point of ``RoundSpec.fast_allreduce``.

    NOT bitwise: the psum reassociates the cross-client fp32 sum (per-shard
    partials, backend-chosen reduction tree), so results agree with
    :func:`fedavg` / :func:`mix_all_reduce` only to float tolerance and the
    model digest forks. With ``axis_name=None`` it is the same
    sum-then-scale math without the collective (float-close to ``fedavg``,
    same association as the sharded form up to the psum tree).

    ``weights`` is always the FULL ``[C]`` vector; the local block is sliced
    by shard index, mirroring how params rows are laid out.

    >>> import jax.numpy as jnp
    >>> out = mix_psum({"w": jnp.array([[0.0], [2.0], [4.0]])})
    >>> [float(v) for v in out["w"].ravel()]
    [2.0, 2.0, 2.0]
    """
    denom = None
    w_local = None
    if weights is not None:
        w_full = jnp.asarray(weights, jnp.float32)
        denom = jnp.sum(w_full)
        if axis_name is None:
            w_local = w_full
        else:
            idx = client_shard_index(axis_name)
            local = w_full.shape[0] // n_shards
            w_local = jax.lax.dynamic_slice_in_dim(w_full, idx * local,
                                                   local, axis=0)

    def one(leaf):
        x = leaf.astype(jnp.float32)
        if weights is None:
            part = jnp.sum(x, axis=0)
        else:
            part = jnp.tensordot(w_local, x, axes=(0, 0))
        if axis_name is not None:
            part = jax.lax.psum(part, axis_name)
        if weights is None:
            n_total = x.shape[0] * (n_shards if axis_name is not None else 1)
            agg = part / jnp.float32(n_total)
        else:
            agg = part / denom
        return jnp.broadcast_to(agg, x.shape).astype(leaf.dtype)

    return jax.tree.map(one, params)


def mix_psum_dense(params, W: jnp.ndarray,
                   weights: Optional[jnp.ndarray] = None, *,
                   axis_name: AxisName = None, n_shards: int = 1,
                   use_kernel: bool = False,
                   interpret: Optional[bool] = None):
    """General-``W`` psum variant: local column-block matmul, then psum.

    Shard d holds client rows ``[d·L, (d+1)·L)``; it contracts them against
    its COLUMN block ``W[:, d·L:(d+1)·L]`` to produce the ``[C, ...]``
    partial products every output row owes to its clients, ``lax.psum``s the
    partials (the SUMMA-style accumulate the bitwise tier deliberately
    avoids), and keeps its own rows. Volume is O(C) like the gather, but no
    shard ever materializes the full client axis and the reduction rides
    the all-reduce lanes. ``W`` may be traced (stochastic topologies /
    schedule tables). ``weights`` (|D_i|) reweights rows exactly like
    :func:`mix`.

    NOT bitwise: the contraction is reassociated across shards (tolerance
    tier). With ``axis_name=None`` this IS :func:`mix` (or the fused kernel
    mix when ``use_kernel=True``, which routes the local column-block matmul
    through ``kernels.fedavg.mix_rows_flat``).
    """
    if axis_name is None:
        return mix_gather(params, W, weights, use_kernel=use_kernel,
                          interpret=interpret) if use_kernel \
            else mix(params, W, weights)
    W = _reweight_rows(W, weights)
    idx = client_shard_index(axis_name)
    local = W.shape[0] // n_shards
    w_cols = jax.lax.dynamic_slice_in_dim(W, idx * local, local, axis=1)
    if use_kernel:
        from repro.kernels.fedavg import ops as fedavg_ops
        if interpret is None:
            interpret = fedavg_ops._default_interpret()

    def one(leaf):
        flat = leaf.astype(jnp.float32).reshape((leaf.shape[0], -1))
        if use_kernel:
            from repro.kernels.fedavg.kernel import mix_rows_flat
            part = mix_rows_flat(w_cols, flat, interpret=interpret)
        else:
            part = w_cols @ flat                   # [C, F] partial products
        full = jax.lax.psum(part, axis_name)
        mine = jax.lax.dynamic_slice_in_dim(full, idx * local, local, axis=0)
        return mine.reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree.map(one, params)


def client_divergence_psum(params, axis_name: AxisName = None,
                           n_shards: int = 1) -> jnp.ndarray:
    """Tolerance-tier twin of :func:`client_divergence`: cross-shard
    reductions as psums of local partials instead of gathered full-width
    math, so the fast path never materializes the full client axis. Same
    quantity up to fp32 association."""
    scale = n_shards if axis_name is not None else 1

    def sq(leaf):
        x = leaf.astype(jnp.float32)
        s = jnp.sum(x, axis=0)
        if axis_name is not None:
            s = jax.lax.psum(s, axis_name)
        mean = s / jnp.float32(x.shape[0] * scale)
        return jnp.sum((x - mean) ** 2, axis=tuple(range(1, x.ndim)))

    total = sum(jax.tree.leaves(jax.tree.map(sq, params)))
    tsum = jnp.sum(total)
    if axis_name is not None:
        tsum = jax.lax.psum(tsum, axis_name)
    return jnp.sqrt(tsum / jnp.float32(total.shape[0] * scale))


def client_divergence(params) -> jnp.ndarray:
    """Mean pairwise L2 distance of client models from their average —
    diagnostic for the gradient-divergence delta of Definition 1."""
    def sq(leaf):
        mean = jnp.mean(leaf.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.sum((leaf.astype(jnp.float32) - mean) ** 2, axis=tuple(range(1, leaf.ndim)))
    total = sum(jax.tree.leaves(jax.tree.map(sq, params)))
    return jnp.sqrt(jnp.mean(total))
