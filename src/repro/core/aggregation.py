"""Decentralized model aggregation (paper §3.1 Steps 2+5).

In BLADE-FL every client broadcasts its model and every client computes the
same aggregate — on a TPU mesh with the client axis sharded over 'data'
(x 'pod'), the broadcast+aggregate pair is exactly one all-reduce (mean over
the leading client axis, re-broadcast to every client slot). The fixed point
is identical to N gossip broadcasts; the ICI ring plays the gossip network.

``aggregate`` is the pure-jnp path; ``repro.kernels.fedavg`` provides the
fused Pallas kernel (aggregate + DP/lazy noise in one VMEM pass) selected by
``use_kernel=True``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def fedavg(params, weights: Optional[jnp.ndarray] = None):
    """Mean (optionally weighted by |D_i|) over leading client axis C,
    broadcast back to every client: returns same-shaped pytree."""

    def one(leaf):
        c = leaf.shape[0]
        if weights is None:
            agg = jnp.mean(leaf.astype(jnp.float32), axis=0)
        else:
            w = (weights / jnp.sum(weights)).astype(jnp.float32)
            agg = jnp.tensordot(w, leaf.astype(jnp.float32), axes=(0, 0))
        return jnp.broadcast_to(agg, leaf.shape).astype(leaf.dtype)

    return jax.tree.map(one, params)


def mix(params, W: jnp.ndarray, weights: Optional[jnp.ndarray] = None):
    """Generalized Steps 2+5: client i adopts ``sum_j W[i, j] * params_j``.

    ``W [C, C]`` is a row-stochastic mixing matrix from ``core.topology``
    (full mesh ``11^T/C`` recovers ``fedavg`` up to float association order;
    the identity matrix is a no-communication round). Optional ``weights``
    (|D_i| data sizes) reweight each row's contributions —
    ``W'[i, j] ∝ W[i, j] * weights[j]``, renormalized per row — so the
    full-mesh W with weights equals weighted ``fedavg``. Accumulation is in
    float32; each leaf round-trips back to its own dtype.
    """
    W = jnp.asarray(W, jnp.float32)
    if weights is not None:
        W = W * jnp.asarray(weights, jnp.float32)[None, :]
        W = W / jnp.sum(W, axis=1, keepdims=True)

    def one(leaf):
        flat = leaf.astype(jnp.float32).reshape((leaf.shape[0], -1))
        return (W @ flat).reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree.map(one, params)


def aggregate_once(params, weights: Optional[jnp.ndarray] = None):
    """Mean over client axis WITHOUT re-broadcast (single global model)."""

    def one(leaf):
        if weights is None:
            return jnp.mean(leaf.astype(jnp.float32), axis=0).astype(leaf.dtype)
        w = (weights / jnp.sum(weights)).astype(jnp.float32)
        return jnp.tensordot(w, leaf.astype(jnp.float32), axes=(0, 0)).astype(leaf.dtype)

    return jax.tree.map(one, params)


def replicate(params, n_clients: int):
    """Lift a single model to the client axis (round-0 initialization)."""
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_clients,) + a.shape), params)


def client_divergence(params) -> jnp.ndarray:
    """Mean pairwise L2 distance of client models from their average —
    diagnostic for the gradient-divergence delta of Definition 1."""
    def sq(leaf):
        mean = jnp.mean(leaf.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.sum((leaf.astype(jnp.float32) - mean) ** 2, axis=tuple(range(1, leaf.ndim)))
    total = sum(jax.tree.leaves(jax.tree.map(sq, params)))
    return jnp.sqrt(jnp.mean(total))
