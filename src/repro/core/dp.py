"""Differential privacy on broadcast models (paper §6, Definition 2).

Clients add Gaussian noise to the model they broadcast. The paper's point
(validated in benchmarks/bench_dp.py, Figs 10-11): DP moves the achievable
loss but NOT the optimal K — privacy and resource allocation decouple.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def gaussian_sigma(epsilon: float, delta: float, sensitivity: float = 1.0) -> float:
    """Classic Gaussian-mechanism calibration: sigma >= sqrt(2 ln(1.25/delta)) * S / eps."""
    if epsilon <= 0:
        raise ValueError("epsilon must be positive")
    return math.sqrt(2.0 * math.log(1.25 / delta)) * sensitivity / epsilon


def epsilon_of_sigma(sigma: float, delta: float, sensitivity: float = 1.0) -> float:
    return math.sqrt(2.0 * math.log(1.25 / delta)) * sensitivity / max(sigma, 1e-12)


def privatize(params, key, sigma: float):
    """Add N(0, sigma^2) to every leaf (per-client, pre-broadcast)."""
    if sigma <= 0.0:
        return params
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(leaves))
    out = [
        (leaf + (jax.random.normal(k, leaf.shape, jnp.float32) * sigma).astype(leaf.dtype))
        for leaf, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, out)
