"""Lazy-client model (paper §5.1, eq. 7).

A lazy client skips local training, plagiarizes an honest client's freshly
trained model and adds N(0, sigma^2) noise to disguise itself. The lazy set
is static per experiment (first M of N clients); lazy client i copies honest
client M + (i mod (N - M)). On the mesh this gather over the client-sharded
leading axis lowers to a collective-permute-like exchange over 'data'.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def plagiarism_sources(n_clients: int, n_lazy: int) -> np.ndarray:
    """source[i] = client whose weights client i ends up holding."""
    if not (0 <= n_lazy < n_clients or (n_lazy == n_clients == 0)):
        raise ValueError(
            f"n_lazy={n_lazy}, n_clients={n_clients}: need at least one "
            "honest client when anyone is lazy")
    src = np.arange(n_clients)
    n_honest = n_clients - n_lazy
    for i in range(n_lazy):
        src[i] = n_lazy + (i % n_honest)
    return src


def apply_lazy(params, key, n_clients: int, n_lazy: int, sigma2: float):
    """params: pytree with leading client axis C. Returns lazy-transformed
    params; honest clients untouched."""
    if n_lazy == 0:
        return params
    src = jnp.asarray(plagiarism_sources(n_clients, n_lazy))
    is_lazy = jnp.arange(n_clients) < n_lazy
    std = float(np.sqrt(sigma2))
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(leaves))

    def one(leaf, k):
        stolen = jnp.take(leaf, src, axis=0)
        if std > 0.0:
            noise = (jax.random.normal(k, leaf.shape, jnp.float32) * std).astype(leaf.dtype)
            stolen = stolen + noise
        sel = is_lazy.reshape((n_clients,) + (1,) * (leaf.ndim - 1))
        return jnp.where(sel, stolen, leaf)

    return jax.tree.unflatten(treedef, [one(l, k) for l, k in zip(leaves, keys)])


def measure_theta(honest_params, lazy_params) -> jnp.ndarray:
    """theta = ||w_lazy - w_honest||_2 (Theorem 4's degradation term),
    computed between a lazy client's weights and its plagiarism source."""
    diffs = jax.tree.map(lambda a, b: jnp.sum((a.astype(jnp.float32)
                                               - b.astype(jnp.float32)) ** 2),
                         honest_params, lazy_params)
    return jnp.sqrt(sum(jax.tree.leaves(diffs)))
