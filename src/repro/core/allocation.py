"""Computing-resource allocation model (paper §3.2).

Every client has the same compute f; a learning task must finish within
t_sum. Each integrated round spends tau*alpha on local training and beta on
mining (eq. 1-3). The allocator turns (t_sum, K, alpha, beta) into a feasible
schedule and exposes the K-vs-tau tradeoff that §4 optimizes.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.core import bounds


def tau_from_budget(t_sum: float, K: int, alpha: float, beta: float) -> int:
    """Eq. (3): tau = floor((t_sum/K - beta)/alpha)."""
    if K <= 0:
        raise ValueError("K must be positive")
    tau = int((t_sum / K - beta) / alpha)
    return max(tau, 0)


@dataclasses.dataclass(frozen=True)
class AllocationPlan:
    K: int
    tau: int
    alpha: float
    beta: float
    t_sum: float

    @property
    def train_time(self) -> float:
        return self.K * self.tau * self.alpha

    @property
    def mine_time(self) -> float:
        return self.K * self.beta

    @property
    def slack(self) -> float:
        """Leftover time (ignored by the paper's analysis; must be >= 0)."""
        return self.t_sum - self.train_time - self.mine_time

    @property
    def feasible(self) -> bool:
        return self.tau >= 1 and self.slack >= -1e-9


def plan(t_sum: float, K: int, alpha: float, beta: float) -> AllocationPlan:
    return AllocationPlan(K=K, tau=tau_from_budget(t_sum, K, alpha, beta),
                          alpha=alpha, beta=beta, t_sum=t_sum)


def feasible_rounds(t_sum: float, alpha: float, beta: float) -> List[int]:
    """All K with tau >= 1."""
    k_max = int(t_sum / (alpha + beta))
    return [k for k in range(1, k_max + 1)
            if tau_from_budget(t_sum, k, alpha, beta) >= 1]


def optimal_plan(p: bounds.BoundParams, **lazy) -> AllocationPlan:
    """Plan at the bound-minimizing K (Theorem 3 numeric form)."""
    k = bounds.k_star_numeric(p, **lazy)
    return plan(p.t_sum, k, p.alpha, p.beta)


def mining_iterations(beta: float, hash_rate: float = 1024.0) -> int:
    """Calibrate the simulated PoW: beta time-units -> hash attempts."""
    return max(int(beta * hash_rate), 1)
