"""Pure-jnp oracle for the fused fedavg kernel."""
from __future__ import annotations

import jax.numpy as jnp


def fedavg_flat_ref(x, weights, noise=None):
    """x: [C, N]; weights: [C] normalized; noise: [C, N] or None."""
    agg = jnp.einsum("c,cn->n", weights.astype(jnp.float32),
                     x.astype(jnp.float32))
    out = jnp.broadcast_to(agg[None, :], x.shape)
    if noise is not None:
        out = out + noise.astype(jnp.float32)
    return out.astype(x.dtype)
