"""Jitted pytree-level wrapper: flatten every leaf to [C, N], run the fused
kernel, restore structure. Drop-in for core.aggregation.fedavg(+noise)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fedavg.kernel import fedavg_flat
from repro.kernels.fedavg.ref import fedavg_flat_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def fedavg_tree(params, weights=None, noise_tree=None, *, use_kernel: bool = True):
    """params: pytree with leading client axis C. Returns aggregated pytree
    (every client slot = weighted mean [+ noise])."""
    leaves, treedef = jax.tree.flatten(params)
    c = leaves[0].shape[0]
    if weights is None:
        weights = jnp.full((c,), 1.0 / c, jnp.float32)
    else:
        weights = weights / jnp.sum(weights)
    noise_leaves = (jax.tree.flatten(noise_tree)[0] if noise_tree is not None
                    else [None] * len(leaves))
    out = []
    for leaf, nz in zip(leaves, noise_leaves):
        flat = leaf.reshape(c, -1)
        nzf = nz.reshape(c, -1) if nz is not None else None
        if use_kernel:
            agg = fedavg_flat(flat, weights, nzf,
                              interpret=_default_interpret())
        else:
            agg = fedavg_flat_ref(flat, weights, nzf)
        out.append(agg.reshape(leaf.shape))
    return jax.tree.unflatten(treedef, out)
