"""Jitted pytree-level wrapper: flatten every leaf to [C, N], run the fused
kernel, restore structure. Drop-in for core.aggregation.fedavg(+noise)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import mining
from repro.kernels.fedavg.kernel import (digest_div_flat, fedavg_flat,
                                         mix_rows_flat)
from repro.kernels.fedavg.ref import fedavg_flat_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def fedavg_tree(params, weights=None, noise_tree=None, *, use_kernel: bool = True):
    """params: pytree with leading client axis C. Returns aggregated pytree
    (every client slot = weighted mean [+ noise])."""
    leaves, treedef = jax.tree.flatten(params)
    c = leaves[0].shape[0]
    if weights is None:
        weights = jnp.full((c,), 1.0 / c, jnp.float32)
    else:
        weights = weights / jnp.sum(weights)
    noise_leaves = (jax.tree.flatten(noise_tree)[0] if noise_tree is not None
                    else [None] * len(leaves))
    out = []
    for leaf, nz in zip(leaves, noise_leaves):
        flat = leaf.reshape(c, -1)
        nzf = nz.reshape(c, -1) if nz is not None else None
        if use_kernel:
            agg = fedavg_flat(flat, weights, nzf,
                              interpret=_default_interpret())
        else:
            agg = fedavg_flat_ref(flat, weights, nzf)
        out.append(agg.reshape(leaf.shape))
    return jax.tree.unflatten(treedef, out)


def mix_rows_tree(params, w_rows, *, block_n: int = 2048,
                  interpret: bool | None = None):
    """Apply the fused row-block mix matmul leaf-wise: every ``[C, ...]``
    leaf flattens to ``[C, N]``, contracts against ``w_rows [R, C]`` (already
    reweighted + row-selected) and comes back as ``[R, ...]``. Traceable —
    called from inside the round scan by ``aggregation.mix_gather``."""
    if interpret is None:
        interpret = _default_interpret()
    r = w_rows.shape[0]

    def one(leaf):
        flat = leaf.astype(jnp.float32).reshape((leaf.shape[0], -1))
        out = mix_rows_flat(w_rows, flat, block_n=block_n,
                            interpret=interpret)
        return out.reshape((r,) + leaf.shape[1:]).astype(leaf.dtype)

    return jax.tree.map(one, params)


def digest_divergence_tree(tree, *, block_n: int = 2048,
                           interpret: bool | None = None):
    """Fused diagnostics: ONE sweep of the broadcast set computes both the
    model digest and the client-divergence diagnostic that the jnp path
    (``mining.digest_tree`` + ``aggregation.client_divergence``) computes in
    two traversals. Returns ``(digest uint32, divergence f32 scalar)``.

    Tolerance tier: per-leaf sums accumulate fp32 tile partials, so the
    digest — and every downstream ledger hash — forks deterministically from
    the bitwise engine's chain (both chains still self-validate, same
    contract as ``fast_allreduce``). Divergence matches
    ``aggregation.client_divergence`` to fp32 tolerance. Non-float leaves
    (absent from real param trees) keep digest_tree's exact int32 sum."""
    if interpret is None:
        interpret = _default_interpret()
    leaves = jax.tree.leaves(tree)
    c = leaves[0].shape[0]
    acc = jnp.uint32(mining.DIGEST_INIT)
    total = jnp.zeros((c,), jnp.float32)
    for leaf in leaves:
        flat = leaf.astype(jnp.float32).reshape((c, -1))
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            s, res = digest_div_flat(flat, block_n=block_n,
                                     interpret=interpret)
        else:
            s = jnp.sum(leaf.astype(jnp.int32)).astype(jnp.float32)
            mean = jnp.mean(flat, axis=0, keepdims=True)
            res = jnp.sum((flat - mean) ** 2, axis=1)
        acc = mining.fold_digest(acc, s)
        total = total + res
    return acc, jnp.sqrt(jnp.mean(total))
