from repro.kernels.fedavg.kernel import (digest_div_flat,  # noqa: F401
                                         fedavg_flat, mix_rows_flat)
from repro.kernels.fedavg.ops import (digest_divergence_tree,  # noqa: F401
                                      fedavg_tree, mix_rows_tree)
from repro.kernels.fedavg.ref import fedavg_flat_ref  # noqa: F401
