from repro.kernels.fedavg.kernel import fedavg_flat  # noqa: F401
from repro.kernels.fedavg.ops import fedavg_tree  # noqa: F401
from repro.kernels.fedavg.ref import fedavg_flat_ref  # noqa: F401
