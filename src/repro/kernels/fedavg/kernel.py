"""Fused BLADE-FL aggregation kernel — TPU Pallas.

One VMEM pass per tile fuses the paper's Steps 2+5 epilogue: weighted mean
over the client axis, re-broadcast to every client slot, and the optional
additive noise (DP mechanism §6 / lazy disguise §5 — noise tile precomputed
outside, the kernel fuses the add so the aggregate never round-trips HBM
between mean, broadcast and noise).

Layout: params are flattened per-leaf to [C, N]; grid tiles N. C (<=32) rides
whole in the sublane dimension of each tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _fedavg_kernel(x_ref, w_ref, noise_ref, o_ref, *, with_noise: bool):
    x = x_ref[...].astype(jnp.float32)            # [C, bn]
    w = w_ref[...].astype(jnp.float32)            # [C]
    agg = jnp.einsum("c,cn->n", w, x)             # weighted mean (w sums to 1)
    out = jnp.broadcast_to(agg[None, :], x.shape)
    if with_noise:
        out = out + noise_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


def fedavg_flat(x: jnp.ndarray, weights: jnp.ndarray,
                noise: jnp.ndarray | None = None, *, block_n: int = 2048,
                interpret: bool = True) -> jnp.ndarray:
    """x: [C, N]; weights: [C] (normalized); noise: [C, N] or None."""
    c, n = x.shape
    block_n = min(block_n, n)
    pad = (-n) % block_n
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        if noise is not None:
            noise = jnp.pad(noise, ((0, 0), (0, pad)))
    npad = x.shape[1]
    with_noise = noise is not None
    if noise is None:
        noise = jnp.zeros((c, block_n), x.dtype)  # dummy single tile
        noise_spec = pl.BlockSpec((c, block_n), lambda i: (0, 0))
    else:
        noise_spec = pl.BlockSpec((c, block_n), lambda i: (0, i))

    out = pl.pallas_call(
        functools.partial(_fedavg_kernel, with_noise=with_noise),
        grid=(npad // block_n,),
        in_specs=[
            pl.BlockSpec((c, block_n), lambda i: (0, i)),
            pl.BlockSpec((c,), lambda i: (0,)),
            noise_spec,
        ],
        out_specs=pl.BlockSpec((c, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((c, npad), x.dtype),
        interpret=interpret,
    )(x, weights, noise)
    return out[:, :n]


def _mix_rows_kernel(w_ref, x_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)            # [R, K]
    x = x_ref[...].astype(jnp.float32)            # [K, bn]
    o_ref[...] = jnp.dot(w, x,
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


def mix_rows_flat(w_rows: jnp.ndarray, x: jnp.ndarray, *, block_n: int = 2048,
                  interpret: bool = True) -> jnp.ndarray:
    """Fused weighted-gather + matmul + row-select: ``w_rows [R, K] @ x
    [K, N] -> [R, N]``, tiled over N with the whole (reweighted,
    row-selected) mixing block resident per tile.

    This is the local column/row-block contraction of the Steps 2+5 mix:
    ``aggregation.mix_gather`` passes its shard's ROW block of ``W`` (R =
    local clients, K = C — only the local rows are ever computed, the
    row-select is fused into the matmul instead of slicing a full [C, N]
    product), ``aggregation.mix_psum_dense`` passes its COLUMN block (R = C,
    K = local clients). Tolerance tier: the kernel's own contraction order
    replaces XLA's.
    """
    r, k = w_rows.shape
    k2, n = x.shape
    if k != k2:
        raise ValueError(
            f"mix_rows_flat: w_rows [R={r}, K={k}] does not contract with "
            f"x [K={k2}, N={n}]")
    block_n = min(block_n, n)
    pad = (-n) % block_n
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    npad = x.shape[1]
    out = pl.pallas_call(
        _mix_rows_kernel,
        grid=(npad // block_n,),
        in_specs=[pl.BlockSpec((r, k), lambda i: (0, 0)),
                  pl.BlockSpec((k, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((r, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((r, npad), x.dtype),
        interpret=interpret,
    )(w_rows, x)
    return out[:, :n]


def _digest_div_kernel(x_ref, s_ref, r_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        r_ref[...] = jnp.zeros_like(r_ref)

    x = x_ref[...].astype(jnp.float32)            # [C, bn]
    c = x.shape[0]
    # column means over the (fully resident) client axis; zero-padded tail
    # columns contribute 0 to both outputs, so no mask is needed
    mean = jnp.sum(x, axis=0, keepdims=True) / np.float32(c)
    s_ref[0] = s_ref[0] + jnp.sum(x)
    r_ref[...] = r_ref[...] + jnp.sum((x - mean) ** 2, axis=1)


def digest_div_flat(x: jnp.ndarray, *, block_n: int = 2048,
                    interpret: bool = True):
    """One sweep of a ``[C, N]`` leaf for BOTH diagnostics of the
    communicate stage: returns ``(leaf_sum scalar, residuals [C])`` where
    ``leaf_sum`` feeds the model digest fold (``mining.fold_digest``) and
    ``residuals[c]`` is client c's squared distance from the client mean
    over this leaf (the divergence diagnostic, Def. 1). The jnp path reads
    the broadcast set twice (digest_tree + client_divergence); this reads it
    once. Tolerance tier: the leaf sum accumulates tile partials, so the
    digest forks deterministically from ``mining.digest_tree``.
    """
    c, n = x.shape
    block_n = min(block_n, n)
    pad = (-n) % block_n
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    s, r = pl.pallas_call(
        _digest_div_kernel,
        grid=(x.shape[1] // block_n,),
        in_specs=[pl.BlockSpec((c, block_n), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1,), lambda i: (0,)),
                   pl.BlockSpec((c,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((1,), jnp.float32),
                   jax.ShapeDtypeStruct((c,), jnp.float32)],
        interpret=interpret,
    )(x)
    return s[0], r
