"""Fused BLADE-FL aggregation kernel — TPU Pallas.

One VMEM pass per tile fuses the paper's Steps 2+5 epilogue: weighted mean
over the client axis, re-broadcast to every client slot, and the optional
additive noise (DP mechanism §6 / lazy disguise §5 — noise tile precomputed
outside, the kernel fuses the add so the aggregate never round-trips HBM
between mean, broadcast and noise).

Layout: params are flattened per-leaf to [C, N]; grid tiles N. C (<=32) rides
whole in the sublane dimension of each tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fedavg_kernel(x_ref, w_ref, noise_ref, o_ref, *, with_noise: bool):
    x = x_ref[...].astype(jnp.float32)            # [C, bn]
    w = w_ref[...].astype(jnp.float32)            # [C]
    agg = jnp.einsum("c,cn->n", w, x)             # weighted mean (w sums to 1)
    out = jnp.broadcast_to(agg[None, :], x.shape)
    if with_noise:
        out = out + noise_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


def fedavg_flat(x: jnp.ndarray, weights: jnp.ndarray,
                noise: jnp.ndarray | None = None, *, block_n: int = 2048,
                interpret: bool = True) -> jnp.ndarray:
    """x: [C, N]; weights: [C] (normalized); noise: [C, N] or None."""
    c, n = x.shape
    block_n = min(block_n, n)
    pad = (-n) % block_n
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        if noise is not None:
            noise = jnp.pad(noise, ((0, 0), (0, pad)))
    npad = x.shape[1]
    with_noise = noise is not None
    if noise is None:
        noise = jnp.zeros((c, block_n), x.dtype)  # dummy single tile
        noise_spec = pl.BlockSpec((c, block_n), lambda i: (0, 0))
    else:
        noise_spec = pl.BlockSpec((c, block_n), lambda i: (0, i))

    out = pl.pallas_call(
        functools.partial(_fedavg_kernel, with_noise=with_noise),
        grid=(npad // block_n,),
        in_specs=[
            pl.BlockSpec((c, block_n), lambda i: (0, i)),
            pl.BlockSpec((c,), lambda i: (0,)),
            noise_spec,
        ],
        out_specs=pl.BlockSpec((c, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((c, npad), x.dtype),
        interpret=interpret,
    )(x, weights, noise)
    return out[:, :n]
