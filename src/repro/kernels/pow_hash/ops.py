"""Jitted wrapper + per-client vmapped mining entry point."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import mining
from repro.kernels.pow_hash.kernel import pow_search_kernel
from repro.kernels.pow_hash.ref import pow_search_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("n_attempts", "use_kernel"))
def mine(prev_hash, payload, client_id, n_attempts: int = 4096, *,
         nonce_offset=0, use_kernel: bool = True):
    """Single-client nonce race; salts the payload per client like
    core.mining.pow_search. Returns (best_hash, best_nonce)."""
    salt = mining._avalanche(jnp.asarray(client_id, jnp.uint32)
                             * jnp.uint32(2246822519))
    payload_s = jnp.asarray(payload, jnp.uint32) ^ salt
    if use_kernel:
        return pow_search_kernel(prev_hash, payload_s,
                                 jnp.asarray(nonce_offset, jnp.uint32),
                                 n_attempts, interpret=_default_interpret())
    return pow_search_ref(prev_hash, payload_s, nonce_offset, n_attempts)
