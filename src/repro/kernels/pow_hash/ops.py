"""Jitted wrapper + per-client vmapped mining entry point."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import mining
from repro.kernels.pow_hash.kernel import pow_race_kernel, pow_search_kernel
from repro.kernels.pow_hash.ref import pow_search_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("n_attempts", "use_kernel"))
def mine(prev_hash, payload, client_id, n_attempts: int = 4096, *,
         nonce_offset=0, use_kernel: bool = True):
    """Single-client nonce race; salts the payload with
    ``mining.client_salt`` exactly like core.mining.pow_search. Returns
    (best_hash, best_nonce)."""
    salt = mining.client_salt(client_id)
    payload_s = jnp.asarray(payload, jnp.uint32) ^ salt
    if use_kernel:
        return pow_search_kernel(prev_hash, payload_s,
                                 jnp.asarray(nonce_offset, jnp.uint32),
                                 n_attempts, interpret=_default_interpret())
    return pow_search_ref(prev_hash, payload_s, nonce_offset, n_attempts)


def pow_race(prev_hash, payload, client_ids, n_attempts: int, *,
             nonce_offset=0, chunk: int = 2048,
             interpret: bool | None = None):
    """The whole Step-3 race on the 2-D (clients × nonce chunks) grid.

    ``client_ids`` is the ``[C]`` uint32 id vector (global ids — sharded
    callers pass their offset local block); each client's payload is salted
    with ``mining.client_salt`` so the disjoint-nonce-space contract has the
    single shared definition. Traceable (called from inside the round scan);
    returns ``(best_hashes [C], best_nonces [C])`` bitwise equal to
    ``vmap(mining.pow_search)`` at every ``(n_attempts, chunk)``.
    """
    if interpret is None:
        interpret = _default_interpret()
    payloads = jnp.asarray(payload, jnp.uint32) ^ mining.client_salt(client_ids)
    return pow_race_kernel(prev_hash, payloads,
                           jnp.asarray(nonce_offset, jnp.uint32),
                           int(n_attempts), block=int(chunk),
                           interpret=interpret)
