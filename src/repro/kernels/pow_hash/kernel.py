"""PoW nonce-search kernel — TPU Pallas.

The mining hot-spot (paper §3.1 Step 3): evaluate the integer mixing hash
over a nonce grid and reduce to the (min_hash, argmin_nonce) pair. Nonce
tiles are generated in-register (iota + offset, no HBM input traffic); the
running minimum lives in a revisited output block, so per grid step the only
HBM traffic is the final 2-word result — the kernel is pure-VPU integer
throughput, exactly how mining behaves on real silicon.

Matches repro.core.mining.mix_hash bit-for-bit (validated vs ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# numpy scalars (NOT jnp arrays) so pallas inlines them as literals
_M1 = np.uint32(2654435761)
_M2 = np.uint32(2246822519)
_M3 = np.uint32(3266489917)


def _avalanche(h):
    h = h ^ (h >> np.uint32(15))
    h = h * _M2
    h = h ^ (h >> np.uint32(13))
    h = h * _M3
    h = h ^ (h >> np.uint32(16))
    return h


def _pow_kernel(seed_ref, best_h_ref, best_n_ref, *, block: int,
                n_attempts: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        best_h_ref[...] = jnp.full_like(best_h_ref, np.uint32(0xFFFFFFFF))
        best_n_ref[...] = jnp.zeros_like(best_n_ref)

    prev_hash = seed_ref[0]
    payload = seed_ref[1]
    offset = seed_ref[2]
    local = (jnp.uint32(i).astype(jnp.uint32) * np.uint32(block)
             + jax.lax.broadcasted_iota(jnp.uint32, (1, block), 1))[0]
    nonces = offset + local
    h = prev_hash * _M1
    h = _avalanche(h ^ payload)
    hs = _avalanche(h ^ nonces)
    # mask padded tail nonces (last partial block) out of the race
    hs = jnp.where(local < np.uint32(n_attempts), hs,
                   jnp.full_like(hs, np.uint32(0xFFFFFFFF)))
    idx = jnp.argmin(hs)
    h_min = hs[idx]
    n_min = nonces[idx]
    take = h_min < best_h_ref[0]
    best_h_ref[0] = jnp.where(take, h_min, best_h_ref[0])
    best_n_ref[0] = jnp.where(take, n_min, best_n_ref[0])


def pow_search_kernel(prev_hash, payload, nonce_offset, n_attempts: int, *,
                      block: int = 2048, interpret: bool = True):
    """Returns (best_hash, best_nonce) over n_attempts nonces. All inputs
    uint32 scalars (payload already salted per client)."""
    if n_attempts <= 0:
        raise ValueError(f"n_attempts must be positive, got {n_attempts}")
    block = min(block, n_attempts)
    n_blocks = -(-n_attempts // block)
    seed = jnp.stack([jnp.asarray(prev_hash, jnp.uint32),
                      jnp.asarray(payload, jnp.uint32),
                      jnp.asarray(nonce_offset, jnp.uint32)])
    best_h, best_n = pl.pallas_call(
        functools.partial(_pow_kernel, block=block, n_attempts=n_attempts),
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((3,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((1,), lambda i: (0,)),
                   pl.BlockSpec((1,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((1,), jnp.uint32),
                   jax.ShapeDtypeStruct((1,), jnp.uint32)],
        interpret=interpret,
    )(seed)
    return best_h[0], best_n[0]


def _pow_race_kernel(seed_ref, payload_ref, best_h_ref, best_n_ref, *,
                     block: int, n_attempts: int):
    """2-D grid body: program (c, j) races nonce chunk j of client c.

    The chunk axis is the minor (innermost) grid dimension, so client c's
    output block is revisited across all its chunks and carries the running
    (min hash, argmin nonce) — the same reduction the 1-D kernel performs,
    now one row per client. Chunked running-min with first-index tie-breaking
    per chunk equals the full-range first-occurrence argmin, so the result is
    bitwise independent of ``block`` — the property the engine's
    (mine_attempts, mine_chunk) sweep tests pin.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_h_ref[...] = jnp.full_like(best_h_ref, np.uint32(0xFFFFFFFF))
        best_n_ref[...] = jnp.zeros_like(best_n_ref)

    prev_hash = seed_ref[0]
    offset = seed_ref[1]
    payload = payload_ref[0]
    local = (jnp.uint32(j).astype(jnp.uint32) * np.uint32(block)
             + jax.lax.broadcasted_iota(jnp.uint32, (1, block), 1))[0]
    nonces = offset + local
    h = prev_hash * _M1
    h = _avalanche(h ^ payload)
    hs = _avalanche(h ^ nonces)
    # budget mask: the tail chunk charges exactly n_attempts nonces (eq. 1)
    hs = jnp.where(local < np.uint32(n_attempts), hs,
                   jnp.full_like(hs, np.uint32(0xFFFFFFFF)))
    idx = jnp.argmin(hs)
    h_min = hs[idx]
    n_min = nonces[idx]
    take = h_min < best_h_ref[0]
    best_h_ref[0] = jnp.where(take, h_min, best_h_ref[0])
    best_n_ref[0] = jnp.where(take, n_min, best_n_ref[0])


def pow_race_kernel(prev_hash, payloads, nonce_offset, n_attempts: int, *,
                    block: int = 2048, interpret: bool = True):
    """Whole-race form of the PoW search: one 2-D (clients × nonce chunks)
    grid replaces the per-client ``vmap(fori_loop)`` of
    ``core.mining.pow_search``.

    ``payloads`` is the ``[C]`` uint32 vector of per-client pre-salted
    payloads (``digest ^ mining.client_salt(client_id)`` — the disjoint
    nonce spaces); ``prev_hash`` / ``nonce_offset`` are shared uint32
    scalars. Returns ``(best_hashes [C], best_nonces [C])``, bitwise equal
    to vmapping ``pow_search_kernel`` (and to the fori_loop path) at every
    ``(n_attempts, block)`` including non-divisible budgets.
    """
    if n_attempts <= 0:
        raise ValueError(f"n_attempts must be positive, got {n_attempts}")
    if payloads.ndim != 1:
        raise ValueError(f"payloads must be a [C] vector, got {payloads.shape}")
    c = payloads.shape[0]
    block = min(block, n_attempts)
    n_blocks = -(-n_attempts // block)
    seed = jnp.stack([jnp.asarray(prev_hash, jnp.uint32),
                      jnp.asarray(nonce_offset, jnp.uint32)])
    best_h, best_n = pl.pallas_call(
        functools.partial(_pow_race_kernel, block=block,
                          n_attempts=n_attempts),
        grid=(c, n_blocks),
        in_specs=[pl.BlockSpec((2,), lambda ci, j: (0,)),
                  pl.BlockSpec((1,), lambda ci, j: (ci,))],
        out_specs=[pl.BlockSpec((1,), lambda ci, j: (ci,)),
                   pl.BlockSpec((1,), lambda ci, j: (ci,))],
        out_shape=[jax.ShapeDtypeStruct((c,), jnp.uint32),
                   jax.ShapeDtypeStruct((c,), jnp.uint32)],
        interpret=interpret,
    )(seed, jnp.asarray(payloads, jnp.uint32))
    return best_h, best_n
