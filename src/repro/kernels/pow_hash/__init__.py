from repro.kernels.pow_hash.kernel import (pow_race_kernel,  # noqa: F401
                                           pow_search_kernel)
from repro.kernels.pow_hash.ops import mine, pow_race  # noqa: F401
from repro.kernels.pow_hash.ref import pow_search_ref  # noqa: F401
