from repro.kernels.pow_hash.kernel import pow_search_kernel  # noqa: F401
from repro.kernels.pow_hash.ops import mine  # noqa: F401
from repro.kernels.pow_hash.ref import pow_search_ref  # noqa: F401
