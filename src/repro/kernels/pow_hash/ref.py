"""Pure-jnp oracle: delegates to core.mining (the canonical implementation)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.mining import mix_hash, pow_search  # noqa: F401


def pow_search_ref(prev_hash, payload_salted, nonce_offset, n_attempts: int):
    """Same contract as kernel.pow_search_kernel (payload pre-salted):
    brute-force over the whole nonce range in one shot."""
    nonces = jnp.asarray(nonce_offset, jnp.uint32) + jnp.arange(
        n_attempts, dtype=jnp.uint32)
    hs = mix_hash(jnp.asarray(prev_hash, jnp.uint32),
                  jnp.asarray(payload_salted, jnp.uint32), nonces)
    idx = jnp.argmin(hs)
    return hs[idx], nonces[idx]
