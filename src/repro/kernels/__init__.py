from repro.kernels import fedavg, flash_attention, pow_hash, ssm_scan  # noqa: F401
