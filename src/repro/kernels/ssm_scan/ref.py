"""Pure-jnp oracle: the same recurrence via lax.scan over time."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(u, dt, bmat, cmat, a, d_skip):
    """Same contract as kernel.ssm_scan."""
    bsz, t, d_in = u.shape

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None] * a)
        h = da * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t) + u_t * d_skip
        return h, y

    h0 = jnp.zeros((bsz, d_in, a.shape[1]), jnp.float32)
    xs = (u.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          bmat.swapaxes(0, 1).astype(jnp.float32),
          cmat.swapaxes(0, 1).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(u.dtype), h
