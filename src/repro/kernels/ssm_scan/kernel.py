"""Selective-state-space (Mamba S6) scan kernel — TPU Pallas.

Hardware adaptation of the paper-adjacent GPU "selective scan" kernel: on
GPU, Mamba fuses the recurrence into an SRAM-resident kernel; the TPU
analogue keeps the [tile_d, d_state] SSM state resident in VMEM across the
whole time sweep. The grid is (batch, d_in tiles, time tiles) with time
innermost — the state block's index_map ignores the time index, so Mosaic
revisits the same VMEM block for every time tile and the state NEVER
round-trips HBM (the pure-XLA lax.scan carries it through HBM every step —
the dominant memory term of the jamba dry-run baseline).

HBM traffic: read u/dt (tile_t x tile_d), B/C (tile_t x d_state) per time
tile, write y — i.e. I/O only, ~(2*d_state)x less than the scan carry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssm_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_ref, *,
                tile_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...]                      # [tile_d, ds]
    dskip = d_ref[...]                  # [tile_d]

    def step(i, h):                     # h: [tile_d, ds]
        u_t = u_ref[0, i, :]            # [tile_d]
        dt_t = dt_ref[0, i, :]          # [tile_d]
        b_t = b_ref[0, i, :]            # [ds]
        c_t = c_ref[0, i, :]            # [ds]
        da = jnp.exp(dt_t[:, None] * a)                 # [tile_d, ds]
        h = da * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y = jnp.sum(h * c_t[None, :], axis=-1) + u_t * dskip
        y_ref[0, i, :] = y.astype(y_ref.dtype)
        return h

    h_ref[0] = jax.lax.fori_loop(0, tile_t, step, h_ref[0])


def ssm_scan(u: jnp.ndarray, dt: jnp.ndarray, bmat: jnp.ndarray,
             cmat: jnp.ndarray, a: jnp.ndarray, d_skip: jnp.ndarray, *,
             tile_t: int = 128, tile_d: int = 512,
             interpret: bool = True):
    """u, dt: [B, T, d_in]; bmat, cmat: [B, T, ds]; a: [d_in, ds];
    d_skip: [d_in]. Returns (y [B, T, d_in], h_final [B, d_in, ds])."""
    b, t, d_in = u.shape
    ds = a.shape[1]
    tile_t = min(tile_t, t)
    tile_d = min(tile_d, d_in)
    if t % tile_t or d_in % tile_d:
        raise ValueError(
            f"pad to tile multiples: T={t} % tile_t={tile_t} and "
            f"d_in={d_in} % tile_d={tile_d} must both be 0")
    nt, nd = t // tile_t, d_in // tile_d

    kern = functools.partial(_ssm_kernel, tile_t=tile_t)
    y, h = pl.pallas_call(
        kern,
        grid=(b, nd, nt),
        in_specs=[
            pl.BlockSpec((1, tile_t, tile_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, tile_t, tile_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, tile_t, ds), lambda bi, di, ti: (bi, ti, 0)),
            pl.BlockSpec((1, tile_t, ds), lambda bi, di, ti: (bi, ti, 0)),
            pl.BlockSpec((tile_d, ds), lambda bi, di, ti: (di, 0)),
            pl.BlockSpec((tile_d,), lambda bi, di, ti: (di,)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_t, tile_d), lambda bi, di, ti: (bi, ti, di)),
            # state block: index_map ignores ti -> VMEM-resident across time
            pl.BlockSpec((1, tile_d, ds), lambda bi, di, ti: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, d_in), u.dtype),
            jax.ShapeDtypeStruct((b, d_in, ds), jnp.float32),
        ],
        interpret=interpret,
    )(u.astype(jnp.float32), dt.astype(jnp.float32),
      bmat.astype(jnp.float32), cmat.astype(jnp.float32),
      a.astype(jnp.float32), d_skip.astype(jnp.float32))
    return y, h
