from repro.kernels.ssm_scan.kernel import ssm_scan  # noqa: F401
from repro.kernels.ssm_scan.ops import selective_scan  # noqa: F401
from repro.kernels.ssm_scan.ref import ssm_scan_ref  # noqa: F401
