"""Jitted wrapper with backend dispatch (kernel on TPU / interpret on CPU,
jnp reference as the fallback path)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssm_scan.kernel import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("tile_t", "tile_d", "use_kernel"))
def selective_scan(u, dt, bmat, cmat, a, d_skip, *, tile_t: int = 128,
                   tile_d: int = 512, use_kernel: bool = True):
    if use_kernel:
        return ssm_scan(u, dt, bmat, cmat, a, d_skip, tile_t=tile_t,
                        tile_d=tile_d, interpret=_default_interpret())
    return ssm_scan_ref(u, dt, bmat, cmat, a, d_skip)
