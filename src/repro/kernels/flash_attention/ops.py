"""Jitted public wrapper: GQA expansion + dtype policy + kernel/ref dispatch.

On CPU (this container) the kernel runs in interpret mode; on TPU set
interpret=False (the default flips automatically by backend).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "use_kernel"))
def mha(q, k, v, *, causal: bool = True, window: int = 0,
        block_q: int = 128, block_k: int = 128, use_kernel: bool = True):
    """q: [B, S, H, D]; k, v: [B, S, Hkv, D] (GQA) -> [B, S, H, D]."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if use_kernel:
        out = flash_attention(qt, kt, vt, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=_default_interpret())
    else:
        out = attention_ref(qt, kt, vt, causal=causal, window=window)
    return out.transpose(0, 2, 1, 3)
