"""Blocked (flash) attention forward kernel — TPU Pallas.

The prefill hot-spot: O(S^2) attention computed in VMEM tiles with the
online-softmax recurrence, never materializing the [S, S] score matrix in
HBM. GQA is handled by expanding kv to the q-head count outside the kernel;
causal and sliding-window masks are applied per tile with index arithmetic,
and fully-masked kv tiles short-circuit.

Grid: (batch*heads, q_blocks, kv_blocks) — kv innermost, so the output block
and the (m, l, acc) running stats (extra outputs whose index_map ignores the
kv index) stay resident in VMEM across the kv sweep (TPU grid revisiting).

Block shapes default to 128x128 tiles over (S_q, S_k) with the full head_dim
in-tile — MXU-aligned for head_dim 64/128/256 (112 for kimi-k2 is padded to
the lane width by Mosaic transparently).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int, block_q: int,
                 block_k: int, seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    def body():
        q = q_ref[0].astype(jnp.float32) * scale          # [bq, d]
        k = k_ref[0].astype(jnp.float32)                  # [bk, d]
        v = v_ref[0].astype(jnp.float32)                  # [bk, d]
        s = q @ k.T                                       # [bq, bk]
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = cols < seq_len
        if causal:
            mask = mask & (cols <= rows)
        if window > 0:
            mask = mask & (cols > rows - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[0]                                 # [bq]
        l_prev = l_ref[0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[0] = l_prev * alpha + jnp.sum(p, axis=-1)
        m_ref[0] = m_cur
        acc_ref[0] = acc_ref[0] * alpha[:, None] + p @ v

    if causal:
        # kv tiles fully above the diagonal contribute nothing — skip
        @pl.when(k_start <= q_start + block_q - 1)
        def _run():
            body()
    else:
        body()

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0] = (acc_ref[0] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    scale: float | None = None,
                    interpret: bool = True) -> jnp.ndarray:
    """q, k, v: [B, H, S, D] (kv already expanded to H heads) -> [B, H, S, D]."""
    b, h, s, d = q.shape
    if not (k.shape == v.shape == (b, h, s, d)):
        raise ValueError(
            f"q/k/v shapes must match: q={q.shape} k={k.shape} v={v.shape}")
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q or s % block_k:
        raise ValueError(
            f"pad seq to a block multiple: S={s} not divisible by "
            f"block_q={block_q} / block_k={block_k}")
    nq = s // block_q
    nk = s // block_k
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)

    kern = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_len=s)

    out, _, _, _ = pl.pallas_call(
        kern,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh, qi)),
            pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh, qi)),
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, d), q.dtype),      # o
            jax.ShapeDtypeStruct((b * h, s), jnp.float32),     # running max
            jax.ShapeDtypeStruct((b * h, s), jnp.float32),     # running denom
            jax.ShapeDtypeStruct((b * h, s, d), jnp.float32),  # accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
