"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    """q, k, v: [B, H, S, D] -> [B, H, S, D]; full softmax attention."""
    b, h, s, d = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok = ok & (j <= i)
    if window > 0:
        ok = ok & (j > i - window)
    logits = jnp.where(ok, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)
