"""Sharding-aware batching for training drivers.

The FL substrate consumes client-stacked batches [C, m, ...]; the pipeline
builds them deterministically per round (so experiments are reproducible and
the dry-run's ShapeDtypeStructs match real batches bit-for-shape).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data import synthetic


class FLDataSource:
    """Fixed per-client local datasets (paper: |D_i| = 512 samples each);
    each round every client does full-batch GD on its local shard."""

    def __init__(self, key, n_clients: int, samples_per_client: int,
                 dirichlet_alpha: float = 0.5, dataset: str = "mnist",
                 seed: int = 0):
        n_eval = 2048
        n_total = n_clients * samples_per_client * 2 + n_eval
        maker = synthetic.mnist_proxy if dataset == "mnist" else synthetic.fashion_proxy
        # one draw so train and eval share the SAME class templates
        full = maker(key, n_total)
        self.eval_data = {k: v[-n_eval:] for k, v in full.items()}
        self.data = {k: v[:-n_eval] for k, v in full.items()}
        part = synthetic.dirichlet_partition(
            np.asarray(self.data["y"]), n_clients, dirichlet_alpha,
            samples_per_client, seed=seed)
        self.client_data = synthetic.client_batches(self.data, part)

    def round_batch(self, k: int) -> Dict[str, jnp.ndarray]:
        # full local batch every round (paper does full-batch GD locally)
        return self.client_data

    def static_batch(self) -> Dict[str, jnp.ndarray]:
        """The [C, m, ...] batch every round reuses — feed this straight to
        ``run_blade_fl`` / ``run_blade_fl_scan`` to take the compiled
        multi-round path (no [K, ...] stacking needed: full-batch GD means
        the scan closes over one constant batch)."""
        return self.client_data


class CohortDataSource:
    """Enrolled-population data for the cohort driver
    (``core.rounds.run_blade_fl_cohort``).

    ``FLDataSource`` materializes every client's local dataset up front —
    O(C · samples) memory, fine at C = 20, unbuildable for a 10k enrolled
    population. Here each client's fixed local dataset is a pure function
    of ``(source key, client id)``: shared class templates (one draw, so
    the population learns one task), per-client Dirichlet(alpha) label
    proportions (the same non-IID skew the partitioned source has) and
    per-client sample noise, all folded from the client id — built only
    when a round's cohort actually contains the client, LRU-bounded. A K-
    round run touches O(A · K) client datasets, never O(C_enrolled).

    ``cohort_batch`` has the ``(round_idx, cohort_idx) -> [A, m, ...]``
    signature ``run_blade_fl_cohort`` expects for its ``batches``
    callable.
    """

    def __init__(self, key, samples_per_client: int,
                 dirichlet_alpha: float = 0.5, dataset: str = "mnist",
                 image_dim: int = 784, n_classes: int = 10,
                 cache_size: int = 512):
        if samples_per_client < 1:
            raise ValueError("samples_per_client must be >= 1")
        if cache_size < 1:
            raise ValueError("cache_size must be >= 1")
        noise, template_scale = ((1.3, 0.35) if dataset == "mnist"
                                 else (4.0, 0.3))
        k_tmpl, k_eval, self._client_key = jax.random.split(key, 3)
        self.templates = (jax.random.normal(k_tmpl, (n_classes, image_dim))
                          * template_scale).astype(jnp.float32)
        self.samples_per_client = samples_per_client
        self.dirichlet_alpha = dirichlet_alpha
        self.n_classes = n_classes
        self.noise = noise
        self.eval_data = self._draw(k_eval, 2048, skew=False)
        self._cache: Dict[int, Dict[str, jnp.ndarray]] = {}
        self._cache_size = cache_size

    def _draw(self, key, n: int, skew: bool = True) -> Dict[str, jnp.ndarray]:
        k_prop, k_lbl, k_noise = jax.random.split(key, 3)
        if skew:
            # per-client Dirichlet label proportions = the non-IID skew
            props = jax.random.dirichlet(
                k_prop, jnp.full((self.n_classes,), self.dirichlet_alpha))
            y = jax.random.categorical(k_lbl, jnp.log(props + 1e-9), shape=(n,))
        else:
            y = jax.random.randint(k_lbl, (n,), 0, self.n_classes)
        x = self.templates[y] + jax.random.normal(
            k_noise, (n, self.templates.shape[1])) * self.noise
        return {"x": jax.nn.sigmoid(x).astype(jnp.float32),
                "y": y.astype(jnp.int32)}

    def client_batch(self, client_id: int) -> Dict[str, jnp.ndarray]:
        """Client ``client_id``'s fixed local dataset ``[m, ...]`` —
        deterministic in the id, cached while hot."""
        cid = int(client_id)
        hit = self._cache.get(cid)
        if hit is not None:
            return hit
        batch = self._draw(jax.random.fold_in(self._client_key, cid),
                           self.samples_per_client)
        if len(self._cache) >= self._cache_size:
            self._cache.pop(next(iter(self._cache)))
        self._cache[cid] = batch
        return batch

    def cohort_batch(self, round_idx: int, cohort_idx) -> Dict[str, jnp.ndarray]:
        """The ``[A, m, ...]`` stack for a round's cohort (full-batch GD:
        round_idx is unused, each client always trains its fixed local
        set)."""
        rows = [self.client_batch(i) for i in np.asarray(cohort_idx)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *rows)


class LMDataSource:
    """Synthetic token streams for the assigned-architecture train runs,
    stacked on a leading client axis."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, n_clients: int,
                 seed: int = 0):
        self.cfg, self.shape, self.n_clients = cfg, shape, n_clients
        self.seed = seed

    def round_batch(self, k: int) -> Dict[str, jnp.ndarray]:
        cfg, shape = self.cfg, self.shape
        key = jax.random.key(self.seed * 100_003 + k)
        b, s = shape.global_batch, shape.seq_len
        c = self.n_clients
        m = b // c
        if cfg.family == "vlm":
            p = cfg.vlm_prefix_len
            k1, k2 = jax.random.split(key)
            return {
                "patches": jax.random.normal(k1, (c, m, p, cfg.d_model), jnp.float32),
                "tokens": synthetic.lm_token_stream(k2, c * m, s - p, cfg.vocab
                                                    ).reshape(c, m, s - p),
            }
        if cfg.audio_frontend:
            k1, k2, k3 = jax.random.split(key, 3)
            return {
                "frames": jax.random.normal(k1, (c, m, s, cfg.d_model), jnp.float32),
                "mask_positions": jax.random.bernoulli(k2, 0.08, (c, m, s)),
                "targets": jax.random.randint(k3, (c, m, s), 0, cfg.vocab),
            }
        toks = synthetic.lm_token_stream(key, c * m, s, cfg.vocab)
        return {"tokens": toks.reshape(c, m, s)}

    def stacked_batches(self, n_rounds: int) -> Dict[str, jnp.ndarray]:
        """All K round batches stacked on a leading axis: leaves are
        [K, C, m, ...]. This is the xs tensor the compiled scan driver
        (core/rounds.run_blade_fl_scan with ``stacked=True``) consumes —
        per-round streams stay deterministic (same round_batch(k) draws)
        while the whole horizon runs without host round-trips."""
        per_round = [self.round_batch(k) for k in range(n_rounds)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per_round)
