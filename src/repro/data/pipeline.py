"""Sharding-aware batching for training drivers.

The FL substrate consumes client-stacked batches [C, m, ...]; the pipeline
builds them deterministically per round (so experiments are reproducible and
the dry-run's ShapeDtypeStructs match real batches bit-for-shape).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data import synthetic


class FLDataSource:
    """Fixed per-client local datasets (paper: |D_i| = 512 samples each);
    each round every client does full-batch GD on its local shard."""

    def __init__(self, key, n_clients: int, samples_per_client: int,
                 dirichlet_alpha: float = 0.5, dataset: str = "mnist",
                 seed: int = 0):
        n_eval = 2048
        n_total = n_clients * samples_per_client * 2 + n_eval
        maker = synthetic.mnist_proxy if dataset == "mnist" else synthetic.fashion_proxy
        # one draw so train and eval share the SAME class templates
        full = maker(key, n_total)
        self.eval_data = {k: v[-n_eval:] for k, v in full.items()}
        self.data = {k: v[:-n_eval] for k, v in full.items()}
        part = synthetic.dirichlet_partition(
            np.asarray(self.data["y"]), n_clients, dirichlet_alpha,
            samples_per_client, seed=seed)
        self.client_data = synthetic.client_batches(self.data, part)

    def round_batch(self, k: int) -> Dict[str, jnp.ndarray]:
        # full local batch every round (paper does full-batch GD locally)
        return self.client_data

    def static_batch(self) -> Dict[str, jnp.ndarray]:
        """The [C, m, ...] batch every round reuses — feed this straight to
        ``run_blade_fl`` / ``run_blade_fl_scan`` to take the compiled
        multi-round path (no [K, ...] stacking needed: full-batch GD means
        the scan closes over one constant batch)."""
        return self.client_data


class LMDataSource:
    """Synthetic token streams for the assigned-architecture train runs,
    stacked on a leading client axis."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, n_clients: int,
                 seed: int = 0):
        self.cfg, self.shape, self.n_clients = cfg, shape, n_clients
        self.seed = seed

    def round_batch(self, k: int) -> Dict[str, jnp.ndarray]:
        cfg, shape = self.cfg, self.shape
        key = jax.random.key(self.seed * 100_003 + k)
        b, s = shape.global_batch, shape.seq_len
        c = self.n_clients
        m = b // c
        if cfg.family == "vlm":
            p = cfg.vlm_prefix_len
            k1, k2 = jax.random.split(key)
            return {
                "patches": jax.random.normal(k1, (c, m, p, cfg.d_model), jnp.float32),
                "tokens": synthetic.lm_token_stream(k2, c * m, s - p, cfg.vocab
                                                    ).reshape(c, m, s - p),
            }
        if cfg.audio_frontend:
            k1, k2, k3 = jax.random.split(key, 3)
            return {
                "frames": jax.random.normal(k1, (c, m, s, cfg.d_model), jnp.float32),
                "mask_positions": jax.random.bernoulli(k2, 0.08, (c, m, s)),
                "targets": jax.random.randint(k3, (c, m, s), 0, cfg.vocab),
            }
        toks = synthetic.lm_token_stream(key, c * m, s, cfg.vocab)
        return {"tokens": toks.reshape(c, m, s)}

    def stacked_batches(self, n_rounds: int) -> Dict[str, jnp.ndarray]:
        """All K round batches stacked on a leading axis: leaves are
        [K, C, m, ...]. This is the xs tensor the compiled scan driver
        (core/rounds.run_blade_fl_scan with ``stacked=True``) consumes —
        per-round streams stay deterministic (same round_batch(k) draws)
        while the whole horizon runs without host round-trips."""
        per_round = [self.round_batch(k) for k in range(n_rounds)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per_round)
