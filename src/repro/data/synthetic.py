"""Synthetic datasets (offline container — no downloads).

``mnist_proxy``: class-conditional Gaussian images with the MNIST interface
(28x28 grayscale, 10 classes). Each class has a fixed random template;
samples are template + noise, so the task is learnable and loss curves have
the qualitative structure the paper's experiments rely on (non-trivially
decreasing loss, client heterogeneity under non-IID splits).

``dirichlet_partition``: non-IID label split across N clients (Dir(alpha)),
the standard FL heterogeneity model — substitutes the paper's unspecified
"non-IID setting" with a controlled one.

``lm_token_stream``: deterministic synthetic token streams (Zipf-ish) for
the assigned-architecture smoke/e2e training runs.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


def mnist_proxy(key, n_samples: int, n_classes: int = 10,
                image_dim: int = 784, noise: float = 1.3,
                template_scale: float = 0.35) -> Dict[str, jnp.ndarray]:
    """Returns {"x": [n, image_dim] float32 in ~[0,1], "y": [n] int32}."""
    k_tmpl, k_lbl, k_noise = jax.random.split(key, 3)
    templates = jax.random.normal(k_tmpl, (n_classes, image_dim)) * template_scale
    y = jax.random.randint(k_lbl, (n_samples,), 0, n_classes)
    x = templates[y] + jax.random.normal(k_noise, (n_samples, image_dim)) * noise
    x = jax.nn.sigmoid(x)  # squash to (0, 1) like pixel intensities
    return {"x": x.astype(jnp.float32), "y": y.astype(jnp.int32)}


def fashion_proxy(key, n_samples: int, **kw) -> Dict[str, jnp.ndarray]:
    """Fashion-MNIST stand-in: same interface, harder (noisier) templates."""
    kw.setdefault("noise", 4.0)
    kw.setdefault("template_scale", 0.3)
    return mnist_proxy(key, n_samples, **kw)


def dirichlet_partition(y: np.ndarray, n_clients: int, alpha: float,
                        samples_per_client: int, seed: int = 0) -> np.ndarray:
    """Non-IID split: client i draws labels with proportions ~ Dir(alpha).

    Returns index array [n_clients, samples_per_client] into the dataset.
    """
    rng = np.random.default_rng(seed)
    y = np.asarray(y)
    n_classes = int(y.max()) + 1
    by_class = [np.flatnonzero(y == c) for c in range(n_classes)]
    for idx in by_class:
        rng.shuffle(idx)
    out = np.zeros((n_clients, samples_per_client), dtype=np.int64)
    for i in range(n_clients):
        props = rng.dirichlet(np.full(n_classes, alpha))
        counts = rng.multinomial(samples_per_client, props)
        chosen = []
        for c, k in enumerate(counts):
            pool = by_class[c]
            take = rng.choice(pool, size=k, replace=len(pool) < k)
            chosen.append(take)
        flat = np.concatenate(chosen)
        rng.shuffle(flat)
        out[i] = flat[:samples_per_client]
    return out


def client_batches(data: Dict[str, jnp.ndarray], partition: np.ndarray):
    """Stack per-client shards: {"x": [C, m, d], "y": [C, m]}."""
    idx = jnp.asarray(partition)
    return {k: v[idx] for k, v in data.items()}


def lm_token_stream(key, batch: int, seq_len: int, vocab: int,
                    zipf_a: float = 1.2) -> jnp.ndarray:
    """[batch, seq_len] int32, Zipf-distributed with local repetition
    structure so an LM has something to learn."""
    k1, k2 = jax.random.split(key)
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    probs = ranks ** (-zipf_a)
    probs = probs / probs.sum()
    toks = jax.random.choice(k1, vocab, (batch, seq_len), p=probs)
    # inject bigram structure: with p=0.3 repeat previous token + 1
    rep = jax.random.bernoulli(k2, 0.3, (batch, seq_len))
    shifted = jnp.roll(toks, 1, axis=1)
    toks = jnp.where(rep, (shifted + 1) % vocab, toks)
    return toks.astype(jnp.int32)
