from repro.models import (  # noqa: F401
    attention,
    layers,
    moe,
    registry,
    ssm,
    transformer,
    xlstm,
)
