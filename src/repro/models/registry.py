"""Uniform model API over all assigned architectures + batch spec builders.

``input_specs(cfg, shape, ...)`` returns jax.ShapeDtypeStruct stand-ins for
every model input of a given (architecture x input-shape) pair — the dry-run
lowers against these without allocating anything (see launch/dryrun.py).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer


def init_model(key, cfg: ModelConfig, dtype=jnp.float32):
    return transformer.init_lm(key, cfg, dtype)


def loss_fn(params, cfg: ModelConfig, batch, *, remat: bool = True,
            loss_chunk: int = 0):
    return transformer.train_loss(params, cfg, batch, remat=remat,
                                  loss_chunk=loss_chunk)


# ---------------------------------------------------------------------------
# Concrete batch builders (tests / examples, small shapes)
# ---------------------------------------------------------------------------


def make_train_batch(key, cfg: ModelConfig, shape: ShapeConfig,
                     dtype=jnp.float32) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.family == "vlm":
        p = cfg.vlm_prefix_len
        return {
            "patches": jax.random.normal(k1, (b, p, cfg.d_model), dtype),
            "tokens": jax.random.randint(k2, (b, s - p), 0, cfg.vocab),
        }
    if cfg.audio_frontend:
        mask = jax.random.bernoulli(k2, 0.08, (b, s))
        return {
            "frames": jax.random.normal(k1, (b, s, cfg.d_model), dtype),
            "mask_positions": mask,
            "targets": jax.random.randint(k3, (b, s), 0, cfg.vocab),
        }
    return {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab)}


def make_prefill_batch(key, cfg: ModelConfig, shape: ShapeConfig,
                       dtype=jnp.float32) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    k1, k2 = jax.random.split(key)
    if cfg.family == "vlm":
        p = cfg.vlm_prefix_len
        return {
            "patches": jax.random.normal(k1, (b, p, cfg.d_model), dtype),
            "tokens": jax.random.randint(k2, (b, s - p), 0, cfg.vocab),
        }
    if cfg.audio_frontend:
        return {"frames": jax.random.normal(k1, (b, s, cfg.d_model), dtype)}
    return {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab)}


# ---------------------------------------------------------------------------
# ShapeDtypeStruct specs (dry-run; no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16,
                      n_clients: int = 1) -> Dict[str, Any]:
    """Training batch specs. With n_clients > 1 the batch carries a leading
    client axis [C, B/C, ...] (BLADE-FL: clients own disjoint local data)."""
    b, s = shape.global_batch, shape.seq_len
    if b % n_clients != 0:
        raise ValueError(
            f"global_batch={b} must divide evenly over n_clients={n_clients}")
    lead = (n_clients, b // n_clients) if n_clients > 1 else (b,)
    if cfg.family == "vlm":
        p = cfg.vlm_prefix_len
        return {
            "patches": _sds(lead + (p, cfg.d_model), dtype),
            "tokens": _sds(lead + (s - p,), jnp.int32),
        }
    if cfg.audio_frontend:
        return {
            "frames": _sds(lead + (s, cfg.d_model), dtype),
            "mask_positions": _sds(lead + (s,), jnp.bool_),
            "targets": _sds(lead + (s,), jnp.int32),
        }
    return {"tokens": _sds(lead + (s,), jnp.int32)}


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                        dtype=jnp.bfloat16) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        p = cfg.vlm_prefix_len
        return {
            "patches": _sds((b, p, cfg.d_model), dtype),
            "tokens": _sds((b, s - p), jnp.int32),
        }
    if cfg.audio_frontend:
        return {"frames": _sds((b, s, cfg.d_model), dtype)}
    return {"tokens": _sds((b, s), jnp.int32)}


def decode_state_specs(cfg: ModelConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16):
    state = jax.eval_shape(
        lambda: transformer.init_decode_state(cfg, batch, max_len, dtype))
    return state


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16):
    b, s = shape.global_batch, shape.seq_len
    return {
        "token": _sds((b,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "state": decode_state_specs(cfg, b, s, dtype),
    }


def params_specs(cfg: ModelConfig, dtype=jnp.bfloat16, n_clients: int = 1):
    """abstract param shapes; with client axis when n_clients > 1."""
    p = jax.eval_shape(lambda: transformer.init_lm(jax.random.key(0), cfg, dtype))
    if n_clients > 1:
        p = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct((n_clients,) + a.shape, a.dtype), p)
    return p
