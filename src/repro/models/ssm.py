"""Mamba (S6) selective-state-space block: full-sequence scan + decode step.

State layout for decode: {"conv": [B, W-1, d_in], "h": [B, d_in, d_state]}.
The sequence recurrence uses lax.scan over time — TPU-friendly (small HLO,
bounded memory) where the GPU original fuses a parallel scan kernel; the
chunked-parallel variant is a §Perf lever (see EXPERIMENTS.md).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import layers


def _dims(cfg: ModelConfig):
    s = cfg.ssm or SSMConfig()
    d_in = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return s, d_in, dt_rank


def init_ssm(key, cfg: ModelConfig, dtype=jnp.float32):
    s, d_in, dt_rank = _dims(cfg)
    ks = jax.random.split(key, 6)
    a_init = jnp.log(jnp.broadcast_to(
        jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (d_in, s.d_state)))
    return {
        "w_in": layers.dense_init(ks[0], cfg.d_model, 2 * d_in, dtype),
        "conv": layers.causal_conv_init(ks[1], d_in, s.d_conv, dtype),
        "w_x": layers.dense_init(ks[2], d_in, dt_rank + 2 * s.d_state, dtype),
        "w_dt": layers.dense_init(ks[3], dt_rank, d_in, dtype),
        "dt_bias": jnp.full((d_in,), -4.6, dtype),  # softplus^-1(0.01)-ish
        "a_log": a_init.astype(dtype),
        "d_skip": jnp.ones((d_in,), dtype),
        "w_out": layers.dense_init(ks[4], d_in, cfg.d_model, dtype),
    }


def _use_scan_kernel() -> bool:
    import os
    return os.environ.get("REPRO_SSM_KERNEL", "0") == "1"


def _ssm_inner(params, cfg: ModelConfig, u: jnp.ndarray):
    """u: [B, T, d_in] (post conv+silu). Returns y: [B, T, d_in], final h.

    Default: lax.scan over time (state round-trips HBM every step — the
    jamba dry-run's dominant memory term). REPRO_SSM_KERNEL=1 switches to
    the VMEM-resident Pallas kernel (kernels/ssm_scan) on TPU.
    """
    s, d_in, dt_rank = _dims(cfg)
    proj = u @ params["w_x"]  # [B, T, dt_rank + 2*ds]
    dt = jax.nn.softplus(proj[..., :dt_rank] @ params["w_dt"]
                         + params["dt_bias"])                      # [B,T,d_in]
    bmat = proj[..., dt_rank: dt_rank + s.d_state]                 # [B,T,ds]
    cmat = proj[..., dt_rank + s.d_state:]                         # [B,T,ds]
    a = -jnp.exp(params["a_log"].astype(jnp.float32))              # [d_in,ds]

    if _use_scan_kernel():
        from repro.kernels.ssm_scan import selective_scan
        y, h_final = selective_scan(u, dt, bmat, cmat, a,
                                    params["d_skip"].astype(jnp.float32))
        return y.astype(u.dtype), h_final

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None] * a)                          # [B,d_in,ds]
        h = da * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    h0 = jnp.zeros((u.shape[0], d_in, s.d_state), jnp.float32)
    xs = (u.swapaxes(0, 1).astype(jnp.float32), dt.swapaxes(0, 1),
          bmat.swapaxes(0, 1).astype(jnp.float32), cmat.swapaxes(0, 1).astype(jnp.float32))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1) + u * params["d_skip"]
    return y.astype(u.dtype), h_final


def ssm_forward(params, cfg: ModelConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, dict]:
    """x: [B, T, D] -> (out [B, T, D], final state dict)."""
    s, d_in, _ = _dims(cfg)
    xz = x @ params["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)
    u = jax.nn.silu(layers.causal_conv_apply(params["conv"], u))
    y, h = _ssm_inner(params, cfg, u)
    out = (y * jax.nn.silu(z)) @ params["w_out"]
    # conv state holds the PRE-activation conv inputs (last W-1 raw u values)
    u_raw, _ = jnp.split(xz, 2, axis=-1)
    pad = jnp.pad(u_raw, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
    conv_state = pad[:, -(s.d_conv - 1):, :]
    return out, {"conv": conv_state, "h": h}


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s, d_in, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, d_in), dtype),
        "h": jnp.zeros((batch, d_in, s.d_state), jnp.float32),
    }


def ssm_decode(params, cfg: ModelConfig, x_t: jnp.ndarray, state: dict):
    """x_t: [B, D] single step."""
    s, d_in, dt_rank = _dims(cfg)
    xz = x_t @ params["w_in"]
    u_raw, z = jnp.split(xz, 2, axis=-1)
    u_c, conv_state = layers.causal_conv_step(params["conv"], state["conv"], u_raw)
    u = jax.nn.silu(u_c)
    proj = u @ params["w_x"]
    dt = jax.nn.softplus(proj[..., :dt_rank] @ params["w_dt"] + params["dt_bias"])
    b_t = proj[..., dt_rank: dt_rank + s.d_state].astype(jnp.float32)
    c_t = proj[..., dt_rank + s.d_state:].astype(jnp.float32)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    da = jnp.exp(dt.astype(jnp.float32)[..., None] * a)
    h = da * state["h"] + (dt * u).astype(jnp.float32)[..., None] * b_t[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, c_t).astype(x_t.dtype) + u * params["d_skip"]
    out = (y * jax.nn.silu(z)) @ params["w_out"]
    return out, {"conv": conv_state, "h": h}
