"""Attention: GQA (with qk-norm, sliding window, prefix-LM masks) and
DeepSeek-style MLA, each with full-sequence forward and single-step decode.

KV caches:
  GQA   : {"k": [B, S_cache, Hkv, hd], "v": [B, S_cache, Hkv, hd]}
          (S_cache = sliding_window when windowed: ring buffer)
  MLA   : {"ckv": [B, S_cache, kv_lora], "k_rope": [B, S_cache, rope_dim]}

Decode attention over a sequence-sharded cache relies on XLA-SPMD partial
softmax reductions (max/sum over the sharded length axis lower to
all-reduces); see DESIGN.md §3 and the roofline notes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32):
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    if cfg.mla is not None:
        m = cfg.mla
        p = {
            "w_dkv": layers.dense_init(ks[0], d, m.kv_lora + m.rope_dim, dtype),
            "kv_norm": layers.rms_norm_init(m.kv_lora, dtype),
            "w_uk": layers.dense_init(ks[1], m.kv_lora, cfg.n_heads * hd, dtype),
            "w_uv": layers.dense_init(ks[2], m.kv_lora, cfg.n_heads * hd, dtype),
            "w_o": layers.dense_init(ks[3], cfg.n_heads * hd, d, dtype),
        }
        if m.q_lora:
            p["w_dq"] = layers.dense_init(ks[4], d, m.q_lora, dtype)
            p["q_norm"] = layers.rms_norm_init(m.q_lora, dtype)
            p["w_uq"] = layers.dense_init(ks[5], m.q_lora, cfg.n_heads * (hd + m.rope_dim), dtype)
        else:
            p["w_uq"] = layers.dense_init(ks[5], d, cfg.n_heads * (hd + m.rope_dim), dtype)
        return p
    p = {
        "w_q": layers.dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "w_k": layers.dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "w_v": layers.dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "w_o": layers.dense_init(ks[3], cfg.n_heads * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.rms_norm_init(hd, dtype)
        p["k_norm"] = layers.rms_norm_init(hd, dtype)
    return p


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    hd = cfg.resolved_head_dim
    s = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jnp.zeros((batch, s, m.kv_lora), dtype),
            "k_rope": jnp.zeros((batch, s, m.rope_dim), dtype),
        }
    return {
        "k": jnp.zeros((batch, s, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, s, cfg.n_kv_heads, hd), dtype),
    }


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------


def build_mask(seq: int, *, causal: bool, prefix_len: int = 0,
               sliding_window: int = 0) -> jnp.ndarray:
    """[seq, seq] additive mask (0 or NEG_INF)."""
    i = jnp.arange(seq)[:, None]
    j = jnp.arange(seq)[None, :]
    if causal:
        ok = j <= i
        if prefix_len:
            ok = ok | ((i < prefix_len) & (j < prefix_len))
        if sliding_window:
            ok = ok & (j > i - sliding_window)
    else:
        ok = jnp.ones((seq, seq), bool)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Core attention math (shared by GQA / MLA paths)
# ---------------------------------------------------------------------------


def _sdpa(q, k, v, mask, scale):
    """q: [B,S,H,hd]; k,v: [B,T,H,hd]; mask: [S,T] or [B,S,T] additive."""
    logits = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    logits = logits + (mask if mask.ndim == 2 else mask[:, None])
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


# S above which the XLA (non-Pallas) path switches to the q-chunked form
SDPA_CHUNK_THRESHOLD = 4096
SDPA_CHUNK = 1024


def _sdpa_chunked(q, k, v, scale, *, causal: bool, window: int,
                  prefix_len: int, chunk: int = SDPA_CHUNK):
    """Flash-style attention in pure XLA (§Perf iteration A1): scan over
    query chunks so the score matrix is [B,H,chunk,S] instead of
    [B,H,S,S], and the mask is built per chunk from index arithmetic
    instead of materializing [S,S]. Numerics identical to _sdpa (full-row
    softmax per chunk). Used for S >= SDPA_CHUNK_THRESHOLD — at 32k the
    full form needs TBs of temp per device; the Pallas kernel
    (kernels/flash_attention) is the TPU production path, this is the
    compile-anywhere fallback with the same memory shape."""
    b, s, h, hd = q.shape
    while s % chunk:
        chunk //= 2
    n_chunks = s // chunk
    qs = q.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    cols = jnp.arange(s)

    def one_chunk(carry, inp):
        qc, ci = inp                                  # [B,chunk,H,hd], scalar
        rows = ci * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bshd,bthd->bhst", qc, k).astype(jnp.float32) * scale
        ok = jnp.ones((chunk, s), bool)
        if causal:
            ok = cols[None, :] <= rows[:, None]
            if prefix_len:
                ok = ok | ((rows[:, None] < prefix_len) & (cols[None, :] < prefix_len))
            if window > 0:
                ok = ok & (cols[None, :] > rows[:, None] - window)
        logits = jnp.where(ok[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhst,bthd->bshd", probs, v)
        return carry, out

    _, outs = jax.lax.scan(one_chunk, 0,
                           (qs, jnp.arange(n_chunks, dtype=jnp.int32)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


def _repeat_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=2)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def _dispatch_sdpa(q, kk, vv, scale, mask_info: dict):
    """Dense [S,S]-mask SDPA for short sequences; q-chunked online form for
    long ones (never materializes [S,S] scores or mask)."""
    s = q.shape[1]
    if s < SDPA_CHUNK_THRESHOLD:
        mask = build_mask(s, causal=mask_info["causal"],
                          prefix_len=mask_info.get("prefix_len", 0),
                          sliding_window=mask_info.get("window", 0))
        return _sdpa(q, kk, vv, mask, scale)
    return _sdpa_chunked(q, kk, vv, scale, causal=mask_info["causal"],
                         window=mask_info.get("window", 0),
                         prefix_len=mask_info.get("prefix_len", 0))


def gqa_forward(params, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray,
                mask_info: dict) -> Tuple[jnp.ndarray, dict]:
    """Full-sequence forward. Returns (out, kv) where kv feeds cache fill."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["w_q"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ params["w_k"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ params["w_v"]).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = layers.rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = layers.rms_norm(params["k_norm"], k, cfg.norm_eps)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    out = _dispatch_sdpa(q, _repeat_kv(k, cfg.n_heads),
                         _repeat_kv(v, cfg.n_heads), hd ** -0.5, mask_info)
    out = out.reshape(b, s, cfg.n_heads * hd) @ params["w_o"]
    return out, {"k": k, "v": v}


def gqa_decode(params, cfg: ModelConfig, x_t: jnp.ndarray, pos: jnp.ndarray,
               cache: dict, cache_len: Optional[int] = None):
    """Single-token decode. x_t: [B, d]; pos: scalar int32 (current position).

    Cache is a ring buffer when cfg.sliding_window > 0 (S_cache == window).
    Attention masks out unwritten / out-of-window slots by comparing each
    slot's stored absolute position.
    """
    b = x_t.shape[0]
    hd = cfg.resolved_head_dim
    s_cache = cache["k"].shape[1]
    q = (x_t @ params["w_q"]).reshape(b, 1, cfg.n_heads, hd)
    k = (x_t @ params["w_k"]).reshape(b, 1, cfg.n_kv_heads, hd)
    v = (x_t @ params["w_v"]).reshape(b, 1, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = layers.rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = layers.rms_norm(params["k_norm"], k, cfg.norm_eps)
    posv = jnp.full((b, 1), pos, dtype=jnp.int32)
    q = layers.apply_rope(q, posv, cfg.rope_theta)
    k = layers.apply_rope(k, posv, cfg.rope_theta)
    slot = (pos % s_cache) if cfg.sliding_window else pos
    new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    # absolute position stored in each slot (ring-buffer aware)
    idx = jnp.arange(s_cache)
    if cfg.sliding_window:
        wraps = (pos // s_cache) + (idx <= (pos % s_cache))  # completed writes
        abs_pos = (wraps - 1) * s_cache + idx
        valid = (abs_pos >= 0) & (abs_pos <= pos) & (abs_pos > pos - cfg.sliding_window)
    else:
        valid = idx <= pos
    mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)  # [T]
    kk = _repeat_kv(new_k, cfg.n_heads)
    vv = _repeat_kv(new_v, cfg.n_heads)
    logits = jnp.einsum("bohd,bthd->bhot", q, kk).astype(jnp.float32) * hd ** -0.5
    logits = logits + mask[None, None, None, :]
    probs = jax.nn.softmax(logits, axis=-1).astype(vv.dtype)
    out = jnp.einsum("bhot,bthd->bohd", probs, vv)
    out = out.reshape(b, cfg.n_heads * hd) @ params["w_o"]
    return out, {"k": new_k, "v": new_v}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------


def _mla_q(params, cfg, x, positions):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    m = cfg.mla
    if m.q_lora:
        cq = layers.rms_norm(params["q_norm"], x @ params["w_dq"], cfg.norm_eps)
        q = (cq @ params["w_uq"]).reshape(b, s, cfg.n_heads, hd + m.rope_dim)
    else:
        q = (x @ params["w_uq"]).reshape(b, s, cfg.n_heads, hd + m.rope_dim)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv(params, cfg, x, positions):
    m = cfg.mla
    dkv = x @ params["w_dkv"]
    ckv = layers.rms_norm(params["kv_norm"], dkv[..., : m.kv_lora], cfg.norm_eps)
    k_rope = layers.apply_rope(dkv[..., m.kv_lora:][..., None, :], positions,
                               cfg.rope_theta)[..., 0, :]
    return ckv, k_rope


def _mla_attend(params, cfg, q_nope, q_rope, ckv, k_rope, mask):
    """Latent-space attention: scores via absorbed projections.

    q_nope: [B,S,H,hd]; q_rope: [B,S,H,r]; ckv: [B,T,kv_lora]; k_rope: [B,T,r].
    """
    b, s, h, hd = q_nope.shape
    m = cfg.mla
    w_uk = params["w_uk"].reshape(m.kv_lora, h, hd)
    # absorb W_uk into the query: q_lat [B,S,H,kv_lora]
    q_lat = jnp.einsum("bshd,lhd->bshl", q_nope, w_uk)
    scores = jnp.einsum("bshl,btl->bhst", q_lat, ckv)
    scores = scores + jnp.einsum("bshr,btr->bhst", q_rope, k_rope)
    scale = (hd + m.rope_dim) ** -0.5
    scores = scores.astype(jnp.float32) * scale
    scores = scores + (mask if mask.ndim == 2 else mask[:, None])
    probs = jax.nn.softmax(scores, axis=-1).astype(ckv.dtype)
    # values in latent space, then up-project: [B,S,H,kv_lora] -> [B,S,H,hd]
    o_lat = jnp.einsum("bhst,btl->bshl", probs, ckv)
    w_uv = params["w_uv"].reshape(m.kv_lora, h, hd)
    out = jnp.einsum("bshl,lhd->bshd", o_lat, w_uv)
    return out.reshape(b, s, h * hd) @ params["w_o"]


def _mla_attend_materialized(params, cfg, q_nope, q_rope, ckv, k_rope,
                             mask_info: dict):
    """Training/prefill form: reconstruct per-head K/V from the latent ONCE
    (O(S) up-projections), then standard SDPA — the S^2 score/value terms
    cost H*(hd+rope) = 192 per pair instead of the absorbed form's
    H*(kv_lora+rope) = 576. DeepSeek-V2 absorbs only at decode, where the
    latent cache (not flops) is the win; doing the same here cut the
    compiled train-step FLOPs ~2.8x (EXPERIMENTS.md §Perf iteration D1)."""
    b, s, h, hd = q_nope.shape
    m = cfg.mla
    w_uk = params["w_uk"].reshape(m.kv_lora, h, hd)
    w_uv = params["w_uv"].reshape(m.kv_lora, h, hd)
    k_nope = jnp.einsum("btl,lhd->bthd", ckv, w_uk)
    v = jnp.einsum("btl,lhd->bthd", ckv, w_uv)
    scale = (hd + m.rope_dim) ** -0.5
    # fold the decoupled-rope key into the head dim so the chunked SDPA
    # dispatcher handles short and long sequences uniformly
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, k_rope.shape[1], h, m.rope_dim))], axis=-1)
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, m.rope_dim)))
    out = _dispatch_sdpa(q_cat, k_cat, v_pad, scale, mask_info)[..., :hd]
    return out.reshape(b, s, h * hd) @ params["w_o"]


def mla_forward(params, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray,
                mask_info: dict, *, absorbed: Optional[bool] = None):
    import os as _os

    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    ckv, k_rope = _mla_kv(params, cfg, x, positions)
    if absorbed is None:
        absorbed = _os.environ.get("REPRO_MLA_ABSORBED", "0") == "1"
    if absorbed:  # ablation path (D1 baseline): dense mask, latent scores
        mask = build_mask(x.shape[1], causal=mask_info["causal"],
                          prefix_len=mask_info.get("prefix_len", 0),
                          sliding_window=mask_info.get("window", 0))
        out = _mla_attend(params, cfg, q_nope, q_rope, ckv, k_rope, mask)
    else:
        out = _mla_attend_materialized(params, cfg, q_nope, q_rope, ckv,
                                       k_rope, mask_info)
    return out, {"ckv": ckv, "k_rope": k_rope}


def mla_decode(params, cfg: ModelConfig, x_t: jnp.ndarray, pos: jnp.ndarray,
               cache: dict):
    b = x_t.shape[0]
    posv = jnp.full((b, 1), pos, dtype=jnp.int32)
    q_nope, q_rope = _mla_q(params, cfg, x_t[:, None, :], posv)
    ckv_t, k_rope_t = _mla_kv(params, cfg, x_t[:, None, :], posv)
    new_ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_t, pos, axis=1)
    new_kr = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope_t, pos, axis=1)
    t = new_ckv.shape[1]
    mask = jnp.where(jnp.arange(t) <= pos, 0.0, NEG_INF).astype(jnp.float32)[None, :]
    out = _mla_attend(params, cfg, q_nope, q_rope, new_ckv, new_kr, mask)
    return out[:, 0, :], {"ckv": new_ckv, "k_rope": new_kr}


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


def attn_forward(params, cfg, x, positions, mask_info):
    if cfg.mla is not None:
        return mla_forward(params, cfg, x, positions, mask_info)
    return gqa_forward(params, cfg, x, positions, mask_info)


def attn_decode(params, cfg, x_t, pos, cache):
    if cfg.mla is not None:
        return mla_decode(params, cfg, x_t, pos, cache)
    return gqa_decode(params, cfg, x_t, pos, cache)
