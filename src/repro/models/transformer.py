"""Unified model: decoder LMs (dense/MoE/MLA), hybrid SSM stacks, xLSTM,
encoder-only (audio), and prefix-LM VLM — one init/apply family driven by
ModelConfig.

Layer layout: ``n_dense_prefix`` unrolled blocks, then the remaining layers
grouped into periods of ``cfg.pattern`` and scanned with lax.scan (stacked
params, leading axis = n_periods). This keeps the HLO small enough to compile
64-layer models on the 512-device dry-run mesh, and remat (jax.checkpoint) on
the period body bounds activation memory.

Public API:
  init_lm(key, cfg, dtype)                       -> params
  train_loss(params, cfg, batch)                 -> (loss, metrics)
  prefill(params, cfg, batch)                    -> (logits_last, decode_state)
  decode_step(params, cfg, state, token, pos)    -> (logits, state)
  init_decode_state(cfg, batch, max_len, dtype)  -> state
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, layers, moe as moe_lib, ssm as ssm_lib, xlstm as xlstm_lib

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Structure helpers
# ---------------------------------------------------------------------------


def _n_periods(cfg: ModelConfig) -> int:
    body = cfg.n_layers - cfg.n_dense_prefix
    pat = len(cfg.pattern)
    assert body % pat == 0, f"{cfg.name}: {body} layers not divisible by pattern {pat}"
    return body // pat


def _uses_moe(cfg: ModelConfig, layer_idx: int) -> bool:
    if cfg.moe is None or layer_idx < cfg.n_dense_prefix:
        return False
    return layer_idx % cfg.moe.every == cfg.moe.every - 1


def _kind_at(cfg: ModelConfig, layer_idx: int) -> str:
    if layer_idx < cfg.n_dense_prefix:
        return "attn"
    j = (layer_idx - cfg.n_dense_prefix) % len(cfg.pattern)
    return cfg.pattern[j]


def _check_static_period(cfg: ModelConfig) -> None:
    """MoE placement must be identical in every period so params can stack."""
    if cfg.moe is not None and cfg.moe.every > 1:
        assert len(cfg.pattern) % cfg.moe.every == 0 or len(cfg.pattern) == 1, (
            f"{cfg.name}: moe.every={cfg.moe.every} incompatible with "
            f"pattern length {len(cfg.pattern)}")


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

_MIXER_INIT = {
    "attn": attention.init_attention,
    "ssm": ssm_lib.init_ssm,
    "mlstm": xlstm_lib.init_mlstm,
    "slstm": xlstm_lib.init_slstm,
}


def _init_block(key, cfg: ModelConfig, kind: str, use_moe: bool, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {
        "norm1": layers.rms_norm_init(cfg.d_model, dtype),
        "mixer": _MIXER_INIT[kind](k1, cfg, dtype),
    }
    if use_moe:
        p["norm2"] = layers.rms_norm_init(cfg.d_model, dtype)
        p["moe"] = moe_lib.init_moe(k2, cfg, dtype)
    elif cfg.d_ff > 0:
        p["norm2"] = layers.rms_norm_init(cfg.d_model, dtype)
        p["mlp"] = layers.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
    return p


def _block_forward(p: Params, cfg: ModelConfig, kind: str, x, positions, mask,
                   want_cache: bool):
    """Full-sequence block. Returns (x, aux, cache_or_None)."""
    h = layers.rms_norm(p["norm1"], x, cfg.norm_eps)
    cache = None
    if kind == "attn":
        out, kv = attention.attn_forward(p["mixer"], cfg, h, positions, mask)
        if want_cache:
            cache = kv
    elif kind == "ssm":
        out, st = ssm_lib.ssm_forward(p["mixer"], cfg, h)
        if want_cache:
            cache = st
    elif kind == "mlstm":
        out, st = xlstm_lib.mlstm_forward(p["mixer"], cfg, h)
        if want_cache:
            cache = st
    else:  # slstm
        out, st = xlstm_lib.slstm_forward(p["mixer"], cfg, h)
        if want_cache:
            cache = st
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        h2 = layers.rms_norm(p["norm2"], x, cfg.norm_eps)
        out2, aux = moe_lib.moe_apply(p["moe"], cfg, h2)
        x = x + out2
    elif "mlp" in p:
        h2 = layers.rms_norm(p["norm2"], x, cfg.norm_eps)
        x = x + layers.mlp_apply(p["mlp"], h2, cfg.mlp)
    return x, aux, cache


def _block_decode(p: Params, cfg: ModelConfig, kind: str, x_t, pos, cache):
    h = layers.rms_norm(p["norm1"], x_t, cfg.norm_eps)
    if kind == "attn":
        out, cache = attention.attn_decode(p["mixer"], cfg, h, pos, cache)
    elif kind == "ssm":
        out, cache = ssm_lib.ssm_decode(p["mixer"], cfg, h, cache)
    elif kind == "mlstm":
        out, cache = xlstm_lib.mlstm_decode(p["mixer"], cfg, h, cache)
    else:
        out, cache = xlstm_lib.slstm_decode(p["mixer"], cfg, h, cache)
    x_t = x_t + out
    if "moe" in p:
        h2 = layers.rms_norm(p["norm2"], x_t, cfg.norm_eps)
        out2, _ = moe_lib.moe_apply(p["moe"], cfg, h2[:, None, :])
        x_t = x_t + out2[:, 0, :]
    elif "mlp" in p:
        h2 = layers.rms_norm(p["norm2"], x_t, cfg.norm_eps)
        x_t = x_t + layers.mlp_apply(p["mlp"], h2, cfg.mlp)
    return x_t, cache


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------


def init_lm(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    _check_static_period(cfg)
    n_per = _n_periods(cfg)
    pat = cfg.pattern
    keys = jax.random.split(key, 4 + cfg.n_dense_prefix)
    params: Params = {
        "embed": layers.embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": layers.rms_norm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(keys[1], cfg.d_model, cfg.vocab, dtype)
    if cfg.audio_frontend:
        params["mask_emb"] = (jax.random.normal(keys[2], (cfg.d_model,)) * 0.02).astype(dtype)
        params["pos_conv"] = layers.causal_conv_init(keys[3], cfg.d_model, 4, dtype)
    # unrolled dense-prefix blocks
    prefix = []
    for i in range(cfg.n_dense_prefix):
        prefix.append(_init_block(keys[4 + i], cfg, "attn", False, dtype))
    if prefix:
        params["prefix"] = prefix
    # scanned periods: for each j in pattern, stack block params over periods
    period: Dict[str, Params] = {}
    for j, kind in enumerate(pat):
        layer0 = cfg.n_dense_prefix + j
        use_moe = _uses_moe(cfg, layer0)
        subkeys = jax.random.split(jax.random.fold_in(key, 1000 + j), n_per)
        blocks = [_init_block(subkeys[p], cfg, kind, use_moe, dtype)
                  for p in range(n_per)]
        period[f"j{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    params["period"] = period
    return params


# ---------------------------------------------------------------------------
# Input assembly
# ---------------------------------------------------------------------------


def _embed_inputs(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    """Returns (x [B,S,D], labels or None, loss_mask or None)."""
    emb = params["embed"]
    if cfg.family == "vlm":
        patches = batch["patches"].astype(emb.dtype)       # [B, P, D]
        tokens = batch["tokens"]                           # [B, S_txt]
        tok_emb = emb[tokens]
        x = jnp.concatenate([patches, tok_emb], axis=1)
        labels = batch.get("labels")
        if labels is not None:
            b, p, _ = patches.shape
            pad = jnp.zeros((b, p), labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros((b, p), jnp.float32), jnp.ones_like(batch["labels"], jnp.float32)],
                axis=1)
            return x, labels, mask
        return x, None, None
    if cfg.audio_frontend:
        frames = batch["frames"].astype(emb.dtype)          # [B, S, D]
        if "mask_positions" in batch:
            m = batch["mask_positions"][..., None].astype(emb.dtype)
            frames = frames * (1 - m) + params["mask_emb"] * m
        x = frames + layers.causal_conv_apply(params["pos_conv"], frames)
        labels = batch.get("targets")
        mask = batch.get("mask_positions")
        mask = mask.astype(jnp.float32) if mask is not None else None
        return x, labels, mask
    tokens = batch["tokens"]
    return emb[tokens], batch.get("labels"), batch.get("loss_mask")


# ---------------------------------------------------------------------------
# Full forward
# ---------------------------------------------------------------------------


def forward(params: Params, cfg: ModelConfig, x: jnp.ndarray, *,
            want_cache: bool = False, remat: bool = True,
            sliding_window: Optional[int] = None):
    """x: [B, S, D] embeddings -> (hidden [B,S,D], aux, caches)."""
    b, s, _ = x.shape
    window = cfg.sliding_window if sliding_window is None else sliding_window
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    # mask described symbolically; attention materializes a dense [S,S]
    # mask only below the chunked-SDPA threshold (A1)
    mask = {
        "causal": cfg.causal,
        "prefix_len": cfg.vlm_prefix_len if cfg.family == "vlm" else 0,
        "window": window,
    }
    aux_total = jnp.zeros((), jnp.float32)
    prefix_caches = []
    for blk in params.get("prefix", []):
        x, aux, c = _block_forward(blk, cfg, "attn", x, positions, mask, want_cache)
        aux_total = aux_total + aux
        prefix_caches.append(c)

    pat = cfg.pattern

    def period_body(carry, period_params):
        x, aux_acc = carry
        caches = {}
        for j, kind in enumerate(pat):
            x, aux, c = _block_forward(period_params[f"j{j}"], cfg, kind, x,
                                       positions, mask, want_cache)
            aux_acc = aux_acc + aux
            if want_cache:
                caches[f"j{j}"] = c
        return (x, aux_acc), caches if want_cache else None

    body = jax.checkpoint(period_body) if remat else period_body
    (x, aux_total), period_caches = jax.lax.scan(
        body, (x, aux_total), params["period"])
    x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, aux_total, {"prefix": prefix_caches, "period": period_caches}


def _lm_head(params: Params, cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ w


def chunked_ce_loss(params, cfg: ModelConfig, h, labels, loss_mask,
                    chunk: int = 0):
    """Cross-entropy without materializing [B, S, V]: scan over seq chunks."""
    b, s, d = h.shape
    if chunk <= 0:
        # pick chunk so B*chunk*V*4 bytes <~ 256MB
        chunk = max(1, min(s, int(256e6 / max(b * cfg.vocab * 4, 1))))
        while s % chunk:
            chunk -= 1
    n_chunks = s // chunk
    hs = h.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    if loss_mask is None:
        loss_mask = jnp.ones((b, s), jnp.float32)
    ms = loss_mask.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def body(acc, inp):
        hc, lc, mc = inp
        logits = _lm_head(params, cfg, hc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return (acc[0] + nll.sum(), acc[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)), (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Train loss
# ---------------------------------------------------------------------------


def train_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
               *, remat: bool = True, loss_chunk: int = 0):
    """Causal-LM / prefix-LM / masked-prediction loss depending on family."""
    if cfg.family == "vlm":
        tokens = batch["tokens"]
        b = {"patches": batch["patches"], "tokens": tokens[:, :-1],
             "labels": tokens[:, 1:]}
        # label at position p predicts tokens[p+1]; image prefix predicts first text token
        x, labels, mask = _embed_inputs(params, cfg, b)
    elif cfg.audio_frontend:
        x, labels, mask = _embed_inputs(params, cfg, batch)
    else:
        tokens = batch["tokens"]
        x, _, _ = _embed_inputs(params, cfg, {"tokens": tokens[:, :-1]})
        labels = tokens[:, 1:]
        mask = batch.get("loss_mask")
        if mask is not None:
            mask = mask[:, 1:]
    h, aux, _ = forward(params, cfg, x, want_cache=False, remat=remat)
    ce = chunked_ce_loss(params, cfg, h, labels, mask, loss_chunk)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def _cache_struct(cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype):
    if kind == "attn":
        return attention.init_cache(cfg, batch, max_len, dtype)
    if kind == "ssm":
        return ssm_lib.init_state(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm_lib.init_mlstm_state(cfg, batch, dtype)
    return xlstm_lib.init_slstm_state(cfg, batch, dtype)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    n_per = _n_periods(cfg)
    state: Params = {}
    if cfg.n_dense_prefix:
        state["prefix"] = [
            _cache_struct(cfg, "attn", batch, max_len, dtype)
            for _ in range(cfg.n_dense_prefix)
        ]
    period = {}
    for j, kind in enumerate(cfg.pattern):
        one = _cache_struct(cfg, kind, batch, max_len, dtype)
        period[f"j{j}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_per,) + a.shape).copy(), one)
    state["period"] = period
    return state


def _fill_attn_cache(cfg: ModelConfig, kv: dict, max_len: int,
                     seq_axis: int = 1):
    """Convert a full-forward kv dict into a decode cache of capacity
    max_len. ``seq_axis`` is 1 for per-layer caches, 2 when the leaves carry
    a leading period-stack axis ([n_per, B, S, ...])."""
    def fill(x):
        s = x.shape[seq_axis]
        if cfg.sliding_window and cfg.sliding_window < s:
            w = cfg.sliding_window
            idx = [slice(None)] * x.ndim
            idx[seq_axis] = slice(s - w, s)
            last = x[tuple(idx)]
            return jnp.roll(last, s % w, axis=seq_axis)
        if s < max_len:
            pad = [(0, 0)] * x.ndim
            pad[seq_axis] = (0, max_len - s)
            return jnp.pad(x, pad)
        return x
    return jax.tree.map(fill, kv)


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], *,
            max_len: int = 0, remat: bool = False,
            sliding_window: Optional[int] = None):
    """Run the full prompt; return (last-token logits, decode state)."""
    x, _, _ = _embed_inputs(params, cfg, batch)
    s = x.shape[1]
    max_len = max_len or s
    h, _, caches = forward(params, cfg, x, want_cache=True, remat=remat,
                           sliding_window=sliding_window)
    logits = _lm_head(params, cfg, h[:, -1, :])

    def finalize(kind, c, seq_axis):
        if kind == "attn":
            return _fill_attn_cache(cfg, c, max_len, seq_axis)
        return c  # recurrent states are already final

    state: Params = {}
    if caches["prefix"]:
        state["prefix"] = [finalize("attn", c, 1) for c in caches["prefix"]]
    period = {}
    for j, kind in enumerate(cfg.pattern):
        # period-stacked leaves: [n_per, B, S, ...] -> seq axis 2
        period[f"j{j}"] = finalize(kind, caches["period"][f"j{j}"], 2)
    state["period"] = period
    return logits, state


def decode_step(params: Params, cfg: ModelConfig, state: Params,
                token: jnp.ndarray, pos: jnp.ndarray):
    """token: [B] int32; pos: scalar int32. Returns (logits [B,V], state)."""
    x_t = params["embed"][token]
    new_prefix = []
    for blk, cache in zip(params.get("prefix", []), state.get("prefix", [])):
        x_t, cache = _block_decode(blk, cfg, "attn", x_t, pos, cache)
        new_prefix.append(cache)

    pat = cfg.pattern

    def body(x_t, inp):
        period_params, period_cache = inp
        new_cache = {}
        for j, kind in enumerate(pat):
            x_t, c = _block_decode(period_params[f"j{j}"], cfg, kind, x_t, pos,
                                   period_cache[f"j{j}"])
            new_cache[f"j{j}"] = c
        return x_t, new_cache

    x_t, new_period = jax.lax.scan(body, x_t, (params["period"], state["period"]))
    x_t = layers.rms_norm(params["final_norm"], x_t, cfg.norm_eps)
    logits = _lm_head(params, cfg, x_t)
    out_state: Params = {"period": new_period}
    if new_prefix:
        out_state["prefix"] = new_prefix
    return logits, out_state
