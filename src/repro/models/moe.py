"""Mixture-of-Experts MLP with capacity-based scatter dispatch.

Expert weights are stacked [E, ...] and sharded over the 'model' mesh axis
(expert parallelism). Dispatch scatters tokens into per-expert slots
[E, C, D]; XLA-SPMD partitions the scatter/gather onto expert shards and the
combine gather lowers to a masked local gather + all-reduce — the
all-to-all-like collective the roofline tracks for MoE archs.

Slot assignment loops over the k routing choices (k <= 8) so the transient
one-hot is only [T, E] per step (never [T, E, C]).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    gated = cfg.mlp in ("swiglu", "geglu")
    scale = d ** -0.5

    def stack(k, e, din, dout):
        return (jax.random.normal(k, (e, din, dout)) * scale).astype(dtype)

    p = {
        "router": layers.dense_init(ks[0], d, m.n_experts, jnp.float32),
        "w_in": stack(ks[1], m.n_experts, d, m.d_ff),
        "w_out": stack(ks[2], m.n_experts, m.d_ff, d),
    }
    if gated:
        p["w_gate"] = stack(ks[3], m.n_experts, d, m.d_ff)
    if m.n_shared:
        p["shared"] = layers.mlp_init(ks[4], d, m.n_shared * m.d_ff, cfg.mlp, dtype)
    return p


def _expert_ffn(p, h: jnp.ndarray, kind: str) -> jnp.ndarray:
    """h: [E, C, D] -> [E, C, D] through per-expert FFN (batched einsum)."""
    up = jnp.einsum("ecd,edf->ecf", h, p["w_in"])
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        up = act(jnp.einsum("ecd,edf->ecf", h, p["w_gate"])) * up
    elif kind == "squared_relu":
        up = jnp.square(jax.nn.relu(up))
    else:
        up = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", up, p["w_out"])


def moe_apply(params, cfg: ModelConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits = (xt.astype(jnp.float32) @ params["router"])  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)  # [T, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = max(int(t * m.top_k * m.capacity_factor / m.n_experts), 4)

    # --- slot assignment, one routing choice at a time --------------------
    counts = jnp.zeros((m.n_experts,), jnp.int32)
    slot_list, keep_list = [], []
    for j in range(m.top_k):
        e_j = gate_idx[:, j]                                   # [T]
        onehot = jax.nn.one_hot(e_j, m.n_experts, dtype=jnp.int32)
        ranks = jnp.cumsum(onehot, axis=0) - 1                 # rank among this choice
        slot = jnp.take_along_axis(ranks, e_j[:, None], axis=1)[:, 0] + counts[e_j]
        keep = slot < capacity
        slot_list.append(jnp.where(keep, slot, capacity))      # cap as scratch slot
        keep_list.append(keep)
        counts = counts + onehot.sum(axis=0)
    slots = jnp.stack(slot_list, 1)                            # [T, k]
    keeps = jnp.stack(keep_list, 1)                            # [T, k]

    # --- dispatch: scatter tokens into [E, C+1, D] (slot C = overflow bin) -
    buf = jnp.zeros((m.n_experts, capacity + 1, d), x.dtype)
    flat_e = gate_idx.reshape(-1)
    flat_slot = slots.reshape(-1)
    flat_x = jnp.repeat(xt[:, None, :], m.top_k, axis=1).reshape(-1, d)
    buf = buf.at[flat_e, flat_slot].set(flat_x, mode="drop")
    expert_out = _expert_ffn(params, buf[:, :capacity], cfg.mlp)  # [E, C, D]
    expert_out = jnp.pad(expert_out, ((0, 0), (0, 1), (0, 0)))    # overflow -> 0

    # --- combine: gather back, weight by (renormalized) gates -------------
    gathered = expert_out[flat_e, flat_slot].reshape(t, m.top_k, d)
    w = (gate_vals * keeps.astype(gate_vals.dtype)).astype(x.dtype)
    out = jnp.einsum("tkd,tk->td", gathered, w)

    if m.n_shared:
        out = out + layers.mlp_apply(params["shared"], xt, cfg.mlp)

    # --- load-balance aux loss (Switch-style) ------------------------------
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx[:, 0], m.n_experts, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(frac_tokens * frac_probs) * m.aux_loss_weight
    return out.reshape(b, s, d), aux
