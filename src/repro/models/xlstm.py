"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM (scalar
memory), with stabilized exponential gating, full-sequence scan + decode step.

mLSTM state: {"C": [B,H,hd,hd], "n": [B,H,hd], "m": [B,H]}
sLSTM state: {"c": [B,H,hd], "n": [B,H,hd], "m": [B,H,hd], "h": [B,H,hd]}
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, XLSTMConfig
from repro.models import layers


def _dims(cfg: ModelConfig):
    x = cfg.xlstm or XLSTMConfig()
    d_in = int(x.proj_factor * cfg.d_model)
    hd = d_in // cfg.n_heads
    return x, d_in, hd


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig, dtype=jnp.float32):
    x, d_in, hd = _dims(cfg)
    ks = jax.random.split(key, 9)
    return {
        "w_up": layers.dense_init(ks[0], cfg.d_model, 2 * d_in, dtype),
        "conv": layers.causal_conv_init(ks[1], d_in, x.conv_width, dtype),
        "w_q": layers.dense_init(ks[2], d_in, d_in, dtype),
        "w_k": layers.dense_init(ks[3], d_in, d_in, dtype),
        "w_v": layers.dense_init(ks[4], d_in, d_in, dtype),
        "w_i": layers.dense_init(ks[5], d_in, cfg.n_heads, dtype),
        "w_f": layers.dense_init(ks[6], d_in, cfg.n_heads, dtype),
        "f_bias": jnp.full((cfg.n_heads,), 3.0, dtype),  # forget-open init
        "o_norm": layers.rms_norm_init(d_in, dtype),
        "w_down": layers.dense_init(ks[7], d_in, cfg.d_model, dtype),
    }


def _mlstm_gates_qkv(params, cfg, u):
    """u: [B,T,d_in] conv+silu'd. Returns per-head q,k,v [B,T,H,hd], i/f pre-acts [B,T,H]."""
    x, d_in, hd = _dims(cfg)
    b, t, _ = u.shape
    q = (u @ params["w_q"]).reshape(b, t, cfg.n_heads, hd)
    k = (u @ params["w_k"]).reshape(b, t, cfg.n_heads, hd) * hd ** -0.5
    v = (u @ params["w_v"]).reshape(b, t, cfg.n_heads, hd)
    i_pre = (u @ params["w_i"]).astype(jnp.float32)
    f_pre = (u @ params["w_f"]).astype(jnp.float32) + params["f_bias"].astype(jnp.float32)
    return q, k, v, i_pre, f_pre


def _mlstm_step(carry, inp):
    """Stabilized mLSTM recurrence (one time step)."""
    C, n, m = carry                     # [B,H,hd,hd], [B,H,hd], [B,H]
    q_t, k_t, v_t, i_pre, f_pre = inp   # [B,H,hd] x3, [B,H] x2
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    f_s = jnp.exp(logf + m - m_new)     # [B,H]
    i_s = jnp.exp(i_pre - m_new)
    C = f_s[..., None, None] * C + i_s[..., None, None] * (
        v_t[..., :, None] * k_t[..., None, :])
    n = f_s[..., None] * n + i_s[..., None] * k_t
    num = jnp.einsum("bhvk,bhk->bhv", C, q_t)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t))
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return (C, n, m_new), h


# default chunk for the chunkwise-parallel form; must divide the sequence.
# REPRO_MLSTM_CHUNK=0 forces the sequential-scan baseline (perf ablations).
import os as _os

MLSTM_CHUNK = int(_os.environ.get("REPRO_MLSTM_CHUNK", "128"))


def _mlstm_sequential(q, k, v, i_pre, f_pre, carry):
    """Reference: lax.scan over time (one state round-trip per step)."""
    xs = (q.swapaxes(0, 1).astype(jnp.float32), k.swapaxes(0, 1).astype(jnp.float32),
          v.swapaxes(0, 1).astype(jnp.float32), i_pre.swapaxes(0, 1), f_pre.swapaxes(0, 1))
    carry, hs = jax.lax.scan(_mlstm_step, carry, xs)
    return carry, hs.swapaxes(0, 1)


def _mlstm_chunkwise(q, k, v, i_pre, f_pre, carry, chunk: int):
    """Chunkwise-parallel mLSTM (§Perf hillclimb: the sequential scan's
    [B,H,hd,hd] matrix-memory round-trips HBM every step; here the state
    crosses HBM once per CHUNK and the intra-chunk part is a masked
    attention-like batched matmul — identical math, fp-reordered).

    Derivation: unrolling the stabilized recurrence over a chunk with
    b_t = cumsum(log f), M_t = max(m_in, cummax_s<=t(i_s - b_s)):
      m_t   = b_t + M_t
      h_t   = [ sum_s<=t exp(b_t-b_s+i_s-m_t) (q_t.k_s) v_s
                + exp(b_t+m_in-m_t) q_t.C_in ] / den_t
      den_t = max(|same weights applied to (q_t.k_s), q_t.n_in|, exp(-m_t))
    and the carry update is the t=L row applied to (C, n).
    """
    b, t, h, hd = q.shape
    n_chunks = t // chunk

    def resh(x):
        return x.reshape(b, n_chunks, chunk, *x.shape[2:]).swapaxes(0, 1)

    qs, ks, vs = (resh(q.astype(jnp.float32)), resh(k.astype(jnp.float32)),
                  resh(v.astype(jnp.float32)))
    is_, fs = resh(i_pre), resh(f_pre)   # [n, B, L, H]

    def one_chunk(carry, inp):
        C_in, n_in, m_in = carry          # [B,H,hd,hd], [B,H,hd], [B,H]
        qc, kc, vc, ic, fc = inp          # [B,L,H,hd] x3, [B,L,H] x2
        logf = jax.nn.log_sigmoid(fc)                       # [B,L,H]
        bcum = jnp.cumsum(logf, axis=1)                     # inclusive
        rel = ic - bcum                                     # i_s - b_s
        M = jnp.maximum(m_in[:, None], jax.lax.cummax(rel, axis=1))
        m = bcum + M                                        # [B,L,H]
        # intra-chunk decay matrix: D[t,s] = exp(b_t - b_s + i_s - m_t), s<=t
        dmat = (bcum[:, :, None] - bcum[:, None, :] + ic[:, None, :]
                - m[:, :, None])                            # [B,L(t),L(s),H]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = jnp.where(mask[None, :, :, None], jnp.exp(dmat), 0.0)
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc)
        sw = scores * w
        intra = jnp.einsum("btsh,bshd->bthd", sw, vc)
        inter_scale = jnp.exp(bcum + m_in[:, None] - m)     # [B,L,H]
        # C layout is [B,H,v,k] (v_t k_t^T): contract q with the k axis
        inter = jnp.einsum("bthk,bhvk->bthv", qc, C_in) * inter_scale[..., None]
        den_dot = sw.sum(axis=2) + jnp.einsum("bthd,bhd->bth", qc, n_in) * inter_scale
        den = jnp.maximum(jnp.abs(den_dot), jnp.exp(-m))
        hout = (intra + inter) / den[..., None]             # [B,L,H,hd]
        # carry update = row t=L
        b_tot = bcum[:, -1]                                 # [B,H]
        m_out = m[:, -1]
        carry_w = jnp.exp(b_tot[:, None] - bcum + ic - m_out[:, None])  # [B,L,H]
        C_out = (jnp.exp(b_tot + m_in - m_out)[..., None, None] * C_in
                 + jnp.einsum("blh,blhd,blhe->bhde", carry_w, vc, kc))
        n_out = (jnp.exp(b_tot + m_in - m_out)[..., None] * n_in
                 + jnp.einsum("blh,blhd->bhd", carry_w, kc))
        return (C_out, n_out, m_out), hout

    carry, hs = jax.lax.scan(one_chunk, carry, (qs, ks, vs, is_, fs))
    return carry, hs.swapaxes(0, 1).reshape(b, t, h, hd)


def mlstm_forward(params, cfg: ModelConfig, x: jnp.ndarray,
                  chunk: int | None = None) -> Tuple[jnp.ndarray, dict]:
    xcfg, d_in, hd = _dims(cfg)
    b, t, _ = x.shape
    up = x @ params["w_up"]
    u, z = jnp.split(up, 2, axis=-1)
    u = jax.nn.silu(layers.causal_conv_apply(params["conv"], u))
    q, k, v, i_pre, f_pre = _mlstm_gates_qkv(params, cfg, u)
    carry = (
        jnp.zeros((b, cfg.n_heads, hd, hd), jnp.float32),
        jnp.zeros((b, cfg.n_heads, hd), jnp.float32),
        jnp.full((b, cfg.n_heads), -1e30, jnp.float32),
    )
    if chunk is None:
        # largest divisor of t not exceeding MLSTM_CHUNK (train seqs are
        # S-1 = 4095 = 3^2*5*7*13 -> chunk 117); sequential if degenerate
        chunk = max((c for c in range(1, min(MLSTM_CHUNK, t) + 1)
                     if t % c == 0), default=0)
        if chunk < 16:
            chunk = 0
    if chunk and t % chunk == 0 and t > chunk:
        carry, hs = _mlstm_chunkwise(q, k, v,
                                     i_pre.astype(jnp.float32),
                                     f_pre.astype(jnp.float32), carry, chunk)
    else:
        carry, hs = _mlstm_sequential(q, k, v, i_pre, f_pre, carry)
    h = hs.reshape(b, t, d_in).astype(x.dtype)
    h = layers.rms_norm(params["o_norm"], h, cfg.norm_eps)
    out = (h * jax.nn.silu(z)) @ params["w_down"]
    u_raw, _ = jnp.split(up, 2, axis=-1)
    conv_state = jnp.pad(u_raw, ((0, 0), (xcfg.conv_width - 1, 0), (0, 0)))[:, -(xcfg.conv_width - 1):, :]
    return out, {"C": carry[0], "n": carry[1], "m": carry[2], "conv": conv_state}


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    x, d_in, hd = _dims(cfg)
    return {
        "C": jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, cfg.n_heads, hd), jnp.float32),
        "m": jnp.full((batch, cfg.n_heads), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, x.conv_width - 1, d_in), dtype),
    }


def mlstm_decode(params, cfg: ModelConfig, x_t: jnp.ndarray, state: dict):
    xcfg, d_in, hd = _dims(cfg)
    b = x_t.shape[0]
    up = x_t @ params["w_up"]
    u_raw, z = jnp.split(up, 2, axis=-1)
    u_c, conv_state = layers.causal_conv_step(params["conv"], state["conv"], u_raw)
    u = jax.nn.silu(u_c)[:, None, :]
    q, k, v, i_pre, f_pre = _mlstm_gates_qkv(params, cfg, u)
    carry = (state["C"], state["n"], state["m"])
    inp = (q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
           v[:, 0].astype(jnp.float32), i_pre[:, 0], f_pre[:, 0])
    (C, n, m), h = _mlstm_step(carry, inp)
    h = h.reshape(b, d_in).astype(x_t.dtype)
    h = layers.rms_norm(params["o_norm"], h, cfg.norm_eps)
    out = (h * jax.nn.silu(z)) @ params["w_down"]
    return out, {"C": C, "n": n, "m": m, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig, dtype=jnp.float32):
    x, d_in, hd = _dims(cfg)
    ks = jax.random.split(key, 11)
    scale = hd ** -0.5

    def rec(k):  # per-head recurrent weights (block diagonal), [H, hd, hd]
        return (jax.random.normal(k, (cfg.n_heads, hd, hd)) * scale).astype(dtype)

    return {
        "w_up": layers.dense_init(ks[0], cfg.d_model, d_in, dtype),
        "conv": layers.causal_conv_init(ks[1], d_in, x.conv_width, dtype),
        "w_z": layers.dense_init(ks[2], d_in, d_in, dtype),
        "w_i": layers.dense_init(ks[3], d_in, d_in, dtype),
        "w_f": layers.dense_init(ks[4], d_in, d_in, dtype),
        "w_o": layers.dense_init(ks[5], d_in, d_in, dtype),
        "r_z": rec(ks[6]), "r_i": rec(ks[7]), "r_f": rec(ks[8]), "r_o": rec(ks[9]),
        "f_bias": jnp.full((d_in,), 3.0, dtype),
        "o_norm": layers.rms_norm_init(d_in, dtype),
        "w_down": layers.dense_init(ks[10], d_in, cfg.d_model, dtype),
    }


def _slstm_step(params, cfg, carry, u_t):
    """u_t: [B, d_in] raw input for one step; carry: (c, n, m, h) fp32.
    Used by decode; the full-sequence path precomputes the input projections
    (time-parallel) and scans only the recurrent part (_slstm_step_rec)."""
    xcfg, d_in, hd = _dims(cfg)
    proj = jnp.stack([u_t @ params["w_z"], u_t @ params["w_i"],
                      u_t @ params["w_f"], u_t @ params["w_o"]], axis=1)
    return _slstm_step_rec(params, cfg, carry, proj)


def _slstm_step_rec(params, cfg, carry, proj_t):
    """proj_t: [B, 4, d_in] input projections (z,i,f,o order);
    carry: (c, n, m, h) each [B, H, hd] fp32. Only the recurrent
    h @ r_* matmuls happen per step (§Perf iteration X2)."""
    xcfg, d_in, hd = _dims(cfg)
    c, n, m, h = carry
    b = proj_t.shape[0]
    r_all = jnp.stack([params["r_z"], params["r_i"], params["r_f"],
                       params["r_o"]])                     # [4, H, hd, hd]
    rec = jnp.einsum("bhk,ghkv->bghv", h.astype(proj_t.dtype), r_all)
    gates = proj_t.reshape(b, 4, cfg.n_heads, hd).astype(jnp.float32) \
        + rec.astype(jnp.float32)
    z = jnp.tanh(gates[:, 0])
    i_pre = gates[:, 1]
    f_pre = gates[:, 2] + params["f_bias"].astype(jnp.float32).reshape(1, cfg.n_heads, hd)
    o = jax.nn.sigmoid(gates[:, 3])
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    f_s = jnp.exp(logf + m - m_new)
    i_s = jnp.exp(i_pre - m_new)
    c = f_s * c + i_s * z
    n = f_s * n + i_s
    h_new = o * c / jnp.maximum(n, 1e-6)
    return (c, n, m_new, h_new), h_new


def slstm_forward(params, cfg: ModelConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, dict]:
    xcfg, d_in, hd = _dims(cfg)
    b, t, _ = x.shape
    u = x @ params["w_up"]
    u = jax.nn.silu(layers.causal_conv_apply(params["conv"], u))
    carry = (jnp.zeros((b, cfg.n_heads, hd), jnp.float32),
             jnp.zeros((b, cfg.n_heads, hd), jnp.float32),
             jnp.full((b, cfg.n_heads, hd), -1e30, jnp.float32),
             jnp.zeros((b, cfg.n_heads, hd), jnp.float32))

    # NOTE (§Perf iteration X2, REFUTED): hoisting the input projections out
    # of the scan (xs = precomputed [B,T,4,d_in]) measured WORSE (57.2s ->
    # 85.8s memory term): the per-trip xs slices + their backward cotangent
    # stream cost more HBM than re-reading the (model-sharded) weights.
    # Projections stay in-loop.
    def step(cr, u_t):
        return _slstm_step(params, cfg, cr, u_t)

    carry, hs = jax.lax.scan(step, carry, u.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(b, t, d_in).astype(x.dtype)
    h = layers.rms_norm(params["o_norm"], h, cfg.norm_eps)
    out = h @ params["w_down"]
    u_raw = x @ params["w_up"]
    conv_state = jnp.pad(u_raw, ((0, 0), (xcfg.conv_width - 1, 0), (0, 0)))[:, -(xcfg.conv_width - 1):, :]
    return out, {"c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3],
                 "conv": conv_state}


def init_slstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    x, d_in, hd = _dims(cfg)
    z3 = lambda: jnp.zeros((batch, cfg.n_heads, hd), jnp.float32)  # noqa: E731
    return {
        "c": z3(), "n": z3(),
        "m": jnp.full((batch, cfg.n_heads, hd), -1e30, jnp.float32),
        "h": z3(),
        "conv": jnp.zeros((batch, x.conv_width - 1, d_in), dtype),
    }


def slstm_decode(params, cfg: ModelConfig, x_t: jnp.ndarray, state: dict):
    xcfg, d_in, hd = _dims(cfg)
    u_raw = x_t @ params["w_up"]
    u_c, conv_state = layers.causal_conv_step(params["conv"], state["conv"], u_raw)
    u = jax.nn.silu(u_c)
    carry = (state["c"], state["n"], state["m"], state["h"])
    carry, h = _slstm_step(params, cfg, carry, u)
    b = x_t.shape[0]
    h = h.reshape(b, d_in).astype(x_t.dtype)
    h = layers.rms_norm(params["o_norm"], h, cfg.norm_eps)
    out = h @ params["w_down"]
    return out, {"c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3],
                 "conv": conv_state}
