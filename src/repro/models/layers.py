"""Shared neural-net building blocks (pure-functional, pytree params).

All modules are init/apply pairs over plain dict pytrees so they compose with
pjit sharding rules (repro.sharding.specs) and with the BLADE-FL client-axis
vmap (repro.core.rounds).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32, scale: float | None = None):
    scale = scale if scale is not None else in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rms_norm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding (half-rotation convention)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: [..., seq] int32."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, half]
    cos = jnp.cos(angles)[..., None, :]  # [..., seq, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, kind: str, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, 3)
    gated = kind in ("swiglu", "geglu")
    p: Params = {"w_in": dense_init(keys[0], d_model, d_ff, dtype)}
    if gated:
        p["w_gate"] = dense_init(keys[1], d_model, d_ff, dtype)
    p["w_out"] = dense_init(keys[2], d_ff, d_model, dtype)
    return p


def mlp_apply(params: Params, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    h = x @ params["w_in"]
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * h
    elif kind == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"]) * h
    elif kind == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    elif kind == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(f"unknown mlp kind {kind}")
    return h @ params["w_out"]


# ---------------------------------------------------------------------------
# Depthwise causal conv (mamba / xlstm local mixing; hubert conv-pos stub)
# ---------------------------------------------------------------------------


def causal_conv_init(key, channels: int, width: int, dtype=jnp.float32) -> Params:
    return {
        "w": (jax.random.normal(key, (width, channels)) * width ** -0.5).astype(dtype),
        "b": jnp.zeros((channels,), dtype=dtype),
    }


def causal_conv_apply(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """x: [B, T, C] -> depthwise causal conv over T."""
    w = params["w"]  # [W, C]
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):  # width is small (4); unrolled adds
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    return out + params["b"]


def causal_conv_step(params: Params, conv_state: jnp.ndarray, x_t: jnp.ndarray):
    """Single decode step. conv_state: [B, W-1, C]; x_t: [B, C]."""
    w = params["w"]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # [B, W, C]
    out = jnp.einsum("bwc,wc->bc", window, w) + params["b"]
    return out, window[:, 1:, :]


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, mask=None):
    """logits: [..., V] (any dtype, upcast), labels int32 [...]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
