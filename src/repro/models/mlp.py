"""Paper §7.1 experimental model: MLP with one 256-unit hidden layer + ReLU,
10-class softmax (MNIST / Fashion-MNIST shape)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def init_mlp(key, in_dim: int = 784, hidden: int = 256, n_classes: int = 10,
             dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "w1": layers.dense_init(k1, in_dim, hidden, dtype),
        "b1": jnp.zeros((hidden,), dtype),
        "w2": layers.dense_init(k2, hidden, n_classes, dtype),
        "b2": jnp.zeros((n_classes,), dtype),
    }


def mlp_logits(params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def mlp_loss(params, batch):
    """batch: {"x": [B, in_dim], "y": [B] int32} -> (loss, metrics)."""
    logits = mlp_logits(params, batch["x"])
    loss = layers.softmax_cross_entropy(logits, batch["y"])
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
    return loss, {"accuracy": acc}
