"""Serving driver: prefill a batch of prompts, then batched decode.

The BLADE-FL paper trains models; serving exists here because the assigned
input shapes include inference-prefill/decode — this driver runs the REAL
prefill + decode_step path (the same functions the dry-run lowers at
production shapes) at smoke scale on CPU, validating the serving stack
end-to-end (batched requests, greedy sampling, cache reuse).

  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
      --prompt-len 32 --gen 16 --batch 4
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import ShapeConfig, get_smoke_arch
from repro.models import registry, transformer


def serve(args) -> dict:
    cfg = get_smoke_arch(args.arch)
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    max_len = args.prompt_len + args.gen
    shape = ShapeConfig("serve", args.prompt_len, args.batch, "prefill")
    key = jax.random.key(args.seed)
    params = registry.init_model(key, cfg)
    batch = registry.make_prefill_batch(jax.random.fold_in(key, 1), cfg, shape)

    prefill = jax.jit(lambda p, b: transformer.prefill(p, cfg, b,
                                                       max_len=max_len))
    decode = jax.jit(lambda p, s, t, i: transformer.decode_step(p, cfg, s, t, i))

    t0 = time.time()
    logits, state = prefill(params, batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    prefill_s = time.time() - t0

    generated = [tok]
    t1 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.int32(args.prompt_len + i)
        logits, state = decode(params, state, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        generated.append(tok)
    decode_s = time.time() - t1
    gen = jnp.stack(generated, 1)
    result = {
        "arch": cfg.name, "batch": args.batch, "prompt_len": args.prompt_len,
        "generated_tokens": int(gen.size), "prefill_s": round(prefill_s, 3),
        "decode_s": round(decode_s, 3),
        "tokens_per_s": round(gen.size / max(decode_s, 1e-9), 1),
        "sample": gen[0, :8].tolist(),
        "finite": bool(jnp.isfinite(logits).all()),
    }
    print(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    serve(ap.parse_args())


if __name__ == "__main__":
    main()
