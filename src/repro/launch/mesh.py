"""Mesh factories. Production target: TPU v5e, 256 chips/pod.

``make_production_mesh`` is a FUNCTION (not module state) so importing this
module never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import
and then builds these meshes out of host placeholder devices.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

# hardware constants (TPU v5e) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    # more devices available than the mesh needs (e.g. 512 placeholders,
    # single-pod mesh): build from the first n explicitly.
    from jax.sharding import Mesh

    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Small mesh over however many host devices exist (tests)."""
    import jax
    from jax.sharding import Mesh

    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_client_mesh(n_devices: int = 0):
    """1-D ``('data',)`` mesh for the client-sharded scan engine.

    ``n_devices == 0`` takes every visible device. On a CPU dev box, expose
    more than one host device by setting (BEFORE any jax import / process
    start) ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the
    same trick the dry-run and the multi-device tests use.
    """
    import jax

    n = n_devices or len(jax.devices())
    if len(jax.devices()) < n:
        raise ValueError(
            f"asked for {n} devices but only {len(jax.devices())} visible; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count before "
            "launch to fan a CPU out into placeholder devices")
    return make_host_mesh((n,), ("data",))


def make_cluster_mesh(n_clusters: int, n_devices: int = 0):
    """2-D ``('pod', 'data')`` mesh with one pod row per cluster.

    The hierarchical layout for ``topology.ClusterTopology``: clients
    shard over BOTH axes (``client_axes=('pod', 'data')``), each cluster's
    block lands on one pod row, so the in-cluster mean is an intra-pod
    all-gather and only the narrow cluster-ring exchange crosses the
    ``'pod'`` axis. Same placeholder-device trick as
    :func:`make_client_mesh` on a CPU box.
    """
    import jax

    g = int(n_clusters)
    if g < 1:
        raise ValueError(f"n_clusters={n_clusters} must be >= 1")
    n = n_devices or len(jax.devices())
    if len(jax.devices()) < n:
        raise ValueError(
            f"asked for {n} devices but only {len(jax.devices())} visible; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count before "
            "launch to fan a CPU out into placeholder devices")
    if n % g != 0:
        raise ValueError(
            f"{n} devices do not split into n_clusters={g} equal pod rows; "
            "pick a device count divisible by the cluster count")
    return make_host_mesh((g, n // g), ("pod", "data"))
