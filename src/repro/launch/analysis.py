"""Roofline analysis from compiled dry-run artifacts (no hardware).

Three terms per (arch x shape x mesh), TPU v5e constants from launch.mesh:
  compute    = HLO_FLOPs / (chips * 197e12)
  memory     = HLO_bytes / (chips * 819e9)
  collective = collective_bytes / (chips * 50e9)

cost_analysis() runs on the post-SPMD (per-device) module: flops/bytes it
reports are per-device, so the per-chip division is already done — we
multiply back to record totals AND keep the per-device second. Collective
bytes are parsed from the compiled HLO text (operand sizes of all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute).
"""
from __future__ import annotations

import re
from typing import Dict

from repro.launch import mesh as mesh_lib

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_OP_RE = re.compile(
    r"=\s+(?:\([^=]*?\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device operand bytes by collective type, from compiled HLO."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or m.group(2) == "-done":
            continue
        name = m.group(1)
        args = line[m.end() - 1:]
        total = 0
        for dt, dims in _SHAPE_RE.findall(args):
            total += _shape_bytes(dt, dims)
        out[name] += total
        counts[name] += 1
    out.update({f"n_{k}": float(v) for k, v in counts.items()})
    return out


def roofline(flops_per_dev: float, bytes_per_dev: float,
             coll_bytes_per_dev: float, chips: int) -> Dict[str, float]:
    compute_s = flops_per_dev / mesh_lib.PEAK_FLOPS_BF16
    memory_s = bytes_per_dev / mesh_lib.HBM_BW
    collective_s = coll_bytes_per_dev / mesh_lib.ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)  # type: ignore[arg-type]
    bound = max(compute_s, memory_s, collective_s)
    return {
        **terms,
        "dominant": dom,
        "bound_s": bound,
        "chips": chips,
        "total_flops": flops_per_dev * chips,
        "total_bytes": bytes_per_dev * chips,
    }


def model_flops(n_active_params: int, tokens: float, backward: bool,
                local_iters: int = 1) -> float:
    """6*N*D for training (fwd+bwd), 2*N*D inference."""
    per_tok = 6.0 if backward else 2.0
    return per_tok * n_active_params * tokens * local_iters
