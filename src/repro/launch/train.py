"""End-to-end BLADE-FL training driver.

Runs real integrated rounds (training + lazy + mining + chain) either:
  * paper-scale: --arch mlp  — the §7 substrate (MLP, synthetic non-IID
    MNIST proxy, N=20 clients) on host devices; used by benchmarks/examples;
  * arch-scale: --arch <assigned id> --smoke — reduced config of the same
    family, a few clients, synthetic token streams (CPU-runnable);
  * mesh-scale: add --mesh to place the step on a (sub)mesh with the same
    shardings the dry-run proves out.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch mlp --rounds 10 --k 5
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --smoke --rounds 3

Multi-device (client-sharded scan engine; the K-round carry never leaves the
devices, and results are bit-for-bit the single-device run — see
docs/architecture.md):

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
    PYTHONPATH=src python -m repro.launch.train --arch mlp --devices 4
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import BladeConfig, ShapeConfig, get_smoke_arch
from repro.core import allocation, attacks, rounds, spectral, topology
from repro.data.pipeline import CohortDataSource, FLDataSource, LMDataSource
from repro.launch.mesh import make_client_mesh, make_cluster_mesh
from repro.models import registry
from repro.models.mlp import init_mlp, mlp_loss
from repro.sharding import plans
from repro.training.metrics import MetricLogger


def spectral_fields(spec: rounds.RoundSpec, run_key, n_rounds: int) -> dict:
    """1 - lambda_2(W) diagnostics for the run's topology/schedule: the
    per-round gap stats plus the ergodic (product-matrix) gap. Stochastic
    topologies replay the run's exact per-round key stream."""
    keys = (rounds.topology_keys(run_key, n_rounds)
            if spec.topology.stochastic else None)
    rep = spectral.gap_report(spec.topology, spec.n_clients, n_rounds,
                              keys=keys)
    return {"spectral_gap_mean": rep["gap_mean"],
            "spectral_gap_min": rep["gap_min"],
            "ergodic_gap": rep["ergodic_gap"],
            "predicted_consensus_rate": rep["predicted_consensus_rate"]}


def adversary_fields(args) -> dict:
    """``RoundSpec`` kwargs for the Byzantine scenario axis: ``--attack``
    (parsed by ``attacks.from_name`` with ``--attackers`` adversarial
    clients) and ``--robust`` (the aggregator override string the resolver
    parses; ``mean`` keeps the linear mix). Shared by every run path so the
    flags mean the same thing at paper scale, cohort scale and arch
    scale."""
    out = {}
    if args.attack:
        out["attack"] = attacks.from_name(args.attack, args.attackers)
    if args.robust:
        out["robust_agg"] = args.robust
    return out


def run_mlp(args) -> dict:
    blade = BladeConfig(n_clients=args.clients, n_lazy=args.lazy,
                        sigma2=args.sigma2, t_sum=args.t_sum,
                        alpha=args.alpha, beta=args.beta, eta=args.eta,
                        K=args.k, dp_sigma=args.dp_sigma, seed=args.seed)
    tau = allocation.tau_from_budget(blade.t_sum, blade.K, blade.alpha, blade.beta)
    spec = rounds.RoundSpec(
        n_clients=blade.n_clients, tau=max(tau, 1), eta=blade.eta,
        n_lazy=blade.n_lazy, sigma2=blade.sigma2, dp_sigma=blade.dp_sigma,
        mine_attempts=allocation.mining_iterations(blade.beta),
        difficulty_bits=4, eval_every=args.eval_every,
        topology=topology.from_name(args.topology),
        fast_allreduce=args.fast_allreduce, use_kernel=args.kernels,
        fused_mix=args.fused_mix, **adversary_fields(args))
    key = jax.random.key(blade.seed)
    src = FLDataSource(key, blade.n_clients, blade.samples_per_client,
                       blade.dirichlet_alpha, seed=blade.seed)
    params = init_mlp(jax.random.fold_in(key, 1))
    log = MetricLogger(args.out_dir, "blade_mlp")
    # --clusters lays the mesh out hierarchically: one 'pod' row per
    # cluster, clients sharded over BOTH axes, so ClusterTopology's
    # in-cluster mean stays intra-pod and only the cluster ring crosses pods
    if args.clusters:
        mesh = make_cluster_mesh(args.clusters, args.devices)
        plan = plans.scan_carry_plan(mesh, blade.n_clients,
                                     client_axes=("pod", "data"))
    else:
        mesh = make_client_mesh(args.devices) if args.devices else None
        plan = None
    run_key = jax.random.fold_in(key, 2)
    t0 = time.time()
    # static batch -> compiled scan engine (K rounds, one dispatch);
    # --devices shards the client axis of the whole scan over the mesh
    state, hist, ledger = rounds.run_blade_fl(
        mlp_loss, spec, params, src.static_batch(), run_key,
        blade.K, mesh=mesh, plan=plan)
    # final eval on held-out data with the aggregated model
    from repro.core.aggregation import aggregate_once
    final = aggregate_once(state.params)
    loss, metrics = mlp_loss(final, src.eval_data)
    for i, h in enumerate(hist):
        log.log(i, **h)
    result = {
        "K": blade.K, "tau": spec.tau, "final_eval_loss": float(loss),
        "final_eval_acc": float(metrics["accuracy"]),
        "final_global_loss": hist[-1].get("global_loss"),
        "chain_valid": ledger.validate_chain(), "blocks": len(ledger.blocks),
        "devices": mesh.devices.size if mesh is not None else 1,
        "fast_allreduce": spec.fast_allreduce,
        "dispatch": dict(rounds.LAST_DISPATCH),
        "wall_s": time.time() - t0,
        **spectral_fields(spec, run_key, blade.K),
    }
    print(json.dumps(result, indent=1))
    return result


def run_cohort(args) -> dict:
    """Cohort-sampled population run: ``--enrolled`` clients of which a
    cohort of ``--cohort`` participates per round (``--cohort-bias``
    selects the sampling weights). The round engine runs at cohort size —
    devices never see an array shaped by the enrolled count, which is what
    makes ``--enrolled 10000`` runnable on one CPU."""
    blade = BladeConfig(n_clients=args.cohort, n_lazy=args.lazy,
                        sigma2=args.sigma2, t_sum=args.t_sum,
                        alpha=args.alpha, beta=args.beta, eta=args.eta,
                        K=args.k, dp_sigma=args.dp_sigma, seed=args.seed)
    tau = allocation.tau_from_budget(blade.t_sum, blade.K, blade.alpha, blade.beta)
    cohort = topology.CohortSchedule.from_spec(
        args.enrolled, args.cohort, args.cohort_bias)
    spec = rounds.RoundSpec(
        n_clients=args.cohort, tau=max(tau, 1), eta=blade.eta,
        n_lazy=blade.n_lazy, sigma2=blade.sigma2, dp_sigma=blade.dp_sigma,
        mine_attempts=allocation.mining_iterations(blade.beta),
        difficulty_bits=4, eval_every=args.eval_every,
        topology=topology.from_name(args.topology),
        fast_allreduce=args.fast_allreduce, use_kernel=args.kernels,
        fused_mix=args.fused_mix, **adversary_fields(args))
    key = jax.random.key(blade.seed)
    src = CohortDataSource(key, blade.samples_per_client,
                           blade.dirichlet_alpha)
    params = init_mlp(jax.random.fold_in(key, 1))
    mesh = make_client_mesh(args.devices) if args.devices else None
    plan = (plans.cohort_carry_plan(mesh, args.enrolled, args.cohort)
            if mesh is not None else None)
    log = MetricLogger(args.out_dir, "blade_cohort")
    run_key = jax.random.fold_in(key, 2)
    t0 = time.time()
    store, hist, ledger = rounds.run_blade_fl_cohort(
        mlp_loss, spec, params, src.cohort_batch, run_key, blade.K, cohort,
        mesh=mesh, plan=plan)
    # final eval: aggregate the LAST round's cohort (the freshest models)
    from repro.core.aggregation import aggregate_once
    final = aggregate_once(store.gather(hist[-1]["cohort"]))
    loss, metrics = mlp_loss(final, src.eval_data)
    for i, h in enumerate(hist):
        log.log(i, **{k: v for k, v in h.items() if k != "cohort"})
    result = {
        "enrolled": args.enrolled, "cohort": args.cohort,
        "cohort_bias": args.cohort_bias, "K": blade.K, "tau": spec.tau,
        "touched": store.touched,
        "store_mb": round(store.materialized_bytes() / 1e6, 3),
        "final_eval_loss": float(loss),
        "final_eval_acc": float(metrics["accuracy"]),
        "final_global_loss": hist[-1].get("global_loss"),
        "chain_valid": ledger.validate_chain(), "blocks": len(ledger.blocks),
        "devices": mesh.devices.size if mesh is not None else 1,
        "dispatch": dict(rounds.LAST_DISPATCH),
        "wall_s": time.time() - t0,
        # intra-cohort mixing diagnostics at size A (the enrolled graph is
        # never materialized — that is the point)
        **spectral_fields(spec, run_key, blade.K),
    }
    print(json.dumps(result, indent=1))
    return result


def run_arch_smoke(args) -> dict:
    cfg = get_smoke_arch(args.arch)
    shape = ShapeConfig("smoke", args.seq, args.clients * args.per_client, "train")
    spec = rounds.RoundSpec(n_clients=args.clients, tau=2, eta=1e-2,
                            n_lazy=args.lazy, sigma2=args.sigma2,
                            mine_attempts=256, difficulty_bits=2,
                            eval_every=args.eval_every,
                            topology=topology.from_name(args.topology),
                            fast_allreduce=args.fast_allreduce,
                            use_kernel=args.kernels,
                            fused_mix=args.fused_mix,
                            **adversary_fields(args))
    src = LMDataSource(cfg, shape, args.clients, seed=args.seed)
    key = jax.random.key(args.seed)
    params = registry.init_model(key, cfg)

    def loss_fn(p, b):
        return registry.loss_fn(p, cfg, b, remat=False)

    mesh = make_client_mesh(args.devices) if args.devices else None
    run_key = jax.random.fold_in(key, 2)
    t0 = time.time()
    # stacked [K, C, ...] token streams -> compiled scan engine;
    # --devices shards the client axis over the mesh, same as the mlp path
    state, hist, ledger = rounds.run_blade_fl(
        loss_fn, spec, params, src.stacked_batches(args.rounds),
        run_key, args.rounds, stacked=True, mesh=mesh)
    result = {
        "arch": cfg.name, "rounds": args.rounds,
        "loss_curve": [h["global_loss"] for h in hist],
        "chain_valid": ledger.validate_chain(),
        "devices": mesh.devices.size if mesh is not None else 1,
        "fast_allreduce": spec.fast_allreduce,
        "dispatch": dict(rounds.LAST_DISPATCH),
        "wall_s": time.time() - t0,
        **spectral_fields(spec, run_key, args.rounds),
    }
    print(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mlp")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--per-client", type=int, default=2)
    ap.add_argument("--lazy", type=int, default=0)
    ap.add_argument("--sigma2", type=float, default=0.0)
    ap.add_argument("--dp-sigma", type=float, default=0.0)
    ap.add_argument("--t-sum", type=float, default=100.0)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--beta", type=float, default=10.0)
    ap.add_argument("--eta", type=float, default=0.05)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--topology", default="full",
                    help="Steps 2+5 mixing: full | ring[:k] | random[:p] | "
                         "partial:n | shift[:s] | cluster:g[:a] "
                         "(core/topology.py)")
    ap.add_argument("--schedule", default=None,
                    help="time-varying topology schedule (overrides "
                         "--topology): rotate[:step] | alt[:k[:m]] | "
                         "snr[:period] (core/topology.py Schedules)")
    ap.add_argument("--enrolled", type=int, default=0,
                    help="cohort mode (mlp arch): total enrolled clients; a "
                         "cohort of --cohort participates per round. Devices "
                         "scale with the cohort, not this count — tens of "
                         "thousands run on one CPU (core/rounds.py "
                         "run_blade_fl_cohort)")
    ap.add_argument("--cohort", type=int, default=64,
                    help="active cohort size A per round (with --enrolled)")
    ap.add_argument("--cohort-bias", default="uniform",
                    help="cohort sampling weights: uniform | pareto[:alpha] "
                         "| prefix (core/topology.py CohortSchedule)")
    ap.add_argument("--eval-every", type=int, default=1,
                    help="global-loss eval stride (NaN on skipped rounds)")
    ap.add_argument("--attack", default=None,
                    help="Byzantine attack stage on the pre-broadcast "
                         "params: signflip[:scale] | noise[:sigma2[:scale]] "
                         "| alie[:z] | replace[:boost] (core/attacks.py); "
                         "the first --attackers clients are adversarial")
    ap.add_argument("--attackers", type=int, default=1,
                    help="adversarial client count for --attack (first-M "
                         "convention, like --lazy)")
    ap.add_argument("--robust", default=None,
                    help="Byzantine-robust aggregation override: mean | "
                         "median | trimmed[:t] | geomed[:iters] — order "
                         "statistics over the full broadcast set instead "
                         "of the linear mix; tolerance tier on the mesh "
                         "(docs/architecture.md Robust aggregation)")
    ap.add_argument("--fast-allreduce", action="store_true",
                    help="opt-in psum fast path for dense mixes: ~C/D x less "
                         "data moved, fp32 reassociated — tolerance tier, "
                         "ledger hashes fork from the bitwise engine (see "
                         "docs/architecture.md)")
    ap.add_argument("--kernels", action="store_true",
                    help="run the Steps 3+4 PoW race on the Pallas 2-D "
                         "(clients x nonce-chunk) grid (kernels/pow_hash). "
                         "Bitwise-identical results and ledger; "
                         "run_blade_fl's auto dispatch skips the kernel for "
                         "tiny mining budgets (docs/architecture.md "
                         "Kernel dispatch)")
    ap.add_argument("--fused-mix", action="store_true",
                    help="fuse dense mixes + the digest/divergence "
                         "diagnostics into Pallas kernels (kernels/fedavg): "
                         "one sweep of the broadcast set instead of two. "
                         "Tolerance tier like --fast-allreduce: ledger "
                         "hashes fork deterministically")
    ap.add_argument("--devices", type=int, default=0,
                    help="shard the client axis of the scan engine over this "
                         "many devices (0 = single-device; requires "
                         "clients %% devices == 0; see docs/architecture.md)")
    ap.add_argument("--clusters", type=int, default=0,
                    help="hierarchical two-level layout (mlp arch): a "
                         "('pod', 'data') mesh with one pod row per cluster "
                         "(launch/mesh.py make_cluster_mesh), clients "
                         "sharded over both axes. Defaults --topology to "
                         "cluster:<g> so the mix is the in-cluster mean + "
                         "cluster-ring exchange")
    ap.add_argument("--out-dir", default=None)
    args = ap.parse_args()
    if args.schedule:
        args.topology = args.schedule
    if args.clusters:
        if args.arch != "mlp" or args.enrolled > 0:
            ap.error("--clusters hierarchical mode runs the mlp substrate")
        if args.topology == "full" and not args.schedule:
            args.topology = f"cluster:{args.clusters}"
    if args.enrolled > 0:
        if args.arch != "mlp":
            ap.error("--enrolled cohort mode runs the mlp substrate")
        run_cohort(args)
    elif args.arch == "mlp":
        run_mlp(args)
    else:
        run_arch_smoke(args)


if __name__ == "__main__":
    main()
