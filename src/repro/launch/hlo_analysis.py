"""Loop-aware HLO analyzer for the dry-run roofline.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified:
scan(length=1) and scan(length=10) report identical flops), which silently
zeroes out the cost of scanned layers, local-iteration loops and microbatch
accumulation — i.e. almost all of our compute. This module parses the
compiled per-device HLO text instead and propagates costs through the
computation tree with loop trip-count multipliers:

  * trip counts: ``backend_config={"known_trip_count":{"n":"N"}}`` on the
    while op (present for lax.scan/fori_loop), falling back to the largest
    integer constant in the loop condition computation, else 1;
  * flops: 2*M*N*K for every ``dot`` (+ conv as implicit dot), wherever it
    sits (fusion bodies included), times the product of enclosing trips;
  * HBM bytes: operand+output bytes of top-level (fusion-boundary) ops —
    fusion-internal ops don't round-trip HBM;
  * collective bytes: operand bytes of all-reduce/all-gather/reduce-scatter/
    all-to-all/collective-permute, per enclosing-trip multiplier.

All quantities are per-device (the HLO is the post-SPMD partitioned module).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|s4|u4|s8|u8|"
                       r"s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_OP_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\]{},]+))\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[\"\\:{\s]+n[\"\\:\s]+\"?(\d+)')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_tokens_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(text: str) -> int:
    """elements of the FIRST shape token."""
    m = _SHAPE_RE.search(text)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _dims_of(text: str) -> List[List[int]]:
    out = []
    for _, dims in _SHAPE_RE.findall(text):
        out.append([int(d) for d in dims.split(",")] if dims else [])
    return out


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    out_bytes: int
    out_shape_txt: str
    operands: List[str]
    line: str
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, Op]
    order: List[str]


def _parse_operands(argtxt: str) -> List[str]:
    # argtxt: inside the outer parens of op(...), operands are %names
    return re.findall(r"%([\w.\-]+)", argtxt)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        mc = _COMP_RE.match(line)
        if mc and not line.startswith(" "):
            cur = Computation(mc.group(1), {}, [])
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        md = _DEF_RE.match(stripped)
        if not md:
            continue
        is_root = stripped.startswith("ROOT")
        name, rhs = md.group(1), md.group(2)
        mo = _OP_RE.match(rhs)
        if not mo:
            continue
        out_shape_txt, opcode = mo.group(1), mo.group(2)
        paren = rhs.find("(", len(mo.group(1)))
        depth, j = 0, paren
        for j in range(paren, len(rhs)):
            if rhs[j] == "(":
                depth += 1
            elif rhs[j] == ")":
                depth -= 1
                if depth == 0:
                    break
        argtxt = rhs[paren + 1: j]
        op = Op(name=name, opcode=opcode,
                out_bytes=_shape_tokens_bytes(out_shape_txt),
                out_shape_txt=out_shape_txt,
                operands=_parse_operands(argtxt), line=rhs,
                is_root=is_root)
        cur.ops[name] = op
        cur.order.append(name)
    if entry and entry != "__entry__":
        comps["__entry__"] = comps[entry]
    return comps


def _dot_flops(op: Op, comp: Computation) -> float:
    """2 * output_elems * contracted_size (batch dims fall out naturally)."""
    out_elems = _shape_elems(op.out_shape_txt)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if not m or not op.operands:
        return 2.0 * out_elems  # degenerate
    lhs_name = op.operands[0]
    lhs = comp.ops.get(lhs_name)
    if lhs is None:
        return 2.0 * out_elems
    lhs_dims = _dims_of(lhs.out_shape_txt)
    if not lhs_dims:
        return 2.0 * out_elems
    dims = lhs_dims[0]
    k = 1
    for idx in m.group(1).split(","):
        if idx != "" and int(idx) < len(dims):
            k *= dims[int(idx)]
    return 2.0 * out_elems * k


def _conv_flops(op: Op, comp: Computation) -> float:
    out_elems = _shape_elems(op.out_shape_txt)
    if len(op.operands) < 2:
        return 2.0 * out_elems
    rhs = comp.ops.get(op.operands[1])
    if rhs is None:
        return 2.0 * out_elems
    kdims = _dims_of(rhs.out_shape_txt)
    k = 1
    for d in (kdims[0] if kdims else []):
        k *= d
    return 2.0 * out_elems * k  # upper bound (ignores feature grouping)


def _root_of(cname: str, comps: Dict[str, "Computation"]) -> Optional["Op"]:
    comp = comps.get(cname)
    if comp is None:
        return None
    for name in reversed(comp.order):
        if comp.ops[name].is_root:
            return comp.ops[name]
    return comp.ops[comp.order[-1]] if comp.order else None


def _slice_update_bytes(root: "Op", comp: "Computation") -> Optional[int]:
    """Real traffic of an in-place dynamic-update-slice: 2x the update
    region (read-modify-write of the slice), not the whole buffer."""
    if root is None:
        return None
    if root.opcode == "dynamic-update-slice" and len(root.operands) >= 2:
        upd = comp.ops.get(root.operands[1])
        if upd is not None:
            return 2 * upd.out_bytes
    if root.opcode == "dynamic-slice":
        return 2 * root.out_bytes
    return None


def _op_hbm_bytes(op: "Op", comp: "Computation",
                  comps: Dict[str, "Computation"]) -> int:
    if op.opcode == "dynamic-update-slice" and len(op.operands) >= 2:
        upd = comp.ops.get(op.operands[1])
        if upd is not None:
            return 2 * upd.out_bytes
    if op.opcode == "dynamic-slice":
        return 2 * op.out_bytes
    if op.opcode == "fusion":
        sub = _CALLS_RE.search(op.line)
        if sub:
            subcomp = comps.get(sub.group(1))
            if subcomp is not None:
                alias = _slice_update_bytes(_root_of(sub.group(1), comps),
                                            subcomp)
                if alias is not None:
                    # other (non-aliased) small operands still stream in
                    small = sum(comp.ops[o].out_bytes for o in op.operands
                                if o in comp.ops
                                and comp.ops[o].out_bytes < op.out_bytes // 2)
                    return alias + small
    operand_bytes = sum(comp.ops[on].out_bytes
                        for on in op.operands if on in comp.ops)
    return op.out_bytes + operand_bytes


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_type: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k in _COLLECTIVES:
            self.coll_by_type[k] += other.coll_by_type[k] * mult
            self.coll_counts[k] += other.coll_counts[k] * mult


def _trip_count(op: Op, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(op.line)
    if m:
        return int(m.group(1))
    mc = _COND_RE.search(op.line)
    if mc and mc.group(1) in comps:
        consts = [int(c) for c in _CONST_RE.findall(
            "\n".join(o.line for o in comps[mc.group(1)].ops.values()))]
        if consts:
            return max(consts)
    return 1


def _analyze_comp(cname: str, comps: Dict[str, Computation],
                  memo: Dict[str, Costs], top_level: bool) -> Costs:
    if cname in memo:
        return memo[cname]
    comp = comps.get(cname)
    cost = Costs()
    if comp is None:
        memo[cname] = cost
        return cost
    memo[cname] = cost  # break cycles defensively
    for name in comp.order:
        op = comp.ops[name]
        oc = op.opcode
        # flops
        if oc == "dot":
            cost.flops += _dot_flops(op, comp)
        elif oc == "convolution":
            cost.flops += _conv_flops(op, comp)
        # collectives
        base = None
        for c in _COLLECTIVES:
            if oc == c or oc == c + "-start":
                base = c
                break
        if base is not None:
            operand_bytes = 0
            for on in op.operands:
                src = comp.ops.get(on)
                if src is not None:
                    operand_bytes += src.out_bytes
            if operand_bytes == 0:
                operand_bytes = op.out_bytes  # fallback
            cost.coll_bytes += operand_bytes
            cost.coll_by_type[base] += operand_bytes
            cost.coll_counts[base] += 1
        # HBM bytes: top-level ops only (fusion boundaries). In-place
        # slice updates (scan xs/ys/carry plumbing, KV-cache writes) alias
        # their big operand and only move the slice region — counting the
        # full buffer per loop trip would overstate scanned models by
        # orders of magnitude (verified on the xlstm dry-run).
        if oc not in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast"):
            cost.hbm_bytes += _op_hbm_bytes(op, comp, comps)
        # recurse into called computations
        if oc == "while":
            trips = _trip_count(op, comps)
            body = _CALLS_RE.search(op.line)
            if body:
                cost.add(_analyze_comp(body.group(1), comps, memo, False),
                         trips)
        elif oc in ("fusion", "call", "conditional", "map", "reduce",
                    "reduce-window", "sort", "scatter", "select-and-scatter",
                    "custom-call", "async-start"):
            for sub in _CALLS_RE.findall(op.line):
                subcost = _analyze_comp(sub, comps, memo, False)
                # fusion-internal ops don't touch HBM; count flops+collectives
                cost.flops += subcost.flops
                cost.coll_bytes += subcost.coll_bytes
                for k in _COLLECTIVES:
                    cost.coll_by_type[k] += subcost.coll_by_type[k]
                    cost.coll_counts[k] += subcost.coll_counts[k]
    return cost


def analyze(hlo_text: str) -> Costs:
    comps = parse_hlo(hlo_text)
    if "__entry__" not in comps:
        raise ValueError("no ENTRY computation found")
    return _analyze_comp("__entry__", comps, {}, True)


def analyze_dict(hlo_text: str) -> Dict[str, float]:
    c = analyze(hlo_text)
    out = {"flops": c.flops, "hbm_bytes": c.hbm_bytes,
           "collective_bytes": c.coll_bytes}
    out.update({k: v for k, v in c.coll_by_type.items()})
    out.update({f"n_{k}": v for k, v in c.coll_counts.items()})
    return out
