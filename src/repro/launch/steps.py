"""Step builders shared by dryrun / train / serve launchers: construct the
jit-able step function + in/out shardings + abstract input specs for any
(architecture x input shape x mesh) pair.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import rounds
from repro.models import registry, transformer
from repro.sharding import plans as plans_lib, specs as specs_lib

SLIDING_WINDOW_LONG = 8192  # dense archs x long_500k: windowed-attention variant


def resolve_cfg(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Arch variant per shape: dense full-attention archs get the sliding-
    window variant for long_500k (DESIGN.md §4)."""
    if (shape.name == "long_500k" and cfg.causal and not cfg.subquadratic):
        return dataclasses.replace(cfg, sliding_window=SLIDING_WINDOW_LONG)
    return cfg


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.kind == "decode" and not cfg.has_decode:
        return "encoder-only architecture: no autoregressive decode step"
    return None


def round_spec_for(cfg: ModelConfig, shape: ShapeConfig,
                   plan: specs_lib.ShardingPlan, *, tau: int = 2,
                   mine_attempts: int = 1024) -> rounds.RoundSpec:
    m = shape.global_batch // plan.n_clients
    # L2 (FSDP) giants: per-microbatch weight traffic and in-loop grad
    # all-reduces dominate — amortize with fewer, larger microbatches
    # (§Perf iteration K1: kimi collective term 522s -> measured below).
    mb_size = 32 if plan.fsdp_axes else 8
    microbatches = max(1, m // mb_size)
    return rounds.RoundSpec(
        n_clients=plan.n_clients, tau=tau, eta=1e-3,
        n_lazy=max(plan.n_clients // 8, 0), sigma2=1e-4,
        mine_attempts=mine_attempts, difficulty_bits=8,
        microbatches=microbatches, eval_global_loss=False)


# ---------------------------------------------------------------------------
# Train (BLADE-FL integrated round)
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     multi_pod: bool, dtype=jnp.bfloat16,
                     spec_override: Optional[rounds.RoundSpec] = None,
                     plan: Optional[specs_lib.ShardingPlan] = None):
    """Returns (jitted_step, (state_specs, batch_specs) abstract inputs)."""
    cfg = resolve_cfg(cfg, shape)
    plan = plan or plans_lib.train_plan(cfg, shape, mesh, multi_pod)
    rspec = spec_override or round_spec_for(cfg, shape, plan)

    def loss_fn(params, batch):
        return registry.loss_fn(params, cfg, batch, remat=True)

    round_fn = rounds.make_integrated_round(loss_fn, rspec)

    # --- abstract inputs --------------------------------------------------
    params_abs = registry.params_specs(cfg, dtype, n_clients=plan.n_clients)
    key_abs = jax.eval_shape(lambda: jax.random.key(0))
    state_abs = rounds.RoundState(
        params=params_abs, key=key_abs,
        round_idx=jax.ShapeDtypeStruct((), jnp.int32),
        prev_hash=jax.ShapeDtypeStruct((), jnp.uint32))
    batch_abs = registry.train_batch_specs(cfg, shape, dtype,
                                           n_clients=plan.n_clients)

    # --- shardings ---------------------------------------------------------
    pspecs = specs_lib.param_pspecs(cfg, mesh, plan, params_abs)
    state_sh = rounds.RoundState(
        params=specs_lib.to_shardings(mesh, pspecs),
        key=specs_lib.replicated(mesh),
        round_idx=specs_lib.replicated(mesh),
        prev_hash=specs_lib.replicated(mesh))
    batch_sh = specs_lib.to_shardings(
        mesh, specs_lib.train_batch_pspecs(cfg, plan, batch_abs))
    metrics_sh = jax.tree.map(lambda _: specs_lib.replicated(mesh),
                              {"local_loss": 0, "winner": 0, "pow_hash": 0,
                               "nonce": 0, "solved": 0, "digest": 0,
                               "divergence": 0})

    step = jax.jit(round_fn, in_shardings=(state_sh, batch_sh),
                   out_shardings=(state_sh, metrics_sh))
    return step, (state_abs, batch_abs), plan, rspec


# ---------------------------------------------------------------------------
# Serve: prefill
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       multi_pod: bool, dtype=jnp.bfloat16,
                       plan: Optional[specs_lib.ShardingPlan] = None):
    cfg = resolve_cfg(cfg, shape)
    plan = plan or plans_lib.serve_plan(cfg, shape, mesh, multi_pod)

    def prefill_fn(params, batch):
        return transformer.prefill(params, cfg, batch, max_len=shape.seq_len,
                                   remat=True)

    params_abs = registry.params_specs(cfg, dtype)
    batch_abs = registry.prefill_batch_specs(cfg, shape, dtype)
    pspecs = specs_lib.param_pspecs(cfg, mesh, plan, params_abs)
    params_sh = specs_lib.to_shardings(mesh, pspecs)
    batch_sh = specs_lib.to_shardings(
        mesh, specs_lib.serve_batch_pspecs(plan, batch_abs))
    step = jax.jit(prefill_fn, in_shardings=(params_sh, batch_sh))
    return step, (params_abs, batch_abs), plan


# ---------------------------------------------------------------------------
# Serve: single-token decode
# ---------------------------------------------------------------------------


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      multi_pod: bool, dtype=jnp.bfloat16,
                      plan: Optional[specs_lib.ShardingPlan] = None):
    cfg = resolve_cfg(cfg, shape)
    plan = plan or plans_lib.serve_plan(cfg, shape, mesh, multi_pod)

    def decode_fn(params, state, token, pos):
        return transformer.decode_step(params, cfg, state, token, pos)

    params_abs = registry.params_specs(cfg, dtype)
    dec = registry.decode_input_specs(cfg, shape, dtype)
    state_abs, token_abs, pos_abs = dec["state"], dec["token"], dec["pos"]

    pspecs = specs_lib.param_pspecs(cfg, mesh, plan, params_abs)
    params_sh = specs_lib.to_shardings(mesh, pspecs)
    state_sh = specs_lib.to_shardings(
        mesh, specs_lib.decode_state_pspecs(cfg, mesh, plan, state_abs))
    token_sh = NamedSharding(mesh, P(plan.batch_axes if plan.batch_axes else None))
    pos_sh = specs_lib.replicated(mesh)
    logits_sh = NamedSharding(
        mesh, P(plan.batch_axes if plan.batch_axes else None,
                "model" if cfg.vocab % mesh.shape["model"] == 0 else None))
    step = jax.jit(decode_fn,
                   in_shardings=(params_sh, state_sh, token_sh, pos_sh),
                   out_shardings=(logits_sh, state_sh))
    return step, (params_abs, state_abs, token_abs, pos_abs), plan


def build_step(kind: str, cfg, shape, mesh, multi_pod, dtype=jnp.bfloat16):
    if kind == "train":
        step, abs_in, plan, _ = build_train_step(cfg, shape, mesh, multi_pod, dtype)
        return step, abs_in, plan
    if kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, multi_pod, dtype)
    return build_decode_step(cfg, shape, mesh, multi_pod, dtype)
