# Intentionally import-light: repro.launch.dryrun must be able to set
# XLA_FLAGS (512 placeholder devices) BEFORE anything touches jax's backend,
# so this package does not import submodules eagerly. Import what you need:
#   from repro.launch import mesh, steps, analysis, hlo_analysis
