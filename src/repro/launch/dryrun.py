import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers AND compiles on the production meshes, and extract the
roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40-pair sweep
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline via benchmarks.roofline.
"""
import argparse
import json
import time
import traceback

from repro.configs import INPUT_SHAPES, arch_ids, get_arch, get_shape
from repro.launch import analysis, hlo_analysis, mesh as mesh_lib, steps

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _memory_analysis_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend without memory analysis
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cost_analysis_dict(compiled):
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    keep = {}
    for k, v in ca.items():
        if isinstance(v, (int, float)) and (
                k in ("flops", "bytes accessed", "optimal_seconds")
                or k.startswith("bytes accessed")):
            keep[k] = float(v)
    return keep


def run_pair(arch: str, shape_name: str, multi_pod: bool,
             tokens_override=None) -> dict:
    cfg0 = get_arch(arch)
    shape = get_shape(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "status": "ok"}
    reason = steps.skip_reason(cfg0, shape)
    if reason:
        rec.update(status="skipped", reason=reason)
        return rec
    cfg = steps.resolve_cfg(cfg0, shape)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    with mesh:
        step, abs_in, plan = steps.build_step(shape.kind, cfg0, shape, mesh,
                                              multi_pod)
        lowered = step.lower(*abs_in) if isinstance(abs_in, tuple) \
            else step.lower(abs_in)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    rec["plan"] = {
        "n_clients": plan.n_clients, "client_axes": list(plan.client_axes),
        "batch_axes": list(plan.batch_axes), "fsdp_axes": list(plan.fsdp_axes),
        "seq_axes": list(plan.seq_axes),
    }
    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)
    rec["memory_analysis"] = _memory_analysis_dict(compiled)
    # raw XLA numbers (loop bodies counted ONCE — reference only, see
    # hlo_analysis docstring); the roofline uses the loop-corrected parse.
    rec["cost_analysis_raw"] = _cost_analysis_dict(compiled)

    hlo = compiled.as_text()
    parsed = hlo_analysis.analyze_dict(hlo)
    rec["hlo_parsed"] = parsed
    rec["hlo_bytes_len"] = len(hlo)

    flops_dev = parsed["flops"]
    bytes_dev = parsed["hbm_bytes"]
    coll_dev = parsed["collective_bytes"]
    rec["roofline"] = analysis.roofline(flops_dev, bytes_dev, coll_dev, chips)

    # MODEL_FLOPS (useful-compute reference)
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tau = 2  # round_spec_for default
        tokens = shape.global_batch * (shape.seq_len - 1)
        mf = analysis.model_flops(n_active, tokens, True, tau)
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = analysis.model_flops(n_active, tokens, False)
    else:
        mf = analysis.model_flops(n_active, shape.global_batch, False)
    rec["model_flops"] = mf
    total_hlo_flops = flops_dev * chips
    rec["useful_flops_ratio"] = (mf / total_hlo_flops) if total_hlo_flops else None
    rec["active_params"] = n_active
    rec["total_params"] = cfg.param_count()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default=OUT_DIR)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    pairs = []
    archs = arch_ids() if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                pairs.append((a, s, mp))

    n_ok = n_skip = n_fail = 0
    for a, s, mp in pairs:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        out_path = os.path.join(args.out_dir, f"{a}__{s}__{mesh_name}.json")
        if os.path.exists(out_path):
            with open(out_path) as f:
                prev = json.load(f)
            if prev.get("status") in ("ok", "skipped"):
                print(f"[cached ] {a} x {s} x {mesh_name}: {prev['status']}")
                n_ok += prev["status"] == "ok"
                n_skip += prev["status"] == "skipped"
                continue
        print(f"[running] {a} x {s} x {mesh_name} ...", flush=True)
        try:
            rec = run_pair(a, s, mp)
        except Exception as e:
            rec = {"arch": a, "shape": s, "mesh": mesh_name,
                   "status": "failed", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
        if rec["status"] == "ok":
            n_ok += 1
            r = rec["roofline"]
            print(f"  ok lower={rec['lower_s']}s compile={rec['compile_s']}s "
                  f"dominant={r['dominant']} bound={r['bound_s']:.4g}s "
                  f"useful={rec['useful_flops_ratio']}")
        elif rec["status"] == "skipped":
            n_skip += 1
            print(f"  skipped: {rec['reason']}")
        else:
            n_fail += 1
            print(f"  FAILED: {rec['error']}")
    print(f"\nsummary: ok={n_ok} skipped={n_skip} failed={n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
