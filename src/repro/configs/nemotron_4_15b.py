"""nemotron-4-15b [dense] — GQA, squared-ReLU MLP [arXiv:2402.16819].

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24_576,
    vocab=256_000,
    head_dim=128,
    mlp="squared_relu",
    rope_theta=10_000.0,
    source="arXiv:2402.16819",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="nemotron-4-15b-smoke",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab=512,
)
