"""Config system: architecture configs, input-shape configs, registries.

Every assigned architecture gets a ``ModelConfig`` in its own module
(``src/repro/configs/<id>.py``) citing its source. Input shapes are the four
assigned (train_4k / prefill_32k / decode_32k / long_500k) plus reduced smoke
variants used by CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0           # shared (always-on) experts
    d_ff: int = 0               # per-expert hidden dim
    every: int = 1              # MoE MLP on layers where (layer % every == every-1)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 1e-2


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention."""
    kv_lora: int = 512
    q_lora: int = 0             # 0 => full-rank q projection
    rope_dim: int = 64          # decoupled rope key dim (shared across heads)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0            # 0 => ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    # which blocks in a period are sLSTM (others mLSTM); xLSTM[7:1] style
    slstm_every: int = 4        # layer % every == every-1 -> sLSTM
    proj_factor: float = 2.0    # up-projection factor inside mLSTM block
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 => d_model // n_heads
    mlp: str = "swiglu"         # swiglu | squared_relu | gelu | geglu
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    causal: bool = True         # False => encoder (bidirectional)
    # sliding-window attention (0 = full). Enables long_500k for dense archs.
    sliding_window: int = 0
    # hybrid layout: period pattern of block kinds, tiled over n_layers.
    # kinds: "attn" | "ssm" | "mlstm" | "slstm". None => all "attn".
    block_pattern: Optional[Tuple[str, ...]] = None
    n_dense_prefix: int = 0     # first layers use dense MLP even if MoE
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # vlm / audio frontends are stubs: inputs arrive as embeddings.
    vlm_prefix_len: int = 0     # number of image-patch embedding positions
    audio_frontend: bool = False
    source: str = ""            # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.block_pattern is not None:
            return self.block_pattern
        return ("attn",)

    @property
    def has_decode(self) -> bool:
        return self.causal

    @property
    def subquadratic(self) -> bool:
        """True when long-context decode is affordable (no full-attn O(S) cache
        scan per step, or sliding window bounds it)."""
        kinds = set(self.pattern)
        if kinds <= {"ssm", "mlstm", "slstm"}:
            return True
        return self.sliding_window > 0 or "ssm" in kinds or "mlstm" in kinds

    def layer_kinds(self) -> Tuple[str, ...]:
        pat = self.pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_attn = 0
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = emb + d  # final norm
        for kind in self.layer_kinds():
            total += 2 * d  # per-block norms
            if kind == "attn":
                if self.mla is not None:
                    m = self.mla
                    q_in = m.q_lora or d
                    total += (d * m.q_lora if m.q_lora else 0)
                    total += q_in * self.n_heads * (hd + m.rope_dim)
                    total += d * (m.kv_lora + m.rope_dim)
                    total += m.kv_lora * self.n_heads * 2 * hd
                    total += self.n_heads * hd * d
                else:
                    total += d * self.n_heads * hd
                    total += 2 * d * self.n_kv_heads * hd
                    total += self.n_heads * hd * d
                n_attn += 1
            elif kind == "ssm":
                s = self.ssm or SSMConfig()
                d_in = s.expand * d
                dt_rank = s.dt_rank or -(-d // 16)
                total += d * 2 * d_in              # in_proj (x, z)
                total += d_in * s.d_conv           # depthwise conv
                total += d_in * (dt_rank + 2 * s.d_state)
                total += dt_rank * d_in + d_in     # dt proj + bias
                total += d_in * s.d_state + d_in   # A_log, D
                total += d_in * d                  # out_proj
            elif kind in ("mlstm", "slstm"):
                x = self.xlstm or XLSTMConfig()
                d_in = int(x.proj_factor * d)
                total += d * 2 * d_in              # up proj (x, z)
                total += 3 * d_in * d_in // max(self.n_heads, 1) * self.n_heads  # qkv-ish
                total += 3 * d_in                  # gates
                total += d_in * d                  # down proj
            # MLP
            li = len([k for k in []])  # placeholder, replaced below
        # MLP params per layer (dense vs MoE), done in a second pass for clarity
        for i in range(self.n_layers):
            use_moe = (
                self.moe is not None
                and i >= self.n_dense_prefix
                and i % self.moe.every == self.moe.every - 1
            )
            gated = self.mlp in ("swiglu", "geglu")
            mult = 3 if gated else 2
            if use_moe:
                m = self.moe
                total += m.n_experts * mult * d * m.d_ff
                total += m.n_shared * mult * d * m.d_ff
                total += d * m.n_experts  # router
            elif self.d_ff > 0:
                total += mult * d * self.d_ff
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only top_k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        gated = self.mlp in ("swiglu", "geglu")
        mult = 3 if gated else 2
        total = self.param_count()
        n_moe_layers = sum(
            1
            for i in range(self.n_layers)
            if i >= self.n_dense_prefix and i % m.every == m.every - 1
        )
        inactive = (m.n_experts - m.top_k) * mult * d * m.d_ff * n_moe_layers
        return int(total - inactive)


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

SMOKE_SHAPES = {
    "smoke_train": ShapeConfig("smoke_train", 64, 4, "train"),
    "smoke_prefill": ShapeConfig("smoke_prefill", 64, 2, "prefill"),
    "smoke_decode": ShapeConfig("smoke_decode", 64, 2, "decode"),
}


# ---------------------------------------------------------------------------
# BLADE-FL experiment config (paper substrate)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BladeConfig:
    """Paper §7 experimental knobs (time normalized by alpha as in the paper)."""
    n_clients: int = 20
    n_lazy: int = 0
    sigma2: float = 0.0          # lazy artificial-noise variance
    t_sum: float = 100.0         # total computing time budget
    alpha: float = 1.0           # training time per local iteration
    beta: float = 10.0           # mining time per block
    eta: float = 0.01            # learning rate
    K: int = 5                   # integrated rounds
    samples_per_client: int = 512
    dirichlet_alpha: float = 0.5 # non-IID-ness
    dp_sigma: float = 0.0        # DP Gaussian mechanism on broadcast models
    seed: int = 0

    @property
    def tau(self) -> int:
        from repro.core.allocation import tau_from_budget
        return tau_from_budget(self.t_sum, self.K, self.alpha, self.beta)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ARCH_IDS = (
    "xlstm-125m",
    "qwen3-32b",
    "nemotron-4-15b",
    "jamba-1.5-large-398b",
    "paligemma-3b",
    "hubert-xlarge",
    "phi4-mini-3.8b",
    "kimi-k2-1t-a32b",
    "minicpm-2b",
    "deepseek-v2-236b",
)


def arch_ids() -> Sequence[str]:
    return _ARCH_IDS


def get_arch(arch_id: str) -> ModelConfig:
    import importlib

    mod_name = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke_arch(arch_id: str) -> ModelConfig:
    import importlib

    mod_name = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE


def get_shape(name: str) -> ShapeConfig:
    if name in INPUT_SHAPES:
        return INPUT_SHAPES[name]
    return SMOKE_SHAPES[name]
