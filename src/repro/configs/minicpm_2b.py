"""minicpm-2b [dense] — WSD schedule, llama-like arch [arXiv:2404.06395].

40L d_model=2304 36H (kv=36, MHA) d_ff=5760 vocab=122753.
The WSD (warmup-stable-decay) schedule lives in repro.training.optim and is
selected by this config's train recipe.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122_753,
    head_dim=64,
    mlp="swiglu",
    tie_embeddings=True,
    source="arXiv:2404.06395",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="minicpm-2b-smoke",
    n_layers=2,
    d_model=144,
    n_heads=4,
    n_kv_heads=4,
    head_dim=36,
    d_ff=288,
    vocab=512,
)
