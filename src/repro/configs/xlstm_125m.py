"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304. d_ff=0: xLSTM blocks carry
their own up/down projections, there is no separate FFN sub-layer.
Block layout: period of 4 = 3 mLSTM + 1 sLSTM (xLSTM[3:1] style).
"""
import dataclasses

from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50_304,
    mlp="gelu",
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    xlstm=XLSTMConfig(slstm_every=4, proj_factor=2.0, conv_width=4),
    source="arXiv:2405.04517",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="xlstm-125m-smoke",
    n_layers=2,
    d_model=128,
    n_heads=2,
    n_kv_heads=2,
    vocab=512,
    block_pattern=("mlstm", "slstm"),
)
