"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE [arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Period of 8 blocks: 7 Mamba + 1 attention (attn at index 3, Jamba-style);
MoE MLP on every 2nd layer, dense MLP otherwise.
"""
import dataclasses

from repro.configs.base import MoEConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24_576,
    vocab=65_536,
    head_dim=128,
    mlp="swiglu",
    block_pattern=("ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm", "ssm"),
    moe=MoEConfig(n_experts=16, top_k=2, n_shared=0, d_ff=24_576, every=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    source="arXiv:2403.19887",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="jamba-1.5-large-398b-smoke",
    n_layers=2,
    d_model=128,
    n_heads=2,
    n_kv_heads=2,
    head_dim=64,
    d_ff=256,
    vocab=512,
    block_pattern=("ssm", "attn"),
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=0, d_ff=256, every=2),
    ssm=SSMConfig(d_state=8, d_conv=4, expand=2),
)
