"""hubert-xlarge [audio] — encoder-only, wav2vec2-style backbone [arXiv:2106.07447].

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (masked-prediction codebook).
The mel-spectrogram + conv feature extractor is a STUB: input_specs() provides
precomputed frame embeddings (batch, frames, d_model). Encoder-only: no decode
step exists — decode_32k and long_500k are skipped (see DESIGN.md §4).
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    mlp="gelu",
    causal=False,
    audio_frontend=True,
    source="arXiv:2106.07447",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="hubert-xlarge-smoke",
    n_layers=2,
    d_model=128,
    n_heads=2,
    n_kv_heads=2,
    d_ff=256,
    vocab=96,
)
