"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6 [arXiv:2405.04434].

60L d_model=5120 128H d_ff=1536 (per-expert) vocab=102400. MLA with
kv_lora_rank=512, q_lora_rank=1536, decoupled rope dim 64; first layer dense.
"""
import dataclasses

from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,  # MLA: per-head keys reconstructed from the shared latent
    d_ff=1536,
    vocab=102_400,
    head_dim=128,
    mlp="swiglu",
    n_dense_prefix=1,
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2, d_ff=1536, every=1),
    mla=MLAConfig(kv_lora=512, q_lora=1536, rope_dim=64),
    source="arXiv:2405.04434",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="deepseek-v2-236b-smoke",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=128,
    vocab=512,
    n_dense_prefix=1,
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_ff=128, every=1),
    mla=MLAConfig(kv_lora=64, q_lora=0, rope_dim=16),
)
