"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2412.08905].

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=200_064,
    head_dim=128,
    mlp="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="arXiv:2412.08905",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="phi4-mini-3.8b-smoke",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab=512,
)
