"""qwen3-32b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family scaled].

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25_600,
    vocab=151_936,
    head_dim=128,
    mlp="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="qwen3-32b-smoke",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab=512,
)
