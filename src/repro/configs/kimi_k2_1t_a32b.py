"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table) [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per-expert) vocab=163840,
MoE 384 experts top-8 + 1 shared, first layer dense.
"""
import dataclasses

from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163_840,
    head_dim=112,  # 7168 / 64
    mlp="swiglu",
    n_dense_prefix=1,
    moe=MoEConfig(n_experts=384, top_k=8, n_shared=1, d_ff=2048, every=1),
    source="arXiv:2501.kimi2",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="kimi-k2-1t-a32b-smoke",
    n_layers=2,
    d_model=128,
    n_heads=2,
    n_kv_heads=2,
    head_dim=64,
    d_ff=128,
    vocab=512,
    n_dense_prefix=1,
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_ff=128, every=1),
)
