"""paligemma-3b [vlm] — SigLIP + gemma decoder [arXiv:2407.07726].

18L d_model=2048 8H (GQA kv=1 = MQA) d_ff=16384 vocab=257216.
SigLIP vision tower + projector are STUBS: input_specs() provides 256
precomputed patch embeddings of shape (batch, 256, d_model); the gemma-style
decoder (built here in full) consumes them with prefix-LM attention.
"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16_384,
    vocab=257_216,
    head_dim=256,
    mlp="geglu",
    tie_embeddings=True,
    vlm_prefix_len=256,
    source="arXiv:2407.07726",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="paligemma-3b-smoke",
    n_layers=2,
    d_model=128,
    n_heads=2,
    n_kv_heads=1,
    head_dim=64,
    d_ff=256,
    vocab=512,
    vlm_prefix_len=16,
)
