"""PartitionSpec rules for every parameter / batch / decode-state tensor.

Layouts (DESIGN.md §3):
  L1 "client-sharded"   — the faithful BLADE-FL mapping: the client axis C is
      sharded over 'data' (x 'pod'); aggregation IS the all-reduce over the
      client axis. Used when C == data-axis extent (small/mid archs).
  L2 "client-replicated + FSDP" — for giant models C is small and replicated;
      parameters are additionally sharded over 'data' (FSDP) so N model
      replicas fit; the per-client local batch is data-parallel inside each
      client. Aggregation is then shard-local math and the per-iteration
      grad all-reduce over 'data' carries the communication cost.

Rules are name+kind-based over the param pytree paths produced by
models.transformer.init_lm; anything unmatched is replicated (safe default —
XLA propagates).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Static description of how one run is laid out on the mesh."""
    n_clients: int
    client_axes: Tuple[str, ...]        # () => client axis replicated (L2)
    batch_axes: Tuple[str, ...]         # per-client batch / serve batch axes
    model_axes: Tuple[str, ...] = ("model",)
    fsdp_axes: Tuple[str, ...] = ()     # () => no FSDP
    seq_axes: Tuple[str, ...] = ()      # decode-cache sequence sharding


def _extent(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _div(dim: int, mesh: Mesh, axes: Tuple[str, ...]):
    """axes if dim divisible by their extent (and axes non-empty) else None."""
    if not axes:
        return None
    return axes if dim % _extent(mesh, axes) == 0 else None


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _kind_of_path(cfg: ModelConfig, path: str) -> str:
    m = re.search(r"period/j(\d+)", path)
    if m:
        return cfg.pattern[int(m.group(1))]
    return "attn"  # prefix blocks are attention


def _param_spec(cfg: ModelConfig, mesh: Mesh, plan: ShardingPlan, path: str,
                shape: Tuple[int, ...]) -> P:
    """Spec for one leaf EXCLUDING client/period leading axes (handled by
    caller); ``shape`` here is the per-layer logical shape."""
    mdl, fsdp = plan.model_axes, plan.fsdp_axes
    name = path.split("/")[-1]
    kind = _kind_of_path(cfg, path)
    nd = len(shape)

    def spec(*entries):
        return P(*(entries + (None,) * (nd - len(entries))))

    if name == "embed":
        return spec(_div(shape[0], mesh, mdl), _div(shape[1], mesh, fsdp))
    if name == "lm_head":
        return spec(_div(shape[0], mesh, fsdp), _div(shape[1], mesh, mdl))
    if name in ("w_q", "w_uq", "w_up"):
        return spec(_div(shape[0], mesh, fsdp), _div(shape[1], mesh, mdl))
    if name in ("w_k", "w_v") and kind == "attn":
        return spec(_div(shape[0], mesh, fsdp), _div(shape[1], mesh, mdl))
    if name == "w_o" and kind == "attn":
        return spec(_div(shape[0], mesh, mdl), _div(shape[1], mesh, fsdp))
    if name in ("w_dkv", "w_dq"):
        return spec(_div(shape[0], mesh, fsdp), None)
    if name in ("w_uk", "w_uv"):
        return spec(None, _div(shape[1], mesh, mdl))
    if name in ("w_in", "w_gate"):
        if nd == 3:  # MoE experts [E, D, F]: expert-parallel + FSDP on F
            return spec(_div(shape[0], mesh, mdl), None, _div(shape[2], mesh, fsdp))
        return spec(_div(shape[0], mesh, fsdp), _div(shape[1], mesh, mdl))
    if name == "w_out":
        if nd == 3:  # [E, F, D]: shard the OUTPUT dim, not the contraction —
            # contracting a 'data'-sharded F makes XLA all-reduce the big
            # [E, C, D] partials every expert matmul (§Perf iteration K2:
            # 587MB AR -> 168MB all-gather of the f-sharded activations).
            return spec(_div(shape[0], mesh, mdl), None, _div(shape[2], mesh, fsdp))
        return spec(_div(shape[0], mesh, mdl), _div(shape[1], mesh, fsdp))
    if name == "router":
        return spec(None, None)
    # --- SSM ---
    if name == "w_x":
        return spec(_div(shape[0], mesh, mdl), None)
    if name == "w_dt":
        return spec(None, _div(shape[1], mesh, mdl))
    if name == "a_log":
        return spec(_div(shape[0], mesh, mdl), None)
    if name in ("d_skip", "dt_bias"):
        return spec(_div(shape[0], mesh, mdl))
    # --- xLSTM (square projections inside the up-projected space) ---
    if name in ("w_z", "w_i", "w_f", "w_o", "w_k", "w_v"):  # non-attn kinds
        return spec(None, _div(shape[1], mesh, mdl))
    if name in ("r_z", "r_i", "r_f", "r_o"):
        return spec(_div(shape[0], mesh, mdl), None, None)
    if name == "w_down":
        return spec(_div(shape[0], mesh, mdl), _div(shape[1], mesh, fsdp))
    if name == "f_bias":
        return spec(_div(shape[0], mesh, mdl))
    if name == "w" and "conv" in path:  # depthwise conv [W, C]
        return spec(None, _div(shape[1], mesh, mdl))
    if name == "b" and "conv" in path:
        return spec(_div(shape[0], mesh, mdl))
    if name == "scale" and path.endswith("o_norm/scale"):
        return spec(_div(shape[0], mesh, mdl))
    # norms, biases, mask_emb, pos_conv, everything else: replicated
    return P(*([None] * nd))


def param_pspecs(cfg: ModelConfig, mesh: Mesh, plan: ShardingPlan,
                 params_tree: Any) -> Any:
    """PartitionSpec pytree matching ``params_tree`` (abstract or concrete).

    Handles the structural leading axes: client axis (plan), period-stack
    axis (paths under period/), both prepended to the per-layer spec.
    """
    client_spec = plan.client_axes if plan.client_axes else None

    def one(path, leaf):
        pstr = _path_str(path)
        shape = tuple(leaf.shape)
        lead = []
        if plan.n_clients > 1:
            lead.append(client_spec)
            shape = shape[1:]
        if "period/" in pstr:
            lead.append(None)       # period-stack axis
            shape = shape[1:]
        inner = _param_spec(cfg, mesh, plan, pstr, shape)
        return P(*(tuple(lead) + tuple(inner)))

    return jax.tree_util.tree_map_with_path(one, params_tree)


# ---------------------------------------------------------------------------
# Batch / decode-state specs
# ---------------------------------------------------------------------------


def train_batch_pspecs(cfg: ModelConfig, plan: ShardingPlan, batch_tree: Any):
    """[C, m, ...] or [B, ...]: client axis per plan, batch dim per plan."""

    def one(path, leaf):
        nd = len(leaf.shape)
        if plan.n_clients > 1:
            lead = (plan.client_axes if plan.client_axes else None,
                    plan.batch_axes if plan.batch_axes else None)
        else:
            lead = (plan.batch_axes if plan.batch_axes else None,)
        return P(*(lead + (None,) * (nd - len(lead))))

    return jax.tree_util.tree_map_with_path(one, batch_tree)


def serve_batch_pspecs(plan: ShardingPlan, batch_tree: Any):
    def one(leaf):
        nd = len(leaf.shape)
        return P(*((plan.batch_axes if plan.batch_axes else None,)
                   + (None,) * (nd - 1)))
    return jax.tree.map(one, batch_tree)


def decode_state_pspecs(cfg: ModelConfig, mesh: Mesh, plan: ShardingPlan,
                        state_tree: Any):
    """Decode caches: [n_per?, B, S, ...] for attention KV; recurrent states
    [n_per?, B, ...]. Sequence axis sharded per plan.seq_axes (sequence-
    parallel decode; softmax partial reductions lower to all-reduces)."""
    batch = plan.batch_axes if plan.batch_axes else None
    seq = plan.seq_axes if plan.seq_axes else None

    def one(path, leaf):
        pstr = _path_str(path)
        shape = tuple(leaf.shape)
        lead: list = []
        if "period/" in pstr:
            lead = [None]
            shape = shape[1:]
        name = pstr.split("/")[-1]
        if name in ("k", "v"):          # [B, S, Hkv, hd]
            inner = (batch, _seq_ok(seq, shape[1], mesh), None, None)
        elif name in ("ckv", "k_rope"):  # [B, S, d]
            inner = (batch, _seq_ok(seq, shape[1], mesh), None)
        elif name == "conv":            # [B, W-1, d_in]
            inner = (batch, None, _div(shape[2], mesh, plan.model_axes))
        elif name == "h" and len(shape) == 3:   # ssm [B, d_in, ds]
            inner = (batch, _div(shape[1], mesh, plan.model_axes), None)
        elif name == "C":               # mlstm [B, H, hd, hd]
            inner = (batch, _div(shape[1], mesh, plan.model_axes), None, None)
        elif name in ("n", "m", "c", "h"):
            hdiv = _div(shape[1], mesh, plan.model_axes) if len(shape) > 1 else None
            inner = (batch,) + ((hdiv,) + (None,) * (len(shape) - 2) if len(shape) > 1 else ())
        else:
            inner = (batch,) + (None,) * (len(shape) - 1)
        return P(*(tuple(lead) + tuple(inner)))

    return jax.tree_util.tree_map_with_path(one, state_tree)


def _seq_ok(seq, dim, mesh):
    if seq is None:
        return None
    return seq if dim % _extent(mesh, seq) == 0 else None


# ---------------------------------------------------------------------------
# NamedSharding helpers
# ---------------------------------------------------------------------------


def to_shardings(mesh: Mesh, pspec_tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
