"""Per-(architecture x input-shape x mesh) layout decisions.

The client count C and layout are an explicit table — BLADE-FL needs C model
replicas somewhere, which is the protocol's real memory price at scale (see
EXPERIMENTS.md §Roofline notes): small/mid archs run the faithful
client-sharded layout (L1, C = data extent); giants run client-replicated +
FSDP (L2) with few clients, and kimi-k2 documents the N>=2 infeasibility at
256 chips honestly rather than hiding it.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.sharding.specs import ShardingPlan

# arch -> (layout, single-pod C, multi-pod C)
_TRAIN_TABLE = {
    "xlstm-125m": ("L1", 16, 32),
    "qwen3-32b": ("L2", 4, 4),
    "nemotron-4-15b": ("L1", 16, 32),
    "jamba-1.5-large-398b": ("L2", 2, 2),
    "paligemma-3b": ("L1", 16, 32),
    "hubert-xlarge": ("L1", 16, 32),
    "phi4-mini-3.8b": ("L1", 16, 32),
    "kimi-k2-1t-a32b": ("L2", 2, 2),   # >HBM at 256 chips; documented finding
    "minicpm-2b": ("L1", 16, 32),
    "deepseek-v2-236b": ("L2", 2, 2),
}

# serve: enable FSDP when TP-only params per device exceed ~12 GB
_FSDP_SERVE_BYTES = 12e9


def _client_axis_extents(mesh: Mesh, client_axes: Tuple[str, ...],
                         what: str) -> Tuple[int, Tuple[int, ...]]:
    """Shared ``client_axes`` validation for the carry-plan builders:
    non-empty, no duplicates, every name on the mesh. Returns the shard
    count with the per-axis extents so divisibility errors can spell out
    the full axis product instead of surfacing as an opaque shard_map
    size mismatch."""
    if not client_axes:
        raise ValueError(
            f"client_axes must name at least one mesh axis (an empty tuple "
            f"would replicate the {what} and silently run every client on "
            "every shard)")
    dupes = sorted({a for a in client_axes if client_axes.count(a) > 1})
    if dupes:
        raise ValueError(
            f"client_axes {tuple(client_axes)} name mesh axes more than "
            f"once ({', '.join(map(repr, dupes))}); each axis shards the "
            "client dimension at most once")
    for a in client_axes:
        if a not in mesh.shape:
            raise ValueError(f"mesh has no axis {a!r}: {dict(mesh.shape)}")
    sizes = tuple(int(mesh.shape[a]) for a in client_axes)
    n_shards = 1
    for s in sizes:
        n_shards *= s
    return n_shards, sizes


def _axis_product(client_axes: Tuple[str, ...],
                  sizes: Tuple[int, ...]) -> str:
    """``"8 (= pod:2 x data:4)"`` — the full axis-product for error text."""
    n = 1
    for s in sizes:
        n *= s
    if len(sizes) == 1:
        return f"{n} ({client_axes[0]}:{sizes[0]})"
    prod = " x ".join(f"{a}:{s}" for a, s in zip(client_axes, sizes))
    return f"{n} (= {prod})"


@dataclasses.dataclass(frozen=True)
class ScanCarryPlan:
    """Layout of the K-round scan engine's carry on a client-sharded mesh.

    The L1 story applied to the WHOLE compiled horizon, not just one round:
    the ``RoundState`` carry has its client-stacked leaves (params, and the
    per-client batch riding along as scan xs) split along ``client_axes``,
    while the protocol scalars every client must agree on — the PRNG key
    each round's lazy/DP/topology streams fold from, the round counter, and
    ``prev_hash`` (the ledger head every block header links to) — stay
    replicated. Mining state (each client's best hash/nonce) lives inside
    the round sharded like the clients that produced it and is only
    gathered for the winner argmin. ``core.rounds._scan_runner`` turns this
    into ``shard_map`` in/out specs, so the donated carry keeps this layout
    across all K rounds without ever leaving the devices.

    Frozen + hashable: the plan is part of the compiled-runner cache key.

    ``axis_sizes`` carries the mesh's per-axis extents (aligned with
    ``client_axes``) so ``topology.resolve_mix_plan`` can judge
    cluster/halo alignment on compound ``('pod', 'data')`` axes without
    holding a mesh reference; empty means "extents unknown, only
    ``n_shards`` is attributed".
    """
    n_clients: int
    client_axes: Tuple[str, ...] = ("data",)
    n_shards: int = 1
    axis_sizes: Tuple[int, ...] = ()

    @property
    def clients_per_shard(self) -> int:
        return self.n_clients // self.n_shards

    def client_spec(self) -> P:
        """Spec prefix for client-stacked leaves ([C, ...] -> axis 0)."""
        return P(self.client_axes)

    def batch_spec(self, stacked: bool) -> P:
        """Per-round batches are ``[C, ...]``; a ``stacked=True`` source is
        ``[K, C, ...]`` — the scan consumes axis 0, clients sit on axis 1."""
        return P(None, self.client_axes) if stacked else P(self.client_axes)


def scan_carry_plan(mesh: Mesh, n_clients: int,
                    client_axes: Tuple[str, ...] = ("data",)) -> ScanCarryPlan:
    """Build + validate the scan-carry layout for ``mesh``.

    ``n_clients`` must divide evenly over the extent of ``client_axes`` —
    every shard carries the same static client block, which is what keeps
    the per-shard program identical (and the sharded scan bit-for-bit with
    the single-device one — or, under ``RoundSpec.fast_allreduce`` /
    ``RoundSpec.robust_agg``, within the tolerance tier: the psum lowerings
    slice per-shard weight/column blocks by the same linearized shard index
    this layout defines, and the robust reducers slice their local rows
    back out of the gathered order statistics by it, so both require the
    uniform block size validated here)."""
    client_axes = tuple(client_axes)
    n_shards, sizes = _client_axis_extents(mesh, client_axes, "client axis")
    if n_clients % n_shards != 0:
        raise ValueError(
            f"n_clients={n_clients} not divisible by the client-axis "
            f"extent {_axis_product(client_axes, sizes)}; pick C as a "
            "multiple of the device count")
    return ScanCarryPlan(n_clients=n_clients, client_axes=client_axes,
                         n_shards=n_shards, axis_sizes=sizes)


@dataclasses.dataclass(frozen=True)
class CohortCarryPlan:
    """Carry layout for the cohort-sampled driver
    (``core.rounds.run_blade_fl_cohort``).

    Only the ``[A, ...]`` ACTIVE-cohort stack has a device layout — split
    along ``client_axes`` like the scan carry, protocol scalars replicated.
    The enrolled population (``n_enrolled``) deliberately has NO spec here:
    it lives in the host-side ``PopulationStore`` and crosses the host
    boundary one cohort per round, which is the whole memory story —
    devices scale with A, the host with touched clients, and nothing
    scales with C_enrolled² .

    Frozen + hashable: part of the cohort runner's cache key.
    """
    n_enrolled: int
    cohort_size: int
    client_axes: Tuple[str, ...] = ("data",)
    n_shards: int = 1
    axis_sizes: Tuple[int, ...] = ()

    @property
    def clients_per_shard(self) -> int:
        return self.cohort_size // self.n_shards

    def client_spec(self) -> P:
        """Spec prefix for cohort-stacked leaves ([A, ...] -> axis 0)."""
        return P(self.client_axes)

    def batch_spec(self, stacked: bool) -> P:
        """Cohort batches are ``[A, ...]`` (the driver feeds one round at a
        time, so there is no stacked [K, A, ...] form)."""
        return P(None, self.client_axes) if stacked else P(self.client_axes)


def cohort_carry_plan(mesh: Mesh, n_enrolled: int, cohort_size: int,
                      client_axes: Tuple[str, ...] = ("data",)
                      ) -> CohortCarryPlan:
    """Build + validate the cohort-carry layout for ``mesh``.

    Only ``cohort_size`` must divide over the client-axis extent — the
    enrolled population is host-side and never sharded, so ``n_enrolled``
    is unconstrained (and may be far larger than any device array could
    be)."""
    client_axes = tuple(client_axes)
    n_shards, sizes = _client_axis_extents(mesh, client_axes, "cohort")
    if not 1 <= cohort_size <= n_enrolled:
        raise ValueError(
            f"cohort_size={cohort_size} must lie in "
            f"[1, n_enrolled={n_enrolled}]")
    if cohort_size % n_shards != 0:
        raise ValueError(
            f"cohort_size={cohort_size} not divisible by the client-axis "
            f"extent {_axis_product(client_axes, sizes)}; pick A as a "
            "multiple of the device count")
    return CohortCarryPlan(n_enrolled=n_enrolled, cohort_size=cohort_size,
                           client_axes=client_axes, n_shards=n_shards,
                           axis_sizes=sizes)


def gathered_mix_models_moved(n_clients: int, n_shards: int) -> int:
    """Models RECEIVED per device per round by a gathered (all-gather +
    replicated math + keep-local-rows) mix lowering — the communication
    price of every robust reducer (``aggregation.mix_median`` et al.) and
    of the bitwise linear gather paths: ``C - C/D`` remote client blocks.
    The psum fast tier moves O(1) models instead, which is exactly the
    volume robust order statistics cannot reclaim (they are not
    psum-associative); ``benchmarks/bench_robust.py`` prices the gap."""
    if n_shards < 1 or n_clients % n_shards:
        raise ValueError(
            f"n_clients={n_clients} must divide over n_shards={n_shards}")
    return n_clients - n_clients // n_shards


def data_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def train_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               multi_pod: bool) -> ShardingPlan:
    layout, c_single, c_multi = _TRAIN_TABLE[cfg.name]
    c = c_multi if multi_pod else c_single
    daxes = data_axes(multi_pod)
    if layout == "L1":
        # faithful mapping: clients sharded over data(+pod); aggregation is
        # the all-reduce over the client axis.
        return ShardingPlan(n_clients=c, client_axes=daxes, batch_axes=(),
                            fsdp_axes=())
    # L2: giants — clients replicated, FSDP over data(+pod), per-client
    # batch data-parallel.
    return ShardingPlan(n_clients=c, client_axes=(), batch_axes=daxes,
                        fsdp_axes=daxes)


def serve_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               multi_pod: bool) -> ShardingPlan:
    daxes = data_axes(multi_pod)
    tp_bytes = cfg.param_count() * 2 / 16
    fsdp = daxes if tp_bytes > _FSDP_SERVE_BYTES else ()
    if shape.kind == "prefill":
        return ShardingPlan(n_clients=1, client_axes=(), batch_axes=daxes,
                            fsdp_axes=fsdp)
    # decode
    if shape.global_batch >= 16:  # decode_32k: batch over data, seq over model
        return ShardingPlan(n_clients=1, client_axes=(), batch_axes=daxes,
                            fsdp_axes=fsdp, seq_axes=("model",))
    # long_500k: batch 1 — sequence-parallel cache over every axis
    seq = ("pod", "data", "model") if multi_pod else ("data", "model")
    return ShardingPlan(n_clients=1, client_axes=(), batch_axes=(),
                        fsdp_axes=fsdp, seq_axes=seq)


def batch_divisible(cfg: ModelConfig, shape: ShapeConfig, plan: ShardingPlan,
                    mesh: Mesh) -> bool:
    from repro.sharding.specs import _extent
    if plan.batch_axes:
        per = shape.global_batch // max(plan.n_clients, 1)
        return per % _extent(mesh, plan.batch_axes) == 0
    return True
