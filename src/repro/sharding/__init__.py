from repro.sharding import plans, specs  # noqa: F401
