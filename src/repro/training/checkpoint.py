"""Checkpointing without external deps: pytrees <-> .npz + structure file.

Handles arbitrary nested dict/list/tuple/NamedTuple-free pytrees of arrays
(our params/state are plain dicts+lists). Keys are flattened jax.tree paths.
Includes the BLADE-FL ledger (JSON) so a restart resumes the hash chain.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.core import chain as chain_lib


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(directory: str, tree: Any, step: int = 0,
         ledger: Optional[chain_lib.Ledger] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {_path_str(p): np.asarray(v) for p, v in flat}
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez(path, **arrays)
    meta = {"step": step, "treedef": str(treedef), "keys": list(arrays)}
    if ledger is not None:
        meta["ledger"] = [vars(b) for b in ledger.blocks]
        meta["difficulty_bits"] = ledger.difficulty_bits
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(meta, f)
    return path


def restore(directory: str, template: Any, step: Optional[int] = None
            ) -> Tuple[Any, int, Optional[chain_lib.Ledger]]:
    """Restore into the structure of ``template`` (shapes must match)."""
    ckpts = sorted(f for f in os.listdir(directory) if f.endswith(".npz"))
    if not ckpts:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    name = f"ckpt_{step:08d}.npz" if step is not None else ckpts[-1]
    data = np.load(os.path.join(directory, name))
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, tmpl in flat:
        arr = data[_path_str(p)]
        if arr.shape != tmpl.shape:
            raise ValueError(
                f"checkpoint leaf {_path_str(p)}: stored shape {arr.shape} "
                f"does not match template shape {tmpl.shape}")
        leaves.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    meta_path = os.path.join(directory, name.replace(".npz", ".json"))
    got_step, ledger = 0, None
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
        got_step = meta.get("step", 0)
        if "ledger" in meta:
            ledger = chain_lib.Ledger(meta.get("difficulty_bits", 0))
            for b in meta["ledger"]:
                ledger.append(chain_lib.Block(**b))
    return tree, got_step, ledger
