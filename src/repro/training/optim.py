"""Optimizers and LR schedules, implemented in plain JAX (no optax):
SGD (+momentum), Adam/AdamW, and the MiniCPM WSD (warmup-stable-decay)
schedule [arXiv:2404.06395] used by minicpm-2b's train recipe.

Each optimizer is an (init, update) pair over arbitrary pytrees so it can
run per-client under vmap (BLADE-FL local training) or globally (the
centralized baseline the paper compares against).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, opt_state, params, step) -> (new_params, new_opt_state)


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params, step):
        eta = lr_fn(step)
        if momentum == 0.0:
            new = jax.tree.map(lambda w, g: w - eta * g.astype(w.dtype), params, grads)
            return new, state
        state = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype), state, grads)
        new = jax.tree.map(lambda w, m: w - eta * m.astype(w.dtype), params, state)
        return new, state

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        eta = lr_fn(step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        mhat_scale = 1.0 / (1 - b1 ** t)
        vhat_scale = 1.0 / (1 - b2 ** t)

        def step_fn(w, m_, v_):
            upd = (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps)
            if weight_decay:
                upd = upd + weight_decay * w.astype(jnp.float32)
            return (w.astype(jnp.float32) - eta * upd).astype(w.dtype)

        new = jax.tree.map(step_fn, params, m, v)
        return new, {"m": m, "v": v}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def wsd_schedule(peak_lr: float, warmup_steps: int, stable_steps: int,
                 decay_steps: int, floor: float = 0.1):
    """MiniCPM warmup-stable-decay: linear warmup -> constant -> exp decay."""

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / max(warmup_steps, 1), 1.0)
        in_decay = step > (warmup_steps + stable_steps)
        t = jnp.maximum(step - warmup_steps - stable_steps, 0.0)
        decay = peak_lr * jnp.maximum(
            floor, jnp.exp(-t / max(decay_steps, 1) * 2.3026))  # 10x down over decay_steps
        return jnp.where(step < warmup_steps, warm,
                         jnp.where(in_decay, decay, peak_lr))

    return lr


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    floor_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / max(warmup_steps, 1), 1.0)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr


def recipe_for(arch_name: str, peak_lr: float = 3e-4, total_steps: int = 1000):
    """Arch-specific default recipe (minicpm gets WSD per its paper)."""
    if arch_name.startswith("minicpm"):
        return adamw(wsd_schedule(peak_lr, total_steps // 10, int(total_steps * 0.7),
                                  total_steps // 5))
    return adamw(cosine_schedule(peak_lr, total_steps // 10, total_steps))
