"""TrainState for the centralized / non-FL training path (baseline the paper
compares against, and the generic fine-tune driver for the assigned archs)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.training.optim import Optimizer


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


def create(params, optimizer: Optimizer) -> TrainState:
    return TrainState(params=params, opt_state=optimizer.init(params),
                      step=jnp.int32(0))


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    microbatches: int = 1):
    """Standard centralized step: grad of mean loss, optimizer update."""

    def step_fn(state: TrainState, batch) -> Tuple[TrainState, dict]:
        if microbatches > 1:
            def split(b):
                return jax.tree.map(
                    lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                        + x.shape[1:]), b)

            def body(acc, mb):
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(state.params, mb)
                return (acc[0] + l, jax.tree.map(jnp.add, acc[1], g)), None

            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params))
            (loss, grads), _ = jax.lax.scan(body, zero, split(batch))
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch)
        params, opt_state = optimizer.update(grads, state.opt_state,
                                             state.params, state.step)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        out = {"loss": loss, "grad_norm": gnorm, **metrics}
        return TrainState(params, opt_state, state.step + 1), out

    return step_fn
