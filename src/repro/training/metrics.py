"""Lightweight metric logging: in-memory history + CSV/JSONL writers."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional


class MetricLogger:
    def __init__(self, out_dir: Optional[str] = None, name: str = "train"):
        self.history: List[Dict] = []
        self.out_dir = out_dir
        self.name = name
        self._t0 = time.time()
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)

    def log(self, step: int, **metrics) -> Dict:
        rec = {"step": step, "wall": time.time() - self._t0}
        rec.update({k: float(v) for k, v in metrics.items()})
        self.history.append(rec)
        if self.out_dir:
            with open(os.path.join(self.out_dir, f"{self.name}.jsonl"), "a") as f:
                f.write(json.dumps(rec) + "\n")
        return rec

    def series(self, key: str) -> List[float]:
        return [r[key] for r in self.history if key in r]

    def best(self, key: str, mode: str = "min") -> Dict:
        sel = min if mode == "min" else max
        return sel((r for r in self.history if key in r), key=lambda r: r[key])
