from repro.training import checkpoint, metrics, optim, train_state  # noqa: F401
