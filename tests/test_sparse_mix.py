"""The sparse segment-mix path (``aggregation.mix_segment`` + the
``SparseLowering``/``ExplicitSparse`` surface) under the repo's two
equivalence tiers.

What is pinned where (docs/architecture.md §Sparse lowering):

  * **tolerance** — sparse-vs-dense agreement: ``mix_segment`` computes the
    same row-stochastic mix as the dense ``[C, C]`` matmul but associates
    fp32 differently (scatter-add vs row contraction), so they agree to
    ``assert_trees_close`` rtol, never bitwise. Property-tested over random
    graphs including padding rows and degree-1 isolates (hypothesis when
    installed, a seeded grid otherwise — same generators either way).
  * **bitwise** — the claims that ARE exact: ``segment_sum`` equals an
    explicit fp32 accumulation over the edge list in ascending edge order;
    degree-1 rows equal the dense matmul row exactly (one nonzero term, and
    adding the zero products of a dense row changes nothing); eager equals
    jit; and the sharded ``mix_segment`` equals the single-device one
    (per-row reductions are shard-local, nothing reassociates).

Plus the dispatch seam: ``rounds.segment_lowering`` / ``RoundSpec.
sparse_mix`` (auto degree threshold, forced-sparse errors, forced-dense),
and the ``ExplicitSparse`` topology running the real engine.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import aggregation, rounds, topology
from repro.models.mlp import init_mlp, mlp_loss

from equivalence import assert_trees_close

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

needs4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs 4 host devices (CI cohort lane sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")

# rtol of the tolerance tier's sparse-vs-dense claim: both sides sum the
# same <= C fp32 terms per row, just in different orders
RTOL, ATOL = 2e-6, 1e-7


def _rand_sparse(seed: int, c: int, dmax: int,
                 isolate_rows=()) -> topology.SparseLowering:
    """Random row-stochastic sparse lowering with real padding: every row
    draws its own degree in [1, dmax] (rows beyond their degree carry
    weight-0 self-edges), and ``isolate_rows`` are forced to degree-1
    self-loops with weight 1."""
    rng = np.random.default_rng(seed)
    idx = np.empty((c, dmax), np.int32)
    w = np.zeros((c, dmax), np.float32)
    for i in range(c):
        if i in isolate_rows:
            deg = 1
            cols = np.array([i])
        else:
            deg = int(rng.integers(1, dmax + 1))
            cols = np.sort(rng.choice(c, size=deg, replace=False))
        raw = rng.uniform(0.1, 1.0, deg)
        idx[i, :deg] = cols
        idx[i, deg:] = i                       # padding: self-edges
        w[i, :deg] = (raw / raw.sum()).astype(np.float32)
    return topology.SparseLowering(idx, w)


def _rand_params(seed: int, c: int):
    k1, k2 = jax.random.split(jax.random.key(seed))
    return {"w": jax.random.normal(k1, (c, 5, 3)),
            "b": jax.random.normal(k2, (c, 3))}


def _dense_mix(params, w):
    w = jnp.asarray(w, jnp.float32)
    return jax.tree.map(
        lambda x: jnp.tensordot(w, x, axes=([1], [0])).astype(x.dtype),
        params)


# ---------------------------------------------------------------------------
# Property tests: sparse vs dense (tolerance tier)
# ---------------------------------------------------------------------------

_GRID = [(seed, c, dmax)
         for seed in range(6)
         for c, dmax in ((2, 1), (3, 3), (7, 2), (12, 5), (17, 17))]


def _check_matches_dense(seed, c, dmax):
    sp = _rand_sparse(seed, c, dmax, isolate_rows={0, c - 1})
    params = _rand_params(seed, c)
    got = aggregation.mix_segment(params, jnp.asarray(sp.neighbor_idx),
                                  jnp.asarray(sp.edge_w))
    want = _dense_mix(params, sp.to_dense())
    assert_trees_close(got, want, rtol=RTOL, atol=ATOL)
    # degree-1 isolates are BITWISE equal to the dense matmul row: one
    # nonzero term, and the dense row's zero products add nothing
    for leaf_g, leaf_w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(leaf_g[0]),
                                      np.asarray(leaf_w[0]))
        np.testing.assert_array_equal(np.asarray(leaf_g[-1]),
                                      np.asarray(leaf_w[-1]))


@pytest.mark.parametrize("seed,c,dmax", _GRID)
def test_mix_segment_matches_dense_grid(seed, c, dmax):
    _check_matches_dense(seed, c, dmax)


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), c=st.integers(2, 24),
           frac=st.floats(0.05, 1.0))
    def test_mix_segment_matches_dense_hypothesis(seed, c, frac):
        _check_matches_dense(seed, c, max(1, int(frac * c)))


def test_segment_sum_is_ordered_edge_accumulation_bitwise():
    """The bitwise contract the sparse path's determinism rests on: the
    ``segment_sum`` over the flattened edge list equals an explicit fp32
    accumulation over the SAME edges in ascending flattened order. (This is
    why sparse runs are reproducible: re-running the same lowering re-adds
    the same terms in the same order.)"""
    for seed, c, dmax in ((0, 9, 4), (1, 16, 7), (2, 5, 5)):
        sp = _rand_sparse(seed, c, dmax)
        x = np.asarray(jax.random.normal(jax.random.key(seed), (c, 6)),
                       np.float32)
        got = np.asarray(aggregation.mix_segment(
            {"x": jnp.asarray(x)}, jnp.asarray(sp.neighbor_idx),
            jnp.asarray(sp.edge_w))["x"])
        want = np.zeros((c, 6), np.float32)
        for i in range(c):
            for d in range(dmax):           # ascending edge order per row
                want[i] = want[i] + \
                    sp.edge_w[i, d] * x[sp.neighbor_idx[i, d]]
        np.testing.assert_array_equal(got, want)


def test_mix_segment_eager_equals_jit_bitwise():
    sp = _rand_sparse(3, 10, 4)
    params = _rand_params(3, 10)
    idx, w = jnp.asarray(sp.neighbor_idx), jnp.asarray(sp.edge_w)
    eager = aggregation.mix_segment(params, idx, w)
    jitted = jax.jit(aggregation.mix_segment)(params, idx, w)
    for a, b in zip(jax.tree.leaves(eager), jax.tree.leaves(jitted)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mix_segment_padding_rows_are_inert():
    """Weight-0 padding self-edges must contribute exactly nothing: a padded
    lowering and its depadded-then-repadded twin agree bitwise."""
    sp = _rand_sparse(4, 8, 3)
    params = _rand_params(4, 8)
    base = aggregation.mix_segment(params, jnp.asarray(sp.neighbor_idx),
                                   jnp.asarray(sp.edge_w))
    # re-point every zero-weight edge at a DIFFERENT row: 0 * other row
    # must still contribute exactly +0.0
    idx2 = np.where(sp.edge_w == 0.0,
                    (sp.neighbor_idx + 1) % 8, sp.neighbor_idx)
    repad = aggregation.mix_segment(params, jnp.asarray(idx2),
                                    jnp.asarray(sp.edge_w))
    for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(repad)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# SparseLowering / sparse_from_dense surface
# ---------------------------------------------------------------------------


def test_sparse_from_dense_round_trip_exact():
    w = np.asarray(topology.Ring(neighbors=2).matrix(11), np.float32)
    sp = topology.sparse_from_dense(w)
    np.testing.assert_array_equal(sp.to_dense().astype(np.float32), w)
    assert sp.max_degree == 5                 # 4 neighbors + self


def test_sparse_lowering_validation():
    with pytest.raises(ValueError):           # shape mismatch
        topology.SparseLowering(np.zeros((3, 2), np.int32),
                                np.zeros((3, 3), np.float32))
    with pytest.raises(ValueError):           # index out of range
        topology.SparseLowering(np.full((3, 1), 7, np.int32),
                                np.ones((3, 1), np.float32))
    with pytest.raises(ValueError):           # zero degree
        topology.SparseLowering(np.zeros((3, 0), np.int32),
                                np.zeros((3, 0), np.float32))


def test_to_dense_guard_refuses_population_scale():
    c = topology.DENSIFY_MAX_CLIENTS + 1
    sp = topology.SparseLowering(
        np.arange(c, dtype=np.int32)[:, None],
        np.ones((c, 1), np.float32))
    with pytest.raises(ValueError, match="refusing to densify"):
        sp.to_dense()
    # explicit opt-up still works
    assert sp.to_dense(max_clients=c).shape == (c, c)


def test_reweighted_renormalizes_rows():
    sp = _rand_sparse(5, 6, 3)
    weights = np.linspace(1.0, 2.0, 6, dtype=np.float32)
    rw = sp.reweighted(weights)
    np.testing.assert_allclose(np.asarray(rw.edge_w).sum(1),
                               np.ones(6), rtol=1e-6)
    # zero-weight padding stays zero
    assert np.all(np.asarray(rw.edge_w)[sp.edge_w == 0.0] == 0.0)


# ---------------------------------------------------------------------------
# ExplicitSparse topology + dispatch seam
# ---------------------------------------------------------------------------


def test_explicit_sparse_validation():
    with pytest.raises(ValueError):           # empty row
        topology.ExplicitSparse(neighbors=((0,), ()))
    with pytest.raises(ValueError):           # index out of range
        topology.ExplicitSparse(neighbors=((0, 5), (0, 1)))
    with pytest.raises(ValueError):           # weight shape mismatch
        topology.ExplicitSparse(neighbors=((0,), (1,)),
                                weights=((1.0, 1.0), (1.0,)))
    with pytest.raises(ValueError):           # negative weight
        topology.ExplicitSparse(neighbors=((0, 1), (0, 1)),
                                weights=((-1.0, 2.0), (1.0, 1.0)))


def test_explicit_sparse_advertises_segment_kind():
    topo = topology.ExplicitSparse(neighbors=topology.ring_neighbors(8, 1))
    assert topo.lowering(8).kind == topology.SEGMENT
    assert rounds.dispatch_plan(
        rounds.RoundSpec(n_clients=8, tau=1, eta=0.1, topology=topo),
        None, 2)["mix"] == "segment"


def test_ring_neighbors_matches_ring_matrix():
    topo = topology.ExplicitSparse(neighbors=topology.ring_neighbors(9, 2))
    np.testing.assert_allclose(np.asarray(topo.matrix(9)),
                               np.asarray(topology.Ring(neighbors=2).matrix(9)),
                               atol=1e-7)


def test_segment_lowering_auto_threshold():
    """Auto dispatch takes the sparse path only when the degree is well
    below C (max_degree * 8 <= C) — so every shipped small-C config keeps
    its dense bitwise mix."""
    def spec_at(c, n_active):
        return rounds.RoundSpec(
            n_clients=c, tau=1, eta=0.1,
            topology=topology.PartialParticipation(n_active=n_active))
    assert rounds.segment_lowering(spec_at(64, 4)) is not None   # 32 <= 64
    assert rounds.segment_lowering(spec_at(20, 4)) is None       # 32 > 20
    # forced off beats auto
    spec = rounds.RoundSpec(
        n_clients=64, tau=1, eta=0.1, sparse_mix=False,
        topology=topology.PartialParticipation(n_active=4))
    assert rounds.segment_lowering(spec) is None
    # never preempt the opt-in fast tiers
    spec = rounds.RoundSpec(
        n_clients=64, tau=1, eta=0.1, fast_allreduce=True,
        topology=topology.PartialParticipation(n_active=4))
    assert rounds.segment_lowering(spec) is None


def test_segment_lowering_forced_sparse_errors_when_unavailable():
    spec = rounds.RoundSpec(n_clients=8, tau=1, eta=0.1, sparse_mix=True,
                            topology=topology.RandomGraph(p_link=0.5))
    with pytest.raises(ValueError, match="sparse lowering"):
        rounds.segment_lowering(spec)


def test_forced_sparse_full_mesh_matches_dense_engine():
    """sparse_mix=True reroutes ANY static topology through mix_segment —
    full mesh included (degree C, no saving: the point is the seam, not the
    speed). Tolerance tier vs the same spec mixed densely."""
    c, k = 8, 3
    key = jax.random.key(0)
    params = init_mlp(jax.random.fold_in(key, 1), in_dim=12, hidden=6)
    batch = {"x": jax.random.normal(jax.random.fold_in(key, 3), (c, 4, 12)),
             "y": jax.random.randint(jax.random.fold_in(key, 4),
                                     (c, 4), 0, 10)}
    outs = {}
    for sparse in (True, False):
        spec = rounds.RoundSpec(n_clients=c, tau=2, eta=0.1,
                                mine_attempts=16, difficulty_bits=1,
                                sparse_mix=sparse,
                                topology=topology.FullMesh())
        outs[sparse] = rounds.run_blade_fl(
            mlp_loss, spec, params, batch, jax.random.fold_in(key, 2), k)
    st_s, hist_s, led_s = outs[True]
    st_d, hist_d, led_d = outs[False]
    assert_trees_close(st_s.params, st_d.params, rtol=1e-5, atol=1e-6)
    # digests are computed pre-mix from the broadcast set: round 1 agrees
    # BITWISE, later rounds may fork deterministically (mixed params feed
    # round 2's training)
    assert led_s.blocks[0].model_digest == led_d.blocks[0].model_digest
    assert led_s.validate_chain() and led_d.validate_chain()


def test_explicit_sparse_scan_vs_loop_bitwise():
    """The sparse mix inside the engine obeys the same scan==loop bitwise
    contract as every other lowering."""
    c, k = 6, 3
    key = jax.random.key(1)
    topo = topology.ExplicitSparse(neighbors=topology.ring_neighbors(c, 1))
    spec = rounds.RoundSpec(n_clients=c, tau=2, eta=0.1, mine_attempts=16,
                            difficulty_bits=1, topology=topo)
    params = init_mlp(jax.random.fold_in(key, 1), in_dim=12, hidden=6)
    batch = {"x": jax.random.normal(jax.random.fold_in(key, 3), (c, 40, 12)),
             "y": jax.random.randint(jax.random.fold_in(key, 4),
                                     (c, 40), 0, 10)}
    st_a, hist_a, led_a = rounds.run_blade_fl(
        mlp_loss, spec, params, batch, jax.random.fold_in(key, 2), k)
    st_b, hist_b, led_b = rounds.run_blade_fl(
        mlp_loss, spec, params, lambda i: batch,  # callable -> loop driver
        jax.random.fold_in(key, 2), k)
    for a, b in zip(jax.tree.leaves(st_a.params),
                    jax.tree.leaves(st_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [b_.header_hash for b_ in led_a.blocks] == \
           [b_.header_hash for b_ in led_b.blocks]


# ---------------------------------------------------------------------------
# Sharded mix_segment (bitwise vs single device)
# ---------------------------------------------------------------------------


@needs4
def test_mix_segment_sharded_bitwise():
    """Per-row segment reductions are shard-local (each shard owns its row
    block and gathers the full broadcast set), so the sharded mix is
    bit-for-bit the single-device one — the BITWISE tier, unlike psum."""
    c = 8
    sp = _rand_sparse(7, c, 3)
    params = _rand_params(7, c)
    idx, w = jnp.asarray(sp.neighbor_idx), jnp.asarray(sp.edge_w)
    want = aggregation.mix_segment(params, idx, w)
    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    fn = shard_map(
        lambda p: aggregation.mix_segment(p, idx, w, axis_name="data",
                                          n_shards=4),
        mesh=mesh, in_specs=(P("data"),), out_specs=P("data"),
        check_rep=False)
    got = fn(params)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_sparse_suite_on_4_devices_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-k", "sharded",
         os.path.abspath(__file__)],
        capture_output=True, text=True, env=env, timeout=900,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-2000:]
