"""Decentralized aggregation (Steps 2+5) — pure-jnp path and Pallas kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation
from repro.kernels.fedavg import fedavg_tree


def _params(key, c=6):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (c, 8, 5)),
            "b": jax.random.normal(k2, (c, 5))}


def test_fedavg_is_mean_broadcast():
    p = _params(jax.random.key(0))
    out = aggregation.fedavg(p)
    want = jnp.mean(p["w1"], axis=0)
    for i in range(p["w1"].shape[0]):
        assert jnp.allclose(out["w1"][i], want, atol=1e-6)


def test_fedavg_weighted():
    p = _params(jax.random.key(1), c=3)
    w = jnp.array([1.0, 2.0, 3.0])
    out = aggregation.fedavg(p, weights=w)
    want = (p["b"][0] + 2 * p["b"][1] + 3 * p["b"][2]) / 6.0
    assert jnp.allclose(out["b"][0], want, atol=1e-5)


def test_aggregate_once_shape():
    p = _params(jax.random.key(2))
    single = aggregation.aggregate_once(p)
    assert single["w1"].shape == (8, 5)


def test_replicate_then_divergence_zero():
    single = {"w": jnp.ones((4, 4))}
    rep = aggregation.replicate(single, 5)
    assert rep["w"].shape == (5, 4, 4)
    assert float(aggregation.client_divergence(rep)) < 1e-6


def test_divergence_positive_when_spread():
    p = _params(jax.random.key(3))
    assert float(aggregation.client_divergence(p)) > 0.01


def test_kernel_matches_jnp_path():
    p = _params(jax.random.key(4))
    ref = aggregation.fedavg(p)
    out = fedavg_tree(p, use_kernel=True)
    for k in p:
        assert jnp.allclose(out[k], ref[k], atol=1e-5), k


def test_fedavg_idempotent():
    p = _params(jax.random.key(5))
    once = aggregation.fedavg(p)
    twice = aggregation.fedavg(once)
    for k in p:
        assert jnp.allclose(once[k], twice[k], atol=1e-6)
