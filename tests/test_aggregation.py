"""Decentralized aggregation (Steps 2+5) — pure-jnp path and Pallas kernel."""
import jax
import jax.numpy as jnp

from repro.core import aggregation
from repro.kernels.fedavg import fedavg_tree


def _params(key, c=6):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (c, 8, 5)),
            "b": jax.random.normal(k2, (c, 5))}


def test_fedavg_is_mean_broadcast():
    p = _params(jax.random.key(0))
    out = aggregation.fedavg(p)
    want = jnp.mean(p["w1"], axis=0)
    for i in range(p["w1"].shape[0]):
        assert jnp.allclose(out["w1"][i], want, atol=1e-6)


def test_fedavg_weighted():
    p = _params(jax.random.key(1), c=3)
    w = jnp.array([1.0, 2.0, 3.0])
    out = aggregation.fedavg(p, weights=w)
    want = (p["b"][0] + 2 * p["b"][1] + 3 * p["b"][2]) / 6.0
    assert jnp.allclose(out["b"][0], want, atol=1e-5)


def test_aggregate_once_shape():
    p = _params(jax.random.key(2))
    single = aggregation.aggregate_once(p)
    assert single["w1"].shape == (8, 5)


def test_replicate_then_divergence_zero():
    single = {"w": jnp.ones((4, 4))}
    rep = aggregation.replicate(single, 5)
    assert rep["w"].shape == (5, 4, 4)
    assert float(aggregation.client_divergence(rep)) < 1e-6


def test_divergence_positive_when_spread():
    p = _params(jax.random.key(3))
    assert float(aggregation.client_divergence(p)) > 0.01


def test_kernel_matches_jnp_path():
    p = _params(jax.random.key(4))
    ref = aggregation.fedavg(p)
    out = fedavg_tree(p, use_kernel=True)
    for k in p:
        assert jnp.allclose(out[k], ref[k], atol=1e-5), k


def test_fedavg_idempotent():
    p = _params(jax.random.key(5))
    once = aggregation.fedavg(p)
    twice = aggregation.fedavg(once)
    for k in p:
        assert jnp.allclose(once[k], twice[k], atol=1e-6)


# ---------------------------------------------------------------------------
# Weighted aggregation: fedavg / aggregate_once / mix
# ---------------------------------------------------------------------------


def test_aggregate_once_weighted_matches_manual():
    p = _params(jax.random.key(6), c=3)
    w = jnp.array([1.0, 3.0, 4.0])  # |D_i| data sizes
    out = aggregation.aggregate_once(p, weights=w)
    want = (p["w1"][0] + 3 * p["w1"][1] + 4 * p["w1"][2]) / 8.0
    assert out["w1"].shape == (8, 5)
    assert jnp.allclose(out["w1"], want, atol=1e-5)


def test_weighted_normalization_scale_invariant():
    """|D_i| weights are ratios — scaling all weights changes nothing, in
    fedavg, aggregate_once, and mix."""
    p = _params(jax.random.key(7), c=4)
    w = jnp.array([1.0, 2.0, 3.0, 4.0])
    full = jnp.full((4, 4), 0.25)
    for fn in (lambda w_: aggregation.fedavg(p, weights=w_),
               lambda w_: aggregation.aggregate_once(p, weights=w_),
               lambda w_: aggregation.mix(p, full, weights=w_)):
        a, b = fn(w), fn(100.0 * w)
        for k in p:
            assert jnp.allclose(a[k], b[k], atol=1e-5), k


def test_mix_full_mesh_weighted_equals_weighted_fedavg():
    p = _params(jax.random.key(8), c=5)
    w = jnp.array([5.0, 1.0, 2.0, 2.0, 10.0])
    full = jnp.full((5, 5), 0.2)
    got = aggregation.mix(p, full, weights=w)
    want = aggregation.fedavg(p, weights=w)
    for k in p:
        assert jnp.allclose(got[k], want[k], atol=1e-5), k


def test_mix_uniform_weights_equals_unweighted():
    p = _params(jax.random.key(9), c=4)
    w_mat = jnp.array([[0.5, 0.5, 0.0, 0.0],
                       [0.0, 0.5, 0.5, 0.0],
                       [0.0, 0.0, 0.5, 0.5],
                       [0.5, 0.0, 0.0, 0.5]])
    a = aggregation.mix(p, w_mat)
    b = aggregation.mix(p, w_mat, weights=jnp.ones(4))
    for k in p:
        assert jnp.allclose(a[k], b[k], atol=1e-6), k


def test_weighted_dtype_round_trip():
    """float32 accumulation, but every leaf comes back in its own dtype."""
    key = jax.random.key(10)
    p = {"h": jax.random.normal(key, (4, 3, 3), jnp.float32).astype(jnp.bfloat16),
         "f": jax.random.normal(key, (4, 6), jnp.float32)}
    w = jnp.array([1.0, 2.0, 3.0, 4.0])
    full = jnp.full((4, 4), 0.25)
    for out in (aggregation.fedavg(p, weights=w),
                aggregation.aggregate_once(p, weights=w),
                aggregation.mix(p, full, weights=w)):
        assert out["h"].dtype == jnp.bfloat16
        assert out["f"].dtype == jnp.float32
    # bf16 mean of identical values is exact — round trip loses nothing
    same = {"h": jnp.ones((4, 3), jnp.bfloat16) * jnp.bfloat16(1.5)}
    got = aggregation.mix(same, full, weights=w)
    assert jnp.all(got["h"] == jnp.bfloat16(1.5))
