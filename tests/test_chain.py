"""Ledger / block validation (§2.2, Steps 2-4)."""
import pytest

from repro.core import chain


def build_ledger(n=5, difficulty_bits=0):
    led = chain.Ledger(difficulty_bits)
    for i in range(n):
        led.append(chain.make_block(
            index=i, prev_hash=led.head_hash, model_digest=1000 + i,
            winner=i % 3, nonce=42 + i, pow_hash=7 + i))
    return led


def test_chain_validates():
    led = build_ledger()
    assert led.validate_chain()
    assert len(led.blocks) == 5


def test_tampered_digest_detected():
    led = build_ledger()
    bad = led.tampered_copy(2, model_digest=9999)
    assert not bad.validate_chain()


def test_tampered_winner_detected():
    led = build_ledger()
    bad = led.tampered_copy(1, winner=99)
    assert not bad.validate_chain()


def test_reorder_detected():
    led = build_ledger()
    bad = chain.Ledger()
    bad.blocks = [led.blocks[0], led.blocks[2], led.blocks[1], *led.blocks[3:]]
    assert not bad.validate_chain()


def test_difficulty_enforced():
    led = chain.Ledger(difficulty_bits=16)
    ok = chain.make_block(0, led.head_hash, 1, 0, 5, pow_hash=0x0000FFFF)
    led.append(ok)
    bad = chain.make_block(1, led.head_hash, 1, 0, 5, pow_hash=0xFFFF0000)
    with pytest.raises(ValueError):
        led.append(bad)


def test_wrong_prev_hash_rejected():
    led = build_ledger(2)
    with pytest.raises(ValueError):
        led.append(chain.make_block(2, prev_hash=123456, model_digest=1,
                                    winner=0, nonce=0, pow_hash=0))


def stacked_fields(n=5):
    """Honest stacked scan outputs (what run_blade_fl_scan hands to
    ledger_from_scan): low pow hashes so a difficulty target can be
    enforced."""
    digests = [1000 + i for i in range(n)]
    winners = [i % 3 for i in range(n)]
    nonces = [42 + i for i in range(n)]
    pow_hashes = [7 + i for i in range(n)]
    return digests, winners, nonces, pow_hashes


def test_ledger_from_scan_happy_path_validates():
    led = chain.ledger_from_scan(*stacked_fields(),
                                 ledger=chain.Ledger(difficulty_bits=16))
    assert led.validate_chain() and len(led.blocks) == 5


def test_ledger_from_scan_rejects_flipped_pow_bit():
    """A single flipped bit in a stacked header field must not replay into a
    valid chain: flipping a high bit of one pow_hash pushes it past the
    difficulty target and Ledger.append (which re-validates every block)
    raises — the scan path keeps the same tamper resistance as the
    per-round driver."""
    digests, winners, nonces, pow_hashes = stacked_fields()
    pow_hashes[2] ^= 1 << 31                       # one bit, now > target
    with pytest.raises(ValueError, match="invalid block"):
        chain.ledger_from_scan(digests, winners, nonces, pow_hashes,
                               ledger=chain.Ledger(difficulty_bits=16))


def test_ledger_from_scan_flipped_digest_bit_forks_every_downstream_link():
    """ledger_from_scan re-derives prev_hash links, so a flipped digest bit
    cannot silently coexist with the honest chain: the tampered replay
    produces a different header hash at the flipped block and at EVERY
    block after it, and grafting the tampered block into the honest chain
    fails validate_chain."""
    digests, winners, nonces, pow_hashes = stacked_fields()
    honest = chain.ledger_from_scan(digests, winners, nonces, pow_hashes)
    digests[1] ^= 1                                # single flipped bit
    tampered = chain.ledger_from_scan(digests, winners, nonces, pow_hashes)
    assert honest.blocks[0].header_hash == tampered.blocks[0].header_hash
    for h, t in zip(honest.blocks[1:], tampered.blocks[1:]):
        assert h.header_hash != t.header_hash
    grafted = honest.tampered_copy(1, model_digest=digests[1])
    assert not grafted.validate_chain()


def test_header_hash_deterministic():
    b1 = chain.make_block(0, 1, 2, 3, 4, 5)
    b2 = chain.make_block(0, 1, 2, 3, 4, 5)
    assert b1.header_hash == b2.header_hash
    b3 = chain.make_block(0, 1, 2, 3, 4, 6)
    assert b1.header_hash != b3.header_hash
