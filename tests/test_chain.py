"""Ledger / block validation (§2.2, Steps 2-4)."""
import pytest

from repro.core import chain


def build_ledger(n=5, difficulty_bits=0):
    led = chain.Ledger(difficulty_bits)
    for i in range(n):
        led.append(chain.make_block(
            index=i, prev_hash=led.head_hash, model_digest=1000 + i,
            winner=i % 3, nonce=42 + i, pow_hash=7 + i))
    return led


def test_chain_validates():
    led = build_ledger()
    assert led.validate_chain()
    assert len(led.blocks) == 5


def test_tampered_digest_detected():
    led = build_ledger()
    bad = led.tampered_copy(2, model_digest=9999)
    assert not bad.validate_chain()


def test_tampered_winner_detected():
    led = build_ledger()
    bad = led.tampered_copy(1, winner=99)
    assert not bad.validate_chain()


def test_reorder_detected():
    led = build_ledger()
    bad = chain.Ledger()
    bad.blocks = [led.blocks[0], led.blocks[2], led.blocks[1], *led.blocks[3:]]
    assert not bad.validate_chain()


def test_difficulty_enforced():
    led = chain.Ledger(difficulty_bits=16)
    ok = chain.make_block(0, led.head_hash, 1, 0, 5, pow_hash=0x0000FFFF)
    led.append(ok)
    bad = chain.make_block(1, led.head_hash, 1, 0, 5, pow_hash=0xFFFF0000)
    with pytest.raises(ValueError):
        led.append(bad)


def test_wrong_prev_hash_rejected():
    led = build_ledger(2)
    with pytest.raises(ValueError):
        led.append(chain.make_block(2, prev_hash=123456, model_digest=1,
                                    winner=0, nonce=0, pow_hash=0))


def test_header_hash_deterministic():
    b1 = chain.make_block(0, 1, 2, 3, 4, 5)
    b2 = chain.make_block(0, 1, 2, 3, 4, 5)
    assert b1.header_hash == b2.header_hash
    b3 = chain.make_block(0, 1, 2, 3, 4, 6)
    assert b1.header_hash != b3.header_hash
