"""Byzantine attack stages (core/attacks.py + rounds.make_attack).

Unit side: every shipped attack is a pure keyed transform on the gathered
``[C, ...]`` broadcast tree — honest rows pass through BITWISE untouched,
attacked rows follow the published formula (checked against independent
numpy math), the one stochastic attack draws deterministically from its
key, and ``n_attackers == 0`` degenerates to the exact identity.

Engine side (the test-matrix centerpiece, with tests/test_robust_mix.py):
under the linear mix every attack stays inside the bitwise contract — the
compiled ``lax.scan`` driver, the per-round Python loop, and the
mesh-lowered scan agree bit-for-bit on params, metric history, and ledger
hash links. The attack key folds from ``k_dp`` with its own salt, so an
inactive attack reproduces the attack-free baseline exactly.
"""
import itertools
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attacks, rounds, topology
from repro.data.pipeline import FLDataSource
from repro.models.mlp import init_mlp, mlp_loss

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

C = 8

# The shipped attack zoo at representative strengths — the row axis of the
# attack x aggregator grid (tests/test_robust_mix.py reuses it).
ATTACKS = [
    attacks.SignFlip(n_attackers=2, scale=2.0),
    attacks.ScaledNoise(n_attackers=2, sigma2=0.5),
    attacks.ALIE(n_attackers=2, z=1.2),
    attacks.ModelReplacement(n_attackers=1),
]


def _ids(atk):
    return type(atk).__name__


def _full(key, c=C, p=33):
    """A trained-like [C, ...] broadcast tree (two ranks, fp32)."""
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (c, 6, p), jnp.float32),
            "b": jax.random.normal(k2, (c, p), jnp.float32)}


# ---------------------------------------------------------------------------
# Attack transforms (unit)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("atk", ATTACKS, ids=_ids)
def test_honest_rows_bitwise_untouched(atk):
    full = _full(jax.random.key(0))
    out = atk.apply(full, jax.random.key(1), C)
    m = atk.n_attackers
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(full)):
        np.testing.assert_array_equal(np.asarray(a)[m:], np.asarray(b)[m:])
        assert not np.array_equal(np.asarray(a)[:m], np.asarray(b)[:m])


@pytest.mark.parametrize("cls", [attacks.SignFlip, attacks.ScaledNoise,
                                 attacks.ALIE, attacks.ModelReplacement],
                         ids=lambda c: c.__name__)
def test_zero_attackers_is_identity(cls):
    atk = cls(n_attackers=0)
    assert not atk.active
    full = _full(jax.random.key(2))
    out = atk.apply(full, jax.random.key(3), C)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(full)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sign_flip_formula():
    full = _full(jax.random.key(4))
    out = attacks.SignFlip(n_attackers=3, scale=2.5).apply(
        full, jax.random.key(0), C)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(full)):
        np.testing.assert_array_equal(np.asarray(a)[:3],
                                      -2.5 * np.asarray(b)[:3])


def test_scaled_noise_keyed_and_calibrated():
    full = {"w": jnp.zeros((4, 50_000), jnp.float32)}
    atk = attacks.ScaledNoise(n_attackers=2, sigma2=0.25)
    out = atk.apply(full, jax.random.key(5), 4)
    again = atk.apply(full, jax.random.key(5), 4)
    other = atk.apply(full, jax.random.key(6), 4)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(again["w"]))   # keyed: replays
    assert not np.array_equal(np.asarray(out["w"][:2]),
                              np.asarray(other["w"][:2]))   # fresh key draws
    assert abs(np.asarray(out["w"][0]).var() - 0.25) < 0.02
    np.testing.assert_array_equal(np.asarray(out["w"][2:]), 0)


def test_alie_matches_honest_statistics():
    full = _full(jax.random.key(7))
    m, z = 3, 1.2
    out = attacks.ALIE(n_attackers=m, z=z).apply(full, jax.random.key(0), C)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(full)):
        honest = np.asarray(b)[m:]
        want = honest.mean(axis=0) - z * honest.std(axis=0)
        got = np.asarray(a)[:m]
        for i in range(m):   # every attacker broadcasts the SAME point
            np.testing.assert_allclose(got[i], want, rtol=2e-6, atol=1e-7)


def test_alie_omniscient_of_honest_rows_only():
    """The ALIE point is a function of the honest rows alone — garbling the
    attacker rows before apply() changes nothing (the omniscient adversary
    discards its own pre-attack models)."""
    full = _full(jax.random.key(8))
    atk = attacks.ALIE(n_attackers=2, z=1.5)
    garbled = jax.tree.map(
        lambda l: l.at[:2].set(jnp.float32(1e6)), full)
    out = atk.apply(full, jax.random.key(0), C)
    out_g = atk.apply(garbled, jax.random.key(0), C)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(out_g)):
        np.testing.assert_array_equal(np.asarray(a)[:2], np.asarray(b)[:2])


def test_model_replacement_hijacks_the_mean():
    """With boost = C (the default), one attacker's deviation boosting pulls
    the linear mean (1-1/C) of the way onto the attacker's ORIGINAL model:
    mean_after = mu + ((C-1)/C)(w_0 - mu) — the backdoor-insertion
    scaling. Exact identity, plus the hijack direction (C-1x closer to the
    attacker than the honest mean was)."""
    full = _full(jax.random.key(9))
    out = attacks.ModelReplacement(n_attackers=1).apply(
        full, jax.random.key(0), C)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(full)):
        w0 = np.asarray(b)[0]
        mu = np.asarray(b).mean(axis=0)
        hijacked_mean = np.asarray(a).mean(axis=0)
        np.testing.assert_allclose(hijacked_mean,
                                   mu + (C - 1) / C * (w0 - mu),
                                   rtol=1e-4, atol=1e-5)
        gap_before = np.linalg.norm(mu - w0)
        gap_after = np.linalg.norm(hijacked_mean - w0)
        assert gap_after < 1.5 * gap_before / C


def test_model_replacement_explicit_boost_formula():
    full = _full(jax.random.key(10))
    out = attacks.ModelReplacement(n_attackers=2, boost=3.0).apply(
        full, jax.random.key(0), C)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(full)):
        mu = np.asarray(b).mean(axis=0)
        want = mu + 3.0 * (np.asarray(b)[:2] - mu)
        np.testing.assert_allclose(np.asarray(a)[:2], want,
                                   rtol=1e-5, atol=1e-6)


def test_validate_rejects_degenerate_attacker_counts():
    full = _full(jax.random.key(11))
    with pytest.raises(ValueError):
        attacks.SignFlip(n_attackers=C).apply(full, jax.random.key(0), C)
    with pytest.raises(ValueError):
        attacks.ALIE(n_attackers=-1).apply(full, jax.random.key(0), C)
    # and at stage-build time, before any tracing
    spec = rounds.RoundSpec(n_clients=4, tau=1, eta=0.1,
                            attack=attacks.SignFlip(n_attackers=4))
    with pytest.raises(ValueError):
        rounds.make_attack(spec)


def test_from_name_round_trips_the_cli_grammar():
    assert attacks.from_name("signflip:2", 3) == \
        attacks.SignFlip(n_attackers=3, scale=2.0)
    assert attacks.from_name("noise:0.5:2") == \
        attacks.ScaledNoise(n_attackers=1, sigma2=0.5, scale=2.0)
    assert attacks.from_name("alie:1.2", 2) == \
        attacks.ALIE(n_attackers=2, z=1.2)
    assert attacks.from_name("replace:8") == \
        attacks.ModelReplacement(n_attackers=1, boost=8.0)
    with pytest.raises(ValueError):
        attacks.from_name("gradient_ascent")


def test_attack_is_hashable_spec_payload():
    """Attacks ride the hashable RoundSpec (compiled-runner cache key)."""
    a = attacks.ALIE(n_attackers=2, z=1.5)
    assert hash(a) == hash(attacks.ALIE(n_attackers=2, z=1.5))
    s1 = rounds.RoundSpec(n_clients=4, tau=1, eta=0.1, attack=a)
    s2 = rounds.RoundSpec(n_clients=4, tau=1, eta=0.1, attack=a)
    assert s1 == s2 and hash(s1) == hash(s2)


# ---------------------------------------------------------------------------
# The attack stage inside the round
# ---------------------------------------------------------------------------


def _run_pair(atk, k_rounds=3, seed=31, **spec_kw):
    key = jax.random.key(seed)
    src = FLDataSource(key, C, samples_per_client=32, seed=seed)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(n_clients=C, tau=2, eta=0.1, n_lazy=1,
                            sigma2=0.05, mine_attempts=64, difficulty_bits=2,
                            topology=topology.Ring(neighbors=2),
                            attack=atk, **spec_kw)
    run_key = jax.random.fold_in(key, 2)
    loop = rounds.run_blade_fl(
        mlp_loss, spec, params, src.round_batch, run_key, k_rounds)
    scan = rounds.run_blade_fl_scan(
        mlp_loss, spec, params, src.static_batch(), run_key, k_rounds)
    return loop, scan


@pytest.mark.parametrize("atk", ATTACKS, ids=_ids)
def test_scan_matches_loop_under_every_attack(atk):
    """Linear mix + attack stays in the bitwise tier: scan and loop agree
    exactly on params, history, and ledger hash links (the attack composes
    with the lazy + DP stages already in the spec)."""
    (st_py, hist_py, led_py), (st_sc, hist_sc, led_sc) = _run_pair(atk)
    for a, b in zip(jax.tree.leaves(st_py.params),
                    jax.tree.leaves(st_sc.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert hist_py == hist_sc
    assert led_sc.validate_chain()
    assert [b.header_hash for b in led_py.blocks] == \
        [b.header_hash for b in led_sc.blocks]


def test_inactive_attack_is_the_exact_baseline():
    """attack=None and a zero-attacker attack produce bit-identical runs —
    the attack key folds from k_dp with its own salt, so merely *enabling*
    the stage never perturbs the lazy/DP/topology streams."""
    (_, hist_none, led_none), _ = _run_pair(None)
    (_, hist_zero, led_zero), _ = _run_pair(
        attacks.SignFlip(n_attackers=0))
    assert hist_none == hist_zero
    assert [b.header_hash for b in led_none.blocks] == \
        [b.header_hash for b in led_zero.blocks]


def test_attack_stream_is_deterministic_and_keyed():
    """Same run key replays the stochastic attack bitwise; a different run
    key draws different noise (the history forks)."""
    (_, h1, l1), _ = _run_pair(attacks.ScaledNoise(n_attackers=2), seed=41)
    (_, h2, l2), _ = _run_pair(attacks.ScaledNoise(n_attackers=2), seed=41)
    (_, h3, _), _ = _run_pair(attacks.ScaledNoise(n_attackers=2), seed=42)
    assert h1 == h2
    assert [b.header_hash for b in l1.blocks] == \
        [b.header_hash for b in l2.blocks]
    assert h1 != h3


def test_attack_actually_moves_the_aggregate():
    """Sanity that the stage is live: a strong sign-flip visibly degrades
    the linear-mean aggregate vs the attack-free run."""
    (st_clean, hist_clean, _), _ = _run_pair(None, k_rounds=4)
    (st_atk, hist_atk, _), _ = _run_pair(
        attacks.SignFlip(n_attackers=3, scale=4.0), k_rounds=4)
    assert hist_atk != hist_clean
    assert hist_atk[-1]["global_loss"] > hist_clean[-1]["global_loss"]


def test_sharded_scan_bitwise_under_attack_single_device():
    """The mesh code path (shard_map gather + local-rows slice) on however
    many devices this host has — bitwise with the unsharded scan."""
    from jax.sharding import Mesh
    atk = attacks.ALIE(n_attackers=2, z=1.2)
    key = jax.random.key(17)
    src = FLDataSource(key, C, samples_per_client=16, seed=17)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(n_clients=C, tau=1, eta=0.1, mine_attempts=16,
                            difficulty_bits=1,
                            topology=topology.Ring(neighbors=1), attack=atk)
    run_key = jax.random.fold_in(key, 2)
    batch = src.static_batch()
    st, hist, led = rounds.run_blade_fl_scan(
        mlp_loss, spec, params, batch, run_key, 3)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    st_m, hist_m, led_m = rounds.run_blade_fl_scan(
        mlp_loss, spec, params, batch, run_key, 3, mesh=mesh)
    for a, b in zip(jax.tree.leaves(st.params), jax.tree.leaves(st_m.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert hist == hist_m
    assert [b.header_hash for b in led.blocks] == \
        [b.header_hash for b in led_m.blocks]


@pytest.mark.slow
def test_sharded_attack_grid_bitwise_subprocess():
    """4 fake host devices, every attack under the linear ring mix: the
    mesh-lowered scan (all-gather + identical full-[C,...] transform +
    local-rows slice) equals the single-device scan bit-for-bit, histories
    and ledger hashes included."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import json
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.core import attacks, rounds, topology
        from repro.data.pipeline import FLDataSource
        from repro.models.mlp import init_mlp, mlp_loss

        C = 8
        ATTACKS = [
            attacks.SignFlip(n_attackers=2, scale=2.0),
            attacks.ScaledNoise(n_attackers=2, sigma2=0.5),
            attacks.ALIE(n_attackers=2, z=1.2),
            attacks.ModelReplacement(n_attackers=1),
        ]
        key = jax.random.key(29)
        src = FLDataSource(key, C, samples_per_client=16, seed=29)
        params = init_mlp(jax.random.fold_in(key, 1))
        batch = src.static_batch()
        run_key = jax.random.fold_in(key, 2)
        mesh = Mesh(np.array(jax.devices()), ("data",))

        out = {}
        for atk in ATTACKS:
            spec = rounds.RoundSpec(
                n_clients=C, tau=1, eta=0.1, n_lazy=1, sigma2=0.05,
                mine_attempts=16, difficulty_bits=1,
                topology=topology.Ring(neighbors=1), attack=atk)
            st, hist, led = rounds.run_blade_fl_scan(
                mlp_loss, spec, params, batch, run_key, 3)
            st_m, hist_m, led_m = rounds.run_blade_fl_scan(
                mlp_loss, spec, params, batch, run_key, 3, mesh=mesh)
            bitwise = all(
                bool((np.asarray(a) == np.asarray(b)).all())
                for a, b in zip(jax.tree.leaves(st.params),
                                jax.tree.leaves(st_m.params)))
            out[type(atk).__name__] = (
                bitwise and hist == hist_m and led_m.validate_chain()
                and [b.header_hash for b in led.blocks]
                == [b.header_hash for b in led_m.blocks])
        print(json.dumps(out))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert len(res) == 4 and all(res.values()), res
