"""Tolerance-tier equivalence helpers (docs/architecture.md §The tolerance
tier).

The repo has two equivalence tiers:

  * **bitwise** — the default contract: sharded-vs-single-device runs agree
    EXACTLY (``np.testing.assert_array_equal``; tests/test_multidevice_scan.py
    pins it). Anything that might reassociate fp32 is forbidden on those
    paths.
  * **tolerance** — the opt-in tier for reassociating fast paths
    (``RoundSpec.fast_allreduce``: psum mixes, psum'd diagnostics). Results
    agree to float tolerance, not bit-for-bit, and ledger hashes are
    EXPECTED to fork. Suites under this tier carry the ``tolerance`` pytest
    marker (registered in pyproject.toml) and run in the CI multidevice lane
    (``XLA_FLAGS=--xla_force_host_platform_device_count=4``).

This module holds the composable assertion helpers every tolerance-tier
suite shares: relative/absolute bounds (``assert_trees_close(rtol, atol)``)
and an ULP bound (``ulp=``) for when "a few reassociated last bits" is the
claim — ``ulp=0`` degenerates to the bitwise tier, which keeps one helper
usable across both.

Not a test module itself (no ``test_`` prefix); import it from tests:

    from equivalence import assert_trees_close, ulp_diff
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

_INT_OF_FLOAT = {2: np.int16, 4: np.int32, 8: np.int64}


def _ordered_ints(x: np.ndarray) -> np.ndarray:
    """Map float bit patterns to integers whose ordering matches the floats'
    (the standard two's-complement trick: negative floats, whose sign-bit
    patterns sort backwards, are reflected below zero; ±0.0 both map to 0).
    Adjacent representable floats map to adjacent integers, so integer
    distance IS distance in units-in-the-last-place."""
    int_t = _INT_OF_FLOAT[x.dtype.itemsize]
    bits = x.view(int_t)
    min_int = np.iinfo(int_t).min
    return np.where(bits < 0, min_int - bits, bits).astype(np.int64)


def ulp_diff(got, want) -> np.ndarray:
    """Element-wise distance in units-in-the-last-place between two same-dtype
    float arrays. 0 = bitwise equal (also for ±0.0 pairs); 1 = adjacent
    representable floats. NaNs compare equal to NaNs of the same bit pattern
    only — a NaN against a finite value is a huge ULP distance, which is what
    an equivalence assertion wants.

    float64 ordered ints span the full int64 range, so an opposite-sign pair
    can overflow the int64 subtraction; such pairs saturate to int64 max
    instead of wrapping (a wrapped distance could read as "close" for two
    maximally distant values, silently passing the assertion)."""
    got, want = np.asarray(got), np.asarray(want)
    if got.dtype != want.dtype:
        raise TypeError(f"dtype mismatch: {got.dtype} vs {want.dtype}")
    if not np.issubdtype(got.dtype, np.floating):
        raise TypeError(f"ulp_diff needs float arrays, got {got.dtype}")
    ka, kb = _ordered_ints(got), _ordered_ints(want)
    with np.errstate(over="ignore"):
        d = ka - kb
    # wrap is only possible when the signs differ and flips the result's
    # sign away from ka's; |int64 min| also wraps under abs
    overflow = ((ka >= 0) != (kb >= 0)) & ((d >= 0) != (ka >= 0))
    with np.errstate(over="ignore"):
        d = np.abs(d)
    overflow |= d < 0
    return np.where(overflow, np.iinfo(np.int64).max, d)


def assert_leaves_close(got, want, *, rtol: float = 1e-5, atol: float = 0.0,
                        ulp: Optional[int] = None, err_msg: str = ""):
    """One-leaf assertion: ULP tier when ``ulp`` is given (float dtypes),
    rtol/atol tier otherwise. NaNs must match NaNs in both tiers (the
    engine's strided eval emits NaN rows by design)."""
    got, want = np.asarray(got), np.asarray(want)
    assert got.shape == want.shape, \
        f"{err_msg}: shape {got.shape} != {want.shape}"
    if ulp is not None and np.issubdtype(want.dtype, np.floating):
        d = ulp_diff(got, want)
        worst = int(d.max()) if d.size else 0
        assert worst <= ulp, (
            f"{err_msg}: max ULP distance {worst} > allowed {ulp} "
            f"({int((d > ulp).sum())}/{d.size} elements over)")
    else:
        np.testing.assert_allclose(got, want, rtol=rtol, atol=atol,
                                   equal_nan=True, err_msg=err_msg)


def assert_trees_close(got, want, *, rtol: float = 1e-5, atol: float = 0.0,
                       ulp: Optional[int] = None):
    """Pytree-wide tolerance assertion: identical treedefs, then every leaf
    pair through :func:`assert_leaves_close`. ``rtol``/``atol`` follow
    ``np.testing.assert_allclose`` semantics; ``ulp`` switches float leaves
    to the units-in-the-last-place tier (``ulp=0`` = bitwise)."""
    got_paths = jax.tree_util.tree_flatten_with_path(got)
    want_paths = jax.tree_util.tree_flatten_with_path(want)
    assert got_paths[1] == want_paths[1], (
        f"tree structure mismatch: {got_paths[1]} vs {want_paths[1]}")
    for (path, g), (_, w) in zip(got_paths[0], want_paths[0]):
        assert_leaves_close(g, w, rtol=rtol, atol=atol, ulp=ulp,
                            err_msg=jax.tree_util.keystr(path))


def tree_max_ulp(got, want) -> int:
    """Largest per-leaf ULP distance across two float pytrees — the
    diagnostic companion to ``assert_trees_close(ulp=...)`` for picking a
    bound or reporting drift."""
    leaves_g = jax.tree.leaves(got)
    leaves_w = jax.tree.leaves(want)
    worst = 0
    for g, w in zip(leaves_g, leaves_w):
        d = ulp_diff(g, w)
        if d.size:
            worst = max(worst, int(d.max()))
    return worst
