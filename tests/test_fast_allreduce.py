"""The opt-in psum fast path (``RoundSpec.fast_allreduce``) under the
tolerance equivalence tier.

Three layers of coverage:

  * harness unit tests — ``tests/equivalence.py`` itself (ULP mapping,
    pass/fail behavior) plus the ``PSUM`` lowering dispatch;
  * single-device tolerance suites — fast-vs-default engines share one
    device, so they exercise the reassociated *math* without collectives;
  * 4-device tolerance suites — psum-vs-gather over full K≥10-round
    sharded runs, params/metrics within rtol=1e-5, plus the explicit test
    that the ledger hashes FORK under the flag (expected behavior: both
    chains self-validate, they just aren't the same chain).

The 4-device cases skip without devices; the CI multidevice lane runs them
under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``, and the slow
subprocess test at the bottom gives the default single-device tier-1 run
the same coverage.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import aggregation, rounds, topology
from repro.data.pipeline import FLDataSource
from repro.models.mlp import init_mlp, mlp_loss

from equivalence import (assert_trees_close, assert_leaves_close, tree_max_ulp,
                         ulp_diff)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

needs4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs 4 host devices (CI multidevice lane sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _params(key, c=8):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (c, 6, 5)),
            "b": jax.random.normal(k2, (c, 5))}


# ---------------------------------------------------------------------------
# The harness itself
# ---------------------------------------------------------------------------


def test_ulp_diff_counts_representable_steps():
    x = np.float32(1.0)
    up = np.nextafter(x, np.float32(2.0), dtype=np.float32)
    assert ulp_diff(np.array([x]), np.array([x]))[0] == 0
    assert ulp_diff(np.array([up]), np.array([x]))[0] == 1
    # the mapping crosses zero without a discontinuity: -0.0 == +0.0
    assert ulp_diff(np.array([-0.0], np.float32),
                    np.array([0.0], np.float32))[0] == 0
    tiny = np.nextafter(np.float32(0.0), np.float32(-1.0), dtype=np.float32)
    assert ulp_diff(np.array([tiny]), np.array([0.0], np.float32))[0] == 1


def test_ulp_diff_float64_opposite_extremes_saturate_not_wrap():
    """Regression: float64 ordered ints span the full int64 range, so the
    distance between opposite-sign extremes overflows the int64 subtraction
    — it must saturate to int64 max, never wrap to a small value that would
    let assert_trees_close(ulp=...) accept maximally distant bit patterns."""
    neg = np.array([np.uint64(0xFFFFFFFFFFFFFFFF)]).view(np.float64)
    pos = np.array([np.uint64(0x7FFFFFFFFFFFFFFF)]).view(np.float64)
    assert ulp_diff(neg, pos)[0] == np.iinfo(np.int64).max
    with pytest.raises(AssertionError):
        assert_leaves_close(neg, pos, ulp=1 << 40)
    # large-but-representable distances still compute exactly
    assert ulp_diff(np.array([-1.0]), np.array([1.0]))[0] == \
        int(ulp_diff(np.array([-1.0]), np.array([0.0]))[0]) * 2


def test_ulp_diff_rejects_mixed_dtypes():
    with pytest.raises(TypeError):
        ulp_diff(np.zeros(2, np.float32), np.zeros(2, np.float64))
    with pytest.raises(TypeError):
        ulp_diff(np.zeros(2, np.int32), np.zeros(2, np.int32))


def test_assert_trees_close_tiers():
    a = {"w": jnp.ones((3,), jnp.float32)}
    b = {"w": jnp.asarray(np.nextafter(np.ones(3, np.float32),
                                       np.float32(2.0)))}
    assert_trees_close(a, a, ulp=0)                    # bitwise degenerate
    assert_trees_close(a, b, ulp=1)                    # one-ulp drift OK
    with pytest.raises(AssertionError):
        assert_trees_close(a, b, ulp=0)                # ...but not bitwise
    assert_trees_close(a, b, rtol=1e-6)                # rtol tier
    with pytest.raises(AssertionError):
        assert_trees_close(a, {"w": jnp.full((3,), 1.1)}, rtol=1e-3)
    with pytest.raises(AssertionError):                # structure mismatch
        assert_trees_close(a, {"v": a["w"]})
    assert tree_max_ulp(a, b) == 1


def test_assert_leaves_close_nan_semantics():
    nan = np.array([np.nan, 1.0], np.float32)
    assert_leaves_close(nan, nan, rtol=1e-6)           # NaN matches NaN
    with pytest.raises(AssertionError):
        assert_leaves_close(nan, np.array([1.0, 1.0], np.float32), rtol=1e-6)


# ---------------------------------------------------------------------------
# PSUM lowering dispatch
# ---------------------------------------------------------------------------


def test_psum_lowering_is_opt_in():
    assert topology.FullMesh().lowering(8).kind == topology.ALL_REDUCE
    assert topology.FullMesh().lowering(
        8, fast_allreduce=True).kind == topology.PSUM
    # stochastic / non-uniform-row matrices keep the gather kind (the engine
    # routes them through mix_psum_dense under the flag instead)
    assert topology.RandomGraph(0.5).lowering(
        8, fast_allreduce=True).kind == topology.GATHER
    assert topology.PartialParticipation(3).lowering(
        8, fast_allreduce=True).kind == topology.GATHER
    assert topology.LinkQualitySchedule().lowering(
        8, fast_allreduce=True).kind == topology.GATHER
    # permute lowerings are already O(window) + bitwise: flag is a no-op
    assert topology.Ring(neighbors=1).lowering(
        8, fast_allreduce=True).kind == topology.NEIGHBOR_PERMUTE
    assert topology.GossipRotation().lowering(
        8, fast_allreduce=True).kind == topology.NEIGHBOR_PERMUTE


def test_uniform_row_detection():
    row = topology.FullMesh().uniform_row(4)
    np.testing.assert_allclose(row, np.full(4, 0.25), atol=0)
    assert topology.Ring(neighbors=1).uniform_row(8) is None
    assert topology.RandomGraph(0.5).uniform_row(8) is None
    assert topology.Topology().uniform_row(8) is None  # abstract matrix


class _UniformRows(topology.Topology):
    """Non-mesh rank-1 topology: every client adopts the same non-uniformly
    weighted average (W = 1 rᵀ)."""

    def matrix(self, n_clients, *, key=None, round_idx=None):
        r = np.linspace(1.0, 2.0, n_clients).astype(np.float32)
        r /= r.sum()
        return jnp.asarray(np.tile(r, (n_clients, 1)))


def test_custom_uniform_row_topology_advertises_psum():
    topo = _UniformRows()
    assert topo.lowering(6).kind == topology.GATHER
    low = topo.lowering(6, fast_allreduce=True)
    assert low.kind == topology.PSUM
    row = topo.uniform_row(6)
    np.testing.assert_allclose(row.sum(), 1.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# mix_psum / mix_psum_dense vs their gathered twins (tolerance tier)
# ---------------------------------------------------------------------------


def _one_device_mesh():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


@pytest.mark.tolerance
def test_mix_psum_dense_mode_close_to_fedavg():
    p = _params(jax.random.key(0))
    got = aggregation.mix_psum(p)
    assert_trees_close(got, aggregation.fedavg(p), rtol=1e-6, atol=1e-7)
    w = jnp.arange(1.0, 9.0)
    got_w = aggregation.mix_psum(p, w)
    assert_trees_close(got_w, aggregation.fedavg(p, w), rtol=1e-6, atol=1e-7)


@pytest.mark.tolerance
def test_mix_psum_dense_variant_unsharded_is_mix():
    p = _params(jax.random.key(1))
    w = topology.RandomGraph(0.5).matrix(8, key=jax.random.key(3))
    got = aggregation.mix_psum_dense(p, w)
    assert_trees_close(got, aggregation.mix(p, w), ulp=0)  # delegates to mix


@pytest.mark.tolerance
def test_mix_psum_sharded_close_to_all_reduce():
    p = _params(jax.random.key(2))
    mesh = _one_device_mesh()
    got = jax.jit(shard_map(
        lambda q: aggregation.mix_psum(q, axis_name="data", n_shards=1),
        mesh=mesh, in_specs=P("data"), out_specs=P("data"),
        check_rep=False))(p)
    assert_trees_close(got, aggregation.mix_all_reduce(p), rtol=1e-6,
                       atol=1e-7)


@pytest.mark.tolerance
def test_mix_psum_dense_sharded_close_to_mix_gather():
    p = _params(jax.random.key(4))
    w = topology.LinkQualitySchedule(fading_period=2).matrix(
        8, round_idx=jnp.int32(1))
    weights = jnp.arange(1.0, 9.0)
    got = jax.jit(shard_map(
        lambda q: aggregation.mix_psum_dense(q, w, weights, axis_name="data",
                                             n_shards=1),
        mesh=_one_device_mesh(), in_specs=P("data"), out_specs=P("data"),
        check_rep=False))(p)
    assert_trees_close(got, aggregation.mix(p, w, weights), rtol=1e-6,
                       atol=1e-7)


@pytest.mark.tolerance
def test_client_divergence_psum_matches_gathered():
    p = _params(jax.random.key(5))
    got = aggregation.client_divergence_psum(p)
    want = aggregation.client_divergence(p)
    assert_leaves_close(got, want, rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# End-to-end K-round runs, single device: fast flag vs default
# ---------------------------------------------------------------------------


def _run_pair(topo, extra, *, mesh=None, c=8, k=10, seed=0):
    key = jax.random.key(seed)
    src = FLDataSource(key, c, samples_per_client=16, seed=seed)
    params = init_mlp(jax.random.fold_in(key, 1))
    batch = src.static_batch()
    rk = jax.random.fold_in(key, 2)
    out = []
    for fast in (False, True):
        spec = rounds.RoundSpec(n_clients=c, tau=2, eta=0.1, mine_attempts=32,
                                difficulty_bits=2, topology=topo,
                                fast_allreduce=fast, **extra)
        out.append(rounds.run_blade_fl_scan(mlp_loss, spec, params, batch,
                                            rk, k, mesh=mesh))
    return out


_DENSE_CASES = [
    ("full_mesh", topology.FullMesh(), {}),
    ("full_mesh_weighted", topology.FullMesh(),
     dict(data_weights=tuple(float(i + 1) for i in range(8)))),
    ("full_mesh_lazy_dp", topology.FullMesh(),
     dict(n_lazy=1, sigma2=0.02, dp_sigma=0.01)),
    ("random_graph", topology.RandomGraph(p_link=0.6), {}),
    ("partial", topology.PartialParticipation(n_active=3), {}),
    ("snr_schedule", topology.LinkQualitySchedule(fading_period=3), {}),
    ("alt_schedule_stochastic", topology.AlternatingSchedule(
        ((topology.RandomGraph(p_link=0.6), 1), (topology.FullMesh(), 1))),
     {}),
]


def _metric_histories_close(h_ref, h_fast):
    """Loss-path metrics must agree to tolerance; mining metrics (winner /
    nonce / pow_hash / digest) legitimately differ because the digest bits
    fork, so they are excluded by construction."""
    for ref, fast in zip(h_ref, h_fast):
        for name in ("local_loss_mean", "divergence", "global_loss"):
            if name in ref:
                assert_leaves_close(
                    np.float32(fast[name]), np.float32(ref[name]),
                    rtol=1e-4, atol=1e-5, err_msg=name)


@pytest.mark.tolerance
@pytest.mark.parametrize("name,topo,extra", _DENSE_CASES,
                         ids=[c[0] for c in _DENSE_CASES])
def test_fast_allreduce_single_device_tolerance(name, topo, extra):
    (st_ref, h_ref, l_ref), (st_fast, h_fast, l_fast) = _run_pair(topo, extra)
    assert_trees_close(st_fast.params, st_ref.params, rtol=1e-5, atol=1e-6)
    _metric_histories_close(h_ref, h_fast)
    assert l_ref.validate_chain() and l_fast.validate_chain()


# ---------------------------------------------------------------------------
# End-to-end K-round runs, 4 devices: psum vs gather (the real fast path)
# ---------------------------------------------------------------------------


def _mesh4():
    return Mesh(np.array(jax.devices()[:4]).reshape(4), ("data",))


@needs4
@pytest.mark.tolerance
@pytest.mark.parametrize("name,topo,extra", _DENSE_CASES,
                         ids=[c[0] for c in _DENSE_CASES])
def test_fast_allreduce_4device_psum_vs_gather(name, topo, extra):
    """Acceptance pin: with the flag on, psum-vs-gather end-of-run params
    agree within rtol=1e-5 over K=10 rounds on 4 fake devices, loss-path
    metrics track, and both engines produce self-validating chains."""
    (st_g, h_g, l_g), (st_p, h_p, l_p) = _run_pair(topo, extra,
                                                   mesh=_mesh4())
    assert_trees_close(st_p.params, st_g.params, rtol=1e-5, atol=1e-6)
    _metric_histories_close(h_g, h_p)
    assert l_g.validate_chain() and l_p.validate_chain()
    assert len(l_p.blocks) == 10


@needs4
@pytest.mark.tolerance
def test_fast_allreduce_default_off_stays_bitwise_sharded():
    """fast_allreduce=False sharded remains bit-for-bit the single-device
    scan — the flag's default must not perturb the bitwise contract."""
    topo = topology.FullMesh()
    key = jax.random.key(7)
    src = FLDataSource(key, 8, samples_per_client=16, seed=7)
    params = init_mlp(jax.random.fold_in(key, 1))
    batch = src.static_batch()
    rk = jax.random.fold_in(key, 2)
    spec = rounds.RoundSpec(n_clients=8, tau=2, eta=0.1, mine_attempts=32,
                            difficulty_bits=2, topology=topo)
    st1, h1, l1 = rounds.run_blade_fl_scan(mlp_loss, spec, params, batch,
                                           rk, 5)
    st2, h2, l2 = rounds.run_blade_fl_scan(mlp_loss, spec, params, batch,
                                           rk, 5, mesh=_mesh4())
    assert_trees_close(st2.params, st1.params, ulp=0)
    assert [b.header_hash for b in l1.blocks] == \
        [b.header_hash for b in l2.blocks]


@needs4
@pytest.mark.tolerance
def test_fast_allreduce_hash_fork_is_expected_behavior():
    """The documented trade of the fast flag: the psum'd digest reassociates
    fp32, so the sharded fast engine's hash chain FORKS from the bitwise
    engine's — from the very first block (the round-1 digest is already
    psum'd) — while each chain stays internally valid. Reproducibility of
    the ledger under the flag means re-running the SAME engine config, not
    cross-checking against the bitwise chain."""
    (st_g, h_g, l_g), (st_p, h_p, l_p) = _run_pair(
        topology.FullMesh(), {}, mesh=_mesh4())
    assert l_g.validate_chain() and l_p.validate_chain()
    heads_g = [b.header_hash for b in l_g.blocks]
    heads_p = [b.header_hash for b in l_p.blocks]
    assert heads_g != heads_p                      # the fork
    assert heads_g[0] != heads_p[0]                # already at block 0
    # ...and the fork is deterministic: the fast engine re-run reproduces
    # its own chain exactly.
    (_, _, _), (_, _, l_p2) = _run_pair(topology.FullMesh(), {},
                                        mesh=_mesh4())
    assert heads_p == [b.header_hash for b in l_p2.blocks]


# ---------------------------------------------------------------------------
# Tier-1 coverage for single-device default runs: the whole tolerance suite
# under 4 fake devices, in a subprocess (XLA_FLAGS must precede jax import)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_tolerance_suite_on_4_devices_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-m", "tolerance",
         os.path.abspath(__file__)],
        capture_output=True, text=True, env=env, timeout=900,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-2000:]
