"""Pluggable communication topology (Steps 2+5 as a mixing matrix) and the
eval_every stride — scan-vs-loop equivalence for every shipped Topology."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, rounds, topology
from repro.data.pipeline import FLDataSource
from repro.models.mlp import init_mlp, mlp_loss

ALL_TOPOLOGIES = [
    topology.FullMesh(),
    topology.Ring(neighbors=1),
    topology.Ring(neighbors=2),
    topology.RandomGraph(p_link=0.6),
    topology.PartialParticipation(n_active=3),
    topology.PairShift(shift=2),
]

ALL_SCHEDULES = [
    topology.GossipRotation(),
    topology.GossipRotation(step=2),
    topology.AlternatingSchedule(
        ((topology.Ring(neighbors=1), 2), (topology.FullMesh(), 1))),
    topology.AlternatingSchedule(
        ((topology.RandomGraph(p_link=0.6), 1), (topology.FullMesh(), 1))),
    topology.LinkQualitySchedule(fading_period=3),
]


def _ids(topo):
    return type(topo).__name__ + "".join(
        f"_{v}" for v in vars(topo).values())


# ---------------------------------------------------------------------------
# Mixing matrices
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", ALL_TOPOLOGIES, ids=_ids)
def test_matrix_row_stochastic(topo):
    c = 5
    w = topo.matrix(c, key=jax.random.key(0), round_idx=jnp.int32(3))
    w = np.asarray(w)
    assert w.shape == (c, c)
    assert (w >= 0).all()
    np.testing.assert_allclose(w.sum(axis=1), np.ones(c), atol=1e-6)


def test_full_mesh_matrix_uniform():
    w = np.asarray(topology.FullMesh().matrix(4))
    np.testing.assert_allclose(w, np.full((4, 4), 0.25), atol=1e-7)


def test_ring_matrix_structure():
    w = np.asarray(topology.Ring(neighbors=1).matrix(5))
    third = pytest.approx(1 / 3, abs=1e-6)
    assert w[0, 0] == third and w[0, 1] == third and w[0, 4] == third
    assert w[0, 2] == 0.0 and w[0, 3] == 0.0


def test_partial_participation_matrix():
    w = np.asarray(topology.PartialParticipation(n_active=2).matrix(4))
    np.testing.assert_allclose(w[:2, :2], np.full((2, 2), 0.5), atol=1e-7)
    np.testing.assert_allclose(w[2:], np.eye(4)[2:], atol=1e-7)


def test_random_graph_deterministic_and_round_varying():
    topo = topology.RandomGraph(p_link=0.5)
    key = jax.random.key(0)
    w0 = np.asarray(topo.matrix(8, key=key, round_idx=jnp.int32(0)))
    w0b = np.asarray(topo.matrix(8, key=key, round_idx=jnp.int32(0)))
    w1 = np.asarray(topo.matrix(8, key=key, round_idx=jnp.int32(1)))
    np.testing.assert_array_equal(w0, w0b)      # same key+round -> same graph
    assert not np.array_equal(w0, w1)           # rounds draw fresh graphs
    assert np.all(np.diag(w0) > 0)              # self-link always delivers


def test_ring_wraparound_never_double_counts():
    # neighbors >= C//2 degenerates to the exact full mesh (distinct window)
    w = np.asarray(topology.Ring(neighbors=2).matrix(4))
    np.testing.assert_allclose(w, np.full((4, 4), 0.25), atol=1e-7)


def test_invalid_params_fail_at_construction():
    with pytest.raises(ValueError):
        topology.Ring(neighbors=0)
    with pytest.raises(ValueError):
        topology.RandomGraph(p_link=1.5)
    with pytest.raises(ValueError):
        topology.PartialParticipation(n_active=0)
    with pytest.raises(ValueError):
        topology.PartialParticipation(n_active=5).matrix(4)


def test_random_graph_requires_key():
    with pytest.raises(ValueError):
        topology.RandomGraph(0.5).matrix(4)


def test_from_name_round_trips():
    assert topology.from_name("full") == topology.FullMesh()
    assert topology.from_name("ring:2") == topology.Ring(neighbors=2)
    assert topology.from_name("random:0.3") == topology.RandomGraph(p_link=0.3)
    assert topology.from_name("partial:7") == \
        topology.PartialParticipation(n_active=7)
    with pytest.raises(ValueError):
        topology.from_name("torus")


def test_topologies_hashable_in_roundspec():
    # RoundSpec is an lru_cache key for the compiled runners
    specs = {rounds.RoundSpec(n_clients=4, tau=1, eta=0.1, topology=t)
             for t in ALL_TOPOLOGIES}
    assert len(specs) == len(ALL_TOPOLOGIES)
    assert rounds.RoundSpec(n_clients=4, tau=1, eta=0.1) == \
        rounds.RoundSpec(n_clients=4, tau=1, eta=0.1,
                         topology=topology.FullMesh())


# ---------------------------------------------------------------------------
# Schedules (time-varying topologies)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sched", ALL_SCHEDULES, ids=_ids)
def test_schedule_matrices_row_stochastic_every_phase(sched):
    c = 6
    for t in range(sched.period(c) + 2):   # + wrap-around rounds
        w = np.asarray(sched.matrix(c, key=jax.random.key(1),
                                    round_idx=jnp.int32(t)))
        assert w.shape == (c, c)
        assert (w >= 0).all()
        np.testing.assert_allclose(w.sum(axis=1), np.ones(c), atol=1e-6)


def test_rotation_cycles_every_partner():
    c = 6
    rot = topology.GossipRotation()
    assert rot.period(c) == c - 1
    shifts = [rot.shift_at(t, c) for t in range(rot.period(c))]
    assert sorted(shifts) == [1, 2, 3, 4, 5]
    # phase t is the PairShift matrix at that shift, and rounds wrap
    for t in (0, 3):
        np.testing.assert_array_equal(
            np.asarray(rot.matrix(c, round_idx=t)),
            np.asarray(topology.PairShift(shifts[t]).matrix(c)))
    np.testing.assert_array_equal(
        np.asarray(rot.matrix(c, round_idx=rot.period(c))),
        np.asarray(rot.matrix(c, round_idx=0)))


def test_alternating_phase_boundaries():
    sched = topology.AlternatingSchedule(
        ((topology.Ring(neighbors=1), 2), (topology.FullMesh(), 1)))
    c = 5
    ring_w = np.asarray(topology.Ring(neighbors=1).matrix(c))
    mesh_w = np.asarray(topology.FullMesh().matrix(c))
    for t, want in [(0, ring_w), (1, ring_w), (2, mesh_w), (3, ring_w)]:
        np.testing.assert_array_equal(
            np.asarray(sched.matrix(c, round_idx=t)), want)


def test_alternating_stochastic_phase_draws_from_key():
    sched = topology.AlternatingSchedule(
        ((topology.RandomGraph(p_link=0.5), 1), (topology.FullMesh(), 1)))
    assert sched.stochastic
    with pytest.raises(ValueError):
        sched.matrix(6, round_idx=0)     # needs a key
    w0 = np.asarray(sched.matrix(6, key=jax.random.key(0), round_idx=0))
    w0b = np.asarray(sched.matrix(6, key=jax.random.key(0), round_idx=0))
    w1 = np.asarray(sched.matrix(6, key=jax.random.key(0), round_idx=1))
    np.testing.assert_array_equal(w0, w0b)
    np.testing.assert_array_equal(w1, np.asarray(topology.FullMesh().matrix(6)))


def test_link_quality_fades_over_rounds_and_repeats():
    sched = topology.LinkQualitySchedule(fading_period=4)
    ws = [np.asarray(sched.matrix(6, round_idx=t)) for t in range(5)]
    assert not np.array_equal(ws[0], ws[1])      # fading moves the weights
    np.testing.assert_array_equal(ws[4], ws[0])  # period 4 repeats
    for w in ws:
        assert (w > 0).all()                     # ergodic: every link alive


def test_pair_shift_identity_degenerate():
    np.testing.assert_array_equal(
        np.asarray(topology.PairShift(shift=4).matrix(4)), np.eye(4))


def test_schedule_invalid_params():
    with pytest.raises(ValueError):
        topology.GossipRotation(step=0)
    with pytest.raises(ValueError):
        topology.AlternatingSchedule(())
    with pytest.raises(ValueError):
        topology.AlternatingSchedule(((topology.FullMesh(), 0),))
    with pytest.raises(ValueError):
        topology.LinkQualitySchedule(fading_period=0)
    with pytest.raises(ValueError):
        topology.PairShift(shift=-1)


def test_from_name_schedules():
    assert topology.from_name("rotate") == topology.GossipRotation()
    assert topology.from_name("rotate:2") == topology.GossipRotation(step=2)
    assert topology.from_name("shift:3") == topology.PairShift(shift=3)
    assert topology.from_name("alt:2:1") == topology.AlternatingSchedule(
        ((topology.Ring(neighbors=1), 2), (topology.FullMesh(), 1)))
    assert topology.from_name("snr:4") == \
        topology.LinkQualitySchedule(fading_period=4)


def test_schedules_hashable_in_roundspec():
    specs = {rounds.RoundSpec(n_clients=4, tau=1, eta=0.1, topology=t)
             for t in ALL_SCHEDULES}
    assert len(specs) == len(ALL_SCHEDULES)


# ---------------------------------------------------------------------------
# mix vs fedavg
# ---------------------------------------------------------------------------


def _params(key, c=6):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (c, 8, 5)),
            "b": jax.random.normal(k2, (c, 5))}


def test_mix_full_mesh_equals_fedavg():
    p = _params(jax.random.key(0))
    w = topology.FullMesh().matrix(6)
    got = aggregation.mix(p, w)
    want = aggregation.fedavg(p)
    for k in p:
        assert jnp.allclose(got[k], want[k], atol=1e-5), k


def test_mix_identity_is_noop():
    p = _params(jax.random.key(1), c=4)
    got = aggregation.mix(p, jnp.eye(4))
    for k in p:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(p[k]),
                                   atol=1e-6)


def test_partial_participation_mix_keeps_inactive():
    c, n_active = 6, 3
    p = _params(jax.random.key(2), c=c)
    w = topology.PartialParticipation(n_active=n_active).matrix(c)
    got = aggregation.mix(p, w)
    for k in p:
        # inactive clients keep their exact models
        np.testing.assert_allclose(np.asarray(got[k][n_active:]),
                                   np.asarray(p[k][n_active:]), atol=1e-6)
        # active clients hold the active-set average
        want = np.mean(np.asarray(p[k][:n_active]), axis=0)
        np.testing.assert_allclose(np.asarray(got[k][0]), want, atol=1e-5)


# ---------------------------------------------------------------------------
# Round engine: scan-vs-loop equivalence for every shipped topology
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", ALL_TOPOLOGIES, ids=_ids)
def test_scan_matches_python_loop_per_topology(topo):
    """The compiled lax.scan driver and the per-round Python loop agree —
    params, metric history, ledger hash links — under every Topology,
    including the stochastic per-round graph."""
    n_clients, k_rounds = 5, 3
    key = jax.random.key(21)
    src = FLDataSource(key, n_clients, samples_per_client=32, seed=21)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(n_clients=n_clients, tau=2, eta=0.1, n_lazy=1,
                            sigma2=0.05, mine_attempts=64, difficulty_bits=2,
                            topology=topo)
    run_key = jax.random.fold_in(key, 2)

    st_py, hist_py, led_py = rounds.run_blade_fl(
        mlp_loss, spec, params, src.round_batch, run_key, k_rounds)
    st_sc, hist_sc, led_sc = rounds.run_blade_fl_scan(
        mlp_loss, spec, params, src.static_batch(), run_key, k_rounds)

    for a, b in zip(jax.tree.leaves(st_py.params), jax.tree.leaves(st_sc.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert hist_py == hist_sc
    assert led_sc.validate_chain()
    assert [b.header_hash for b in led_py.blocks] == \
        [b.header_hash for b in led_sc.blocks]


@pytest.mark.parametrize("sched", ALL_SCHEDULES, ids=_ids)
def test_scan_matches_python_loop_per_schedule(sched):
    """Every shipped Schedule runs inside the compiled scan bit-for-bit
    equal to the per-round Python loop — K spans more than one period, so
    the wrap-around phases are exercised too."""
    n_clients, k_rounds = 5, 7   # GossipRotation period = 4, alt period = 3
    key = jax.random.key(23)
    src = FLDataSource(key, n_clients, samples_per_client=32, seed=23)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(n_clients=n_clients, tau=2, eta=0.1, n_lazy=1,
                            sigma2=0.05, mine_attempts=64, difficulty_bits=2,
                            topology=sched)
    run_key = jax.random.fold_in(key, 2)

    st_py, hist_py, led_py = rounds.run_blade_fl(
        mlp_loss, spec, params, src.round_batch, run_key, k_rounds)
    traces_before = rounds.TRACE_COUNTS["scan_runner"]
    st_sc, hist_sc, led_sc = rounds.run_blade_fl_scan(
        mlp_loss, spec, params, src.static_batch(), run_key, k_rounds)
    # the schedule compiles INTO the scan: one trace covers all K rounds
    assert rounds.TRACE_COUNTS["scan_runner"] - traces_before <= 1

    for a, b in zip(jax.tree.leaves(st_py.params), jax.tree.leaves(st_sc.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert hist_py == hist_sc
    assert led_sc.validate_chain()
    assert [b.header_hash for b in led_py.blocks] == \
        [b.header_hash for b in led_sc.blocks]


def test_rotation_reaches_consensus_faster_than_static_ring():
    """One period of the gossip rotation mixes across the whole client set
    (ergodic gap ~1) while the static ring leaves structured disagreement —
    the scenario the schedule axis opens."""
    n_clients, k_rounds = 8, 7   # one full rotation period
    key = jax.random.key(5)
    src = FLDataSource(key, n_clients, samples_per_client=32, seed=5)
    params = init_mlp(jax.random.fold_in(key, 1))

    def spread_after(topo):
        spec = rounds.RoundSpec(n_clients=n_clients, tau=2, eta=0.1,
                                mine_attempts=32, difficulty_bits=2,
                                topology=topo)
        st, _, _ = rounds.run_blade_fl(
            mlp_loss, spec, params, src.static_batch(),
            jax.random.fold_in(key, 2), k_rounds)
        return float(aggregation.client_divergence(st.params))

    assert spread_after(topology.GossipRotation()) < \
        spread_after(topology.Ring(neighbors=1))


def test_data_weights_reweight_the_mix():
    """RoundSpec.data_weights reweights W rows by |D_j|: a weighted
    FullMesh equals weighted fedavg, and weights must match n_clients."""
    c = 4
    key = jax.random.key(11)
    src = FLDataSource(key, c, samples_per_client=32, seed=11)
    params = init_mlp(jax.random.fold_in(key, 1))
    weights = (4.0, 1.0, 1.0, 2.0)

    def run(topo, dw):
        spec = rounds.RoundSpec(n_clients=c, tau=1, eta=0.1, mine_attempts=32,
                                difficulty_bits=2, topology=topo,
                                data_weights=dw)
        st, _, _ = rounds.run_blade_fl(
            mlp_loss, spec, params, src.static_batch(),
            jax.random.fold_in(key, 2), 1)
        return st.params

    got = run(topology.FullMesh(), weights)
    plain = run(topology.FullMesh(), None)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(plain)):
        assert not np.array_equal(np.asarray(a), np.asarray(b))
    # ring with weights routes through the dense matrix path (no halo);
    # scan-vs-loop equivalence still holds
    spec = rounds.RoundSpec(n_clients=c, tau=1, eta=0.1, mine_attempts=32,
                            difficulty_bits=2, topology=topology.Ring(1),
                            data_weights=weights)
    st1, h1, _ = rounds.run_blade_fl(
        mlp_loss, spec, params, src.static_batch(), jax.random.fold_in(key, 2), 2)
    st2, h2, _ = rounds.run_blade_fl(
        mlp_loss, spec, params, src.round_batch, jax.random.fold_in(key, 2), 2)
    assert h1 == h2
    with pytest.raises(ValueError, match="data_weights"):
        rounds.make_integrated_round(
            mlp_loss, rounds.RoundSpec(n_clients=c, tau=1, eta=0.1,
                                       data_weights=(1.0, 2.0)))


def test_full_mesh_round_collapses_spread_ring_does_not():
    """After one full-mesh round all clients agree (paper Step 5); a ring
    leaves residual disagreement — the scenario axis the refactor opens."""
    n_clients = 6
    key = jax.random.key(4)
    src = FLDataSource(key, n_clients, samples_per_client=32, seed=4)
    params = init_mlp(jax.random.fold_in(key, 1))

    def spread_after_round(topo):
        spec = rounds.RoundSpec(n_clients=n_clients, tau=2, eta=0.1,
                                mine_attempts=32, topology=topo)
        fn = jax.jit(rounds.make_integrated_round(mlp_loss, spec))
        st = rounds.init_state(params, jax.random.key(2), n_clients)
        st, _ = fn(st, src.round_batch(0))
        return float(aggregation.client_divergence(st.params))

    assert spread_after_round(topology.FullMesh()) < 1e-5
    assert spread_after_round(topology.Ring(neighbors=1)) > 1e-4


def test_default_topology_bit_for_bit_with_explicit_full_mesh():
    """RoundSpec() (the pre-refactor engine) and an explicit FullMesh produce
    byte-identical histories — the baseline did not move."""
    key = jax.random.key(9)
    src = FLDataSource(key, 4, samples_per_client=32, seed=9)
    params = init_mlp(jax.random.fold_in(key, 1))
    kw = dict(n_clients=4, tau=2, eta=0.1, n_lazy=1, sigma2=0.02,
              dp_sigma=0.1, mine_attempts=64)
    run = lambda spec: rounds.run_blade_fl(  # noqa: E731
        mlp_loss, spec, params, src.static_batch(),
        jax.random.fold_in(key, 2), 3)
    _, hist_default, led_a = run(rounds.RoundSpec(**kw))
    _, hist_mesh, led_b = run(
        rounds.RoundSpec(**kw, topology=topology.FullMesh()))
    assert hist_default == hist_mesh
    assert [b.header_hash for b in led_a.blocks] == \
        [b.header_hash for b in led_b.blocks]


# ---------------------------------------------------------------------------
# eval_every stride
# ---------------------------------------------------------------------------


def _run_stride(eval_every, k_rounds=4, seed=13, batches="static"):
    key = jax.random.key(seed)
    src = FLDataSource(key, 4, samples_per_client=32, seed=seed)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(n_clients=4, tau=2, eta=0.1, mine_attempts=64,
                            difficulty_bits=2, eval_every=eval_every)
    b = src.static_batch() if batches == "static" else src.round_batch
    return rounds.run_blade_fl(mlp_loss, spec, params, b,
                               jax.random.fold_in(key, 2), k_rounds)


def test_eval_every_nan_masks_skipped_rounds():
    _, hist, _ = _run_stride(eval_every=2, k_rounds=4)
    flags = [math.isfinite(h["global_loss"]) for h in hist]
    assert flags == [False, True, False, True]  # eval on rounds 1 and 3


def test_eval_every_preserves_dynamics_and_values():
    """The stride only masks the metric — params dynamics and the evaluated
    entries match the eval-every-round run exactly, on both driver paths."""
    st1, hist1, led1 = _run_stride(eval_every=1)
    st2, hist2, led2 = _run_stride(eval_every=2)
    for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [b.header_hash for b in led1.blocks] == \
        [b.header_hash for b in led2.blocks]
    for h1, h2 in zip(hist1, hist2):
        if math.isfinite(h2["global_loss"]):
            assert h1["global_loss"] == h2["global_loss"]
    # python loop agrees with the scan engine, NaN mask included
    _, hist_py, _ = _run_stride(eval_every=2, batches="callable")
    for hs, hp in zip(hist2, hist_py):
        assert (hs["global_loss"] == hp["global_loss"]) or (
            math.isnan(hs["global_loss"]) and math.isnan(hp["global_loss"]))


def test_eval_every_default_history_unchanged():
    _, hist, _ = _run_stride(eval_every=1)
    assert all(math.isfinite(h["global_loss"]) for h in hist)


def test_eval_every_forces_final_round_eval():
    """Regression: with K % eval_every != 0 the last round used to report
    NaN, which propagated into sweep_k / bench_topology best-K selection.
    K=5, eval_every=2 must end on a finite eval — on both driver paths."""
    _, hist, _ = _run_stride(eval_every=2, k_rounds=5)
    flags = [math.isfinite(h["global_loss"]) for h in hist]
    assert flags == [False, True, False, True, True]   # forced final eval
    # python loop pins the identical pattern (scan-vs-loop equivalence)
    _, hist_py, _ = _run_stride(eval_every=2, k_rounds=5, batches="callable")
    for hs, hp in zip(hist, hist_py):
        assert (hs["global_loss"] == hp["global_loss"]) or (
            math.isnan(hs["global_loss"]) and math.isnan(hp["global_loss"]))


def test_eval_every_final_loss_reaches_best_k_selection():
    """The selection-facing consequence of the fix: run_once at K=5,
    eval_every=2 reports a finite final_loss for best-K comparison."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import common
    res = common.run_once(k=5, eval_every=2, n_clients=4, samples=32,
                          beta=10.0)
    assert math.isfinite(res["final_loss"])
    assert math.isfinite(res["loss_curve"][-1])   # last round evaluated



# ---------------------------------------------------------------------------
# Stochastic-schedule replay: rounds.topology_keys reproduces the engine's
# actual per-round W draws
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", [
    topology.RandomGraph(p_link=0.6),
    topology.AlternatingSchedule(
        ((topology.RandomGraph(p_link=0.5), 1), (topology.FullMesh(), 1))),
], ids=_ids)
def test_topology_keys_replays_engine_draws(topo):
    """``rounds.topology_keys(run_key, K)`` must regenerate the EXACT k_topo
    stream the engine folds per round (the contract spectral.gap_report's
    stochastic diagnostics rely on): rebuilding the run host-side — the
    local-train stage alternated with ``aggregation.mix`` of the replayed
    matrices — reproduces the engine's end-of-run params on the loop driver,
    the scan driver, AND the sharded scan driver. A deliberately shifted key
    stream draws different graphs and visibly diverges."""
    from jax.sharding import Mesh

    c, k_rounds = 6, 4
    key = jax.random.key(21)
    src = FLDataSource(key, c, samples_per_client=8, seed=21)
    params = init_mlp(jax.random.fold_in(key, 1))
    batch = src.static_batch()
    run_key = jax.random.fold_in(key, 2)
    spec = rounds.RoundSpec(n_clients=c, tau=1, eta=0.1, mine_attempts=8,
                            difficulty_bits=0, topology=topo)
    st_loop, _, _ = rounds.run_blade_fl(mlp_loss, spec, params,
                                        lambda k: batch, run_key, k_rounds)
    st_scan, _, _ = rounds.run_blade_fl_scan(mlp_loss, spec, params, batch,
                                             run_key, k_rounds)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    st_shard, _, _ = rounds.run_blade_fl_scan(mlp_loss, spec, params, batch,
                                              run_key, k_rounds, mesh=mesh)

    local_train = jax.jit(rounds.make_local_train(mlp_loss, spec))

    def replay(keys):
        p = aggregation.replicate(params, c)
        for k, k_topo in enumerate(keys):
            p, _ = local_train(p, batch)
            w = topo.matrix(c, key=k_topo, round_idx=jnp.int32(k))
            p = aggregation.mix(p, w)
        return p

    expect = replay(rounds.topology_keys(run_key, k_rounds))
    for got in (st_loop.params, st_scan.params, st_shard.params):
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(expect)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
    # negative control: a shifted key stream must not reproduce the run —
    # otherwise this test could not tell right draws from wrong ones
    wrong = replay(rounds.topology_keys(jax.random.fold_in(run_key, 9),
                                        k_rounds))
    assert any(
        not np.allclose(np.asarray(a), np.asarray(b), atol=1e-6)
        for a, b in zip(jax.tree.leaves(st_scan.params),
                        jax.tree.leaves(wrong)))
