"""Integrated-round engine (§3.1): learning works, lazy hurts, chain holds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rounds
from repro.data.pipeline import FLDataSource
from repro.models.mlp import init_mlp, mlp_loss


def _run(n_clients=6, n_lazy=0, sigma2=0.0, k_rounds=4, tau=4, eta=0.1,
         dp_sigma=0.0, seed=0):
    key = jax.random.key(seed)
    src = FLDataSource(key, n_clients, samples_per_client=64, seed=seed)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(n_clients=n_clients, tau=tau, eta=eta,
                            n_lazy=n_lazy, sigma2=sigma2, dp_sigma=dp_sigma,
                            mine_attempts=128, difficulty_bits=2)
    return rounds.run_blade_fl(mlp_loss, spec, params, src.round_batch,
                               jax.random.fold_in(key, 2), k_rounds)


def test_loss_decreases():
    _, hist, _ = _run()
    losses = [h["global_loss"] for h in hist]
    assert losses[-1] < losses[0]


def test_chain_valid_and_linked():
    _, hist, ledger = _run(k_rounds=3)
    assert ledger.validate_chain()
    assert len(ledger.blocks) == 3
    assert not ledger.tampered_copy(1, model_digest=1).validate_chain()


def test_lazy_clients_degrade_learning():
    _, clean, _ = _run(k_rounds=4, seed=3)
    _, lazy, _ = _run(k_rounds=4, n_lazy=3, sigma2=0.3, seed=3)
    assert lazy[-1]["global_loss"] > clean[-1]["global_loss"]


def test_noise_power_hurts():
    _, lo, _ = _run(k_rounds=3, n_lazy=2, sigma2=0.01, seed=4)
    _, hi, _ = _run(k_rounds=3, n_lazy=2, sigma2=1.0, seed=4)
    assert hi[-1]["global_loss"] >= lo[-1]["global_loss"]


def test_divergence_positive_pre_aggregation():
    _, hist, _ = _run(k_rounds=2)
    assert hist[-1]["divergence"] > 0


def test_winner_varies_with_round():
    _, hist, _ = _run(k_rounds=6, seed=5)
    winners = {h["winner"] for h in hist}
    assert len(winners) > 1  # the race isn't rigged


def test_microbatched_grad_matches_full():
    key = jax.random.key(0)
    src = FLDataSource(key, 2, samples_per_client=32)
    batch = src.round_batch(0)
    params = init_mlp(jax.random.fold_in(key, 1))

    g_full = rounds._microbatched_grad(mlp_loss, 1)
    g_mb = rounds._microbatched_grad(mlp_loss, 4)
    one = {k: v[0] for k, v in batch.items()}
    l1, gr1 = g_full(params, one)
    l2, gr2 = g_mb(params, one)
    assert abs(float(l1) - float(l2)) < 1e-5
    for a, b in zip(jax.tree.leaves(gr1), jax.tree.leaves(gr2)):
        assert jnp.allclose(a, b, atol=1e-5)


def test_dp_noise_applied():
    _, clean, _ = _run(k_rounds=2, seed=6)
    _, noisy, _ = _run(k_rounds=2, dp_sigma=0.5, seed=6)
    assert noisy[-1]["global_loss"] != clean[-1]["global_loss"]


def test_round_state_advances():
    key = jax.random.key(0)
    src = FLDataSource(key, 4, samples_per_client=32)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(n_clients=4, tau=1, eta=0.05, mine_attempts=64)
    fn = jax.jit(rounds.make_integrated_round(mlp_loss, spec))
    st = rounds.init_state(params, jax.random.key(2), 4)
    st2, _ = fn(st, src.round_batch(0))
    assert int(st2.round_idx) == 1
    assert int(st2.prev_hash) != int(st.prev_hash)


def test_scan_engine_matches_python_loop():
    """The compiled lax.scan driver reproduces the per-round Python loop
    bit-for-bit — final params, metric history, and ledger hash links — with
    lazy clients AND DP noise enabled, and traces exactly once for K rounds."""
    n_clients, k_rounds = 6, 5
    key = jax.random.key(11)
    src = FLDataSource(key, n_clients, samples_per_client=64, seed=11)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(n_clients=n_clients, tau=3, eta=0.1, n_lazy=2,
                            sigma2=0.05, dp_sigma=0.2, mine_attempts=128,
                            difficulty_bits=2)
    run_key = jax.random.fold_in(key, 2)

    # reference: per-round Python loop (callable batch forces that path)
    st_py, hist_py, led_py = rounds.run_blade_fl(
        mlp_loss, spec, params, src.round_batch, run_key, k_rounds)

    traces0 = rounds.TRACE_COUNTS["scan_runner"]
    st_sc, hist_sc, led_sc = rounds.run_blade_fl_scan(
        mlp_loss, spec, params, src.static_batch(), run_key, k_rounds)
    assert rounds.TRACE_COUNTS["scan_runner"] - traces0 == 1  # one trace for K rounds

    for a, b in zip(jax.tree.leaves(st_py.params), jax.tree.leaves(st_sc.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert len(hist_py) == len(hist_sc) == k_rounds
    for hp, hs in zip(hist_py, hist_sc):
        assert hp == hs
    assert led_sc.validate_chain()
    assert [b.header_hash for b in led_py.blocks] == \
        [b.header_hash for b in led_sc.blocks]

    # same config again: lru-cached runner, zero retrace
    rounds.run_blade_fl_scan(mlp_loss, spec, params, src.static_batch(),
                             run_key, k_rounds)
    assert rounds.TRACE_COUNTS["scan_runner"] - traces0 == 1


def test_scan_engine_stacked_batches():
    """stacked=True scans a [K, C, ...] xs tensor; equals the Python loop
    fed the same per-round batches."""
    key = jax.random.key(3)
    src = FLDataSource(key, 4, samples_per_client=32, seed=3)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(n_clients=4, tau=2, eta=0.1, mine_attempts=64,
                            difficulty_bits=2)
    k_rounds = 3
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[src.round_batch(k) for k in range(k_rounds)])
    run_key = jax.random.fold_in(key, 2)
    _, hist_py, led_py = rounds.run_blade_fl(
        mlp_loss, spec, params, src.round_batch, run_key, k_rounds)
    _, hist_sc, led_sc = rounds.run_blade_fl(
        mlp_loss, spec, params, stacked, run_key, k_rounds, stacked=True)
    assert hist_py == hist_sc
    assert [b.header_hash for b in led_py.blocks] == \
        [b.header_hash for b in led_sc.blocks]
    # K must match the stack depth — scan takes its length from xs
    with pytest.raises(ValueError):
        rounds.run_blade_fl_scan(mlp_loss, spec, params, stacked, run_key,
                                 k_rounds + 1, stacked=True)


def test_ledger_from_scan_rejects_broken_link():
    from repro.core import chain
    led = rounds.run_blade_fl(  # quick 2-round run for real header fields
        mlp_loss,
        rounds.RoundSpec(n_clients=2, tau=1, eta=0.1, mine_attempts=32),
        init_mlp(jax.random.key(1)),
        FLDataSource(jax.random.key(0), 2, 16).static_batch(),
        jax.random.key(2), 2)[2]
    digests = np.array([b.model_digest for b in led.blocks], np.uint32)
    winners = np.array([b.winner for b in led.blocks], np.int32)
    nonces = np.array([b.nonce for b in led.blocks], np.uint32)
    pow_hashes = np.array([b.pow_hash for b in led.blocks], np.uint32)
    rebuilt = chain.ledger_from_scan(digests, winners, nonces, pow_hashes)
    assert rebuilt.validate_chain()
    assert [b.header_hash for b in rebuilt.blocks] == \
        [b.header_hash for b in led.blocks]
    # a PoW-enforcing ledger rejects headers that miss the target
    strict = chain.Ledger(difficulty_bits=32)
    with pytest.raises(ValueError):
        chain.ledger_from_scan(digests, winners, nonces, pow_hashes,
                               ledger=strict)


def test_detection_inside_round():
    """beyond-paper: detect_lazy metric flags plagiarists in a live round."""
    key = jax.random.key(7)
    src = FLDataSource(key, 8, samples_per_client=64)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(n_clients=8, tau=6, eta=0.2, n_lazy=2,
                            sigma2=1e-4, mine_attempts=64, detect_lazy=True)
    fn = jax.jit(rounds.make_integrated_round(mlp_loss, spec))
    st = rounds.init_state(params, jax.random.key(2), 8)
    # two rounds so clients diverge before plagiarism happens
    st, m = fn(st, src.round_batch(0))
    st, m = fn(st, src.round_batch(1))
    assert int(m["n_suspects"]) >= 2  # both lazy clients (+ maybe sources)
    # clean run flags nobody after divergence
    spec0 = rounds.RoundSpec(n_clients=8, tau=6, eta=0.2, n_lazy=0,
                             mine_attempts=64, detect_lazy=True)
    fn0 = jax.jit(rounds.make_integrated_round(mlp_loss, spec0))
    st0 = rounds.init_state(params, jax.random.key(2), 8)
    st0, m0 = fn0(st0, src.round_batch(0))
    st0, m0 = fn0(st0, src.round_batch(1))
    assert int(m0["n_suspects"]) == 0


# ---------------------------------------------------------------------------
# auto dispatch: loop-vs-scan-vs-kernel on problem size
# ---------------------------------------------------------------------------


def _batch(c, samples):
    return {"x": jnp.zeros((c, samples, 4)), "y": jnp.zeros((c, samples),
                                                            jnp.int32)}


def test_dispatch_micro_sim_takes_loop():
    spec = rounds.RoundSpec(n_clients=4, tau=1, eta=0.1, mine_attempts=64)
    plan = rounds.dispatch_plan(spec, _batch(4, 16), 3)
    assert plan["driver"] == "loop"
    assert "micro" in plan["reason"]


def test_dispatch_paper_scale_takes_scan():
    spec = rounds.RoundSpec(n_clients=20, tau=2, eta=0.1, mine_attempts=64)
    plan = rounds.dispatch_plan(spec, _batch(20, 512), 10)
    assert plan["driver"] == "scan"
    # a micro client count with a real batch is NOT micro
    spec4 = rounds.RoundSpec(n_clients=4, tau=1, eta=0.1, mine_attempts=64)
    assert rounds.dispatch_plan(spec4, _batch(4, 512), 3)["driver"] == "scan"


def test_dispatch_callable_and_nojit_force_loop():
    spec = rounds.RoundSpec(n_clients=20, tau=2, eta=0.1, mine_attempts=64)
    assert rounds.dispatch_plan(spec, lambda k: None, 3)["driver"] == "loop"
    assert rounds.dispatch_plan(spec, _batch(20, 512), 3,
                                jit=False)["driver"] == "loop"


def test_dispatch_pow_kernel_needs_budget():
    big = rounds.RoundSpec(n_clients=8, tau=1, eta=0.1, mine_attempts=4096,
                           use_kernel=True)
    tiny = rounds.RoundSpec(n_clients=8, tau=1, eta=0.1, mine_attempts=64,
                            use_kernel=True)
    off = rounds.RoundSpec(n_clients=8, tau=1, eta=0.1, mine_attempts=4096)
    b = _batch(8, 512)
    assert rounds.dispatch_plan(big, b, 3)["pow"] == "kernel"
    assert rounds.dispatch_plan(tiny, b, 3)["pow"] == "fori_loop"  # downgrade
    assert rounds.dispatch_plan(off, b, 3)["pow"] == "fori_loop"
    assert rounds.dispatch_plan(big, b, 3)["mix"] == "jnp"
    fused = rounds.RoundSpec(n_clients=8, tau=1, eta=0.1, mine_attempts=64,
                             fused_mix=True)
    assert rounds.dispatch_plan(fused, b, 3)["mix"] == "fused"


def test_dispatch_micro_loop_matches_scan_bitwise():
    """The micro-sim loop shortcut is results-safe: run_blade_fl's loop
    dispatch reproduces the direct scan engine bit for bit."""
    key = jax.random.key(3)
    src = FLDataSource(key, 4, samples_per_client=16)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(n_clients=4, tau=2, eta=0.1, mine_attempts=64)
    batch = src.static_batch()
    rk = jax.random.fold_in(key, 2)
    st_l, h_l, led_l = rounds.run_blade_fl(mlp_loss, spec, params, batch,
                                           rk, 3)
    assert rounds.LAST_DISPATCH["driver"] == "loop"  # recorded decision
    st_s, h_s, led_s = rounds.run_blade_fl_scan(mlp_loss, spec, params,
                                                batch, rk, 3)
    for a, b in zip(jax.tree.leaves(st_l.params),
                    jax.tree.leaves(st_s.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [b.header_hash for b in led_l.blocks] == \
        [b.header_hash for b in led_s.blocks]


def test_dispatch_small_budget_downgrades_use_kernel():
    """run_blade_fl honours the pow downgrade: use_kernel with a tiny budget
    runs the fori_loop path (bitwise identical anyway) and records it."""
    import dataclasses
    key = jax.random.key(5)
    src = FLDataSource(key, 4, samples_per_client=16)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(n_clients=4, tau=1, eta=0.1, mine_attempts=64,
                            use_kernel=True, kernel_interpret=True)
    _, h_k, led_k = rounds.run_blade_fl(mlp_loss, spec, params,
                                        src.static_batch(),
                                        jax.random.fold_in(key, 2), 2)
    assert rounds.LAST_DISPATCH["pow"] == "fori_loop"
    seed = dataclasses.replace(spec, use_kernel=False, kernel_interpret=None)
    _, h_s, led_s = rounds.run_blade_fl(mlp_loss, seed, params,
                                        src.static_batch(),
                                        jax.random.fold_in(key, 2), 2)
    assert [b.header_hash for b in led_k.blocks] == \
        [b.header_hash for b in led_s.blocks]
