"""Two-level aggregation + single-dispatch-surface regressions.

Host side: ``dispatch_plan``'s reported ``mix_mode`` is pinned to the mode
``make_communicate`` actually executes, for every shipped topology crossed
with every mix-relevant ``RoundSpec`` flag — both read the SAME
``topology.resolve_mix_plan``, so report/trace drift (the duplicated
weighted-reroute bug this PR deleted) cannot reappear.

Subprocess side (8 fake devices, 2x4 ``('pod', 'data')`` mesh): the
linearized multi-axis halo lowerings equal dense ``mix_rolls`` bitwise for
shift grids that cross the pod seam and wrap the population, and
``mix_cluster``'s aligned in-pod + cross-pod path equals its dense
``kron(B, J/S)`` math bitwise.
"""
import itertools
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

from repro.core import rounds, topology  # noqa: E402

C = 8

TOPOLOGIES = [
    topology.FullMesh(),
    topology.Ring(neighbors=1),
    topology.Ring(neighbors=2),
    topology.RandomGraph(p_link=0.6),
    topology.PartialParticipation(n_active=3),
    topology.PairShift(shift=5),
    topology.ClusterTopology(n_clusters=2),
    topology.ClusterTopology(n_clusters=4, inter_weight=0.5),
    topology.ExplicitSparse(neighbors=tuple(
        (i, (i + 1) % C) for i in range(C))),
    topology.GossipRotation(),
    topology.AlternatingSchedule(
        ((topology.Ring(neighbors=1), 2), (topology.FullMesh(), 1))),
    topology.LinkQualitySchedule(fading_period=3),
]

FLAG_GRID = list(itertools.product(
    (False, True),                                   # fast_allreduce
    (False, True),                                   # fused_mix
    (None, True),                                    # sparse_mix
    (None, tuple(float(i + 1) for i in range(C))),   # data_weights
    (None, "median", "trimmed:2", "geomed:4"),       # robust_agg
))


def _spec(topo, fast, fused, sparse, weights, robust=None):
    return rounds.RoundSpec(
        n_clients=C, tau=1, eta=0.1, mine_attempts=8, difficulty_bits=1,
        topology=topo, fast_allreduce=fast, fused_mix=fused,
        sparse_mix=sparse, data_weights=weights, robust_agg=robust)


@pytest.mark.parametrize("topo", TOPOLOGIES,
                         ids=lambda t: type(t).__name__)
def test_dispatch_report_matches_executed_mode(topo):
    """plan['mix_mode'] (the report) == communicate.plan.mode (the trace)
    for every flag combination — one resolver, zero drift."""
    import jax.numpy as jnp
    batch = {"x": jnp.zeros((C, 4, 3)), "y": jnp.zeros((C, 4), jnp.int32)}
    for fast, fused, sparse, weights, robust in FLAG_GRID:
        spec = _spec(topo, fast, fused, sparse, weights, robust)
        try:
            reported = rounds.dispatch_plan(spec, batch, 3)["mix_mode"]
        except ValueError:
            # resolver rejected the combo (e.g. sparse_mix=True on a
            # stochastic graph, or a robust override crossed with a
            # linear fast path) — the executor must reject it identically
            with pytest.raises(ValueError):
                rounds.make_communicate(spec)
            continue
        executed = rounds.make_communicate(spec).plan.mode
        assert reported == executed, (
            type(topo).__name__, fast, fused, sparse,
            weights is not None, robust, reported, executed)


def test_dispatch_grid_covers_every_executor_mode():
    """The topology x flag grid above actually exercises the whole executor
    surface — if a new EXEC_* mode ships without a topology that reaches
    it, this fails and the grid must grow."""
    seen = set()
    for topo in TOPOLOGIES:
        for fast, fused, sparse, weights, robust in FLAG_GRID:
            spec = _spec(topo, fast, fused, sparse, weights, robust)
            try:
                seen.add(rounds.make_communicate(spec).plan.mode)
                # sharded resolve: EXEC_HALO degrades to EXEC_SHIFT_HALO
                # when the shift window outgrows the per-shard block
                seen.add(rounds.make_communicate(
                    spec, axis_name=("pod", "data"), n_shards=8,
                    axis_sizes=(2, 4)).plan.mode)
            except ValueError:
                continue  # resolver-rejected combo (covered above)
    all_modes = {getattr(topology, n) for n in dir(topology)
                 if n.startswith("EXEC_")}
    assert seen == all_modes, (sorted(seen), sorted(all_modes))


@pytest.mark.slow
def test_multi_axis_halo_and_cluster_grid_subprocess():
    """On the 2x4 ('pod', 'data') mesh the linearized halo lowerings match
    dense mix_rolls bitwise for every offset grid — windows inside one
    block, shifts across the pod seam (device 3 -> 4), and full wraps — and
    mix_cluster's aligned and unaligned shardings match its dense path."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core import aggregation

        C = 16
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))
        axes = ("pod", "data")
        key = jax.random.key(11)
        tree = {
            "m2": jax.random.normal(key, (C, 37), jnp.float32),
            "m3": jax.random.normal(jax.random.fold_in(key, 1),
                                    (C, 5, 7), jnp.float32),
        }

        def sharded(fn):
            wrapped = shard_map(fn, mesh=mesh, in_specs=P(axes),
                                out_specs=P(axes), check_rep=False)
            return jax.jit(wrapped)

        def bitwise(a, b):
            return all(bool((np.asarray(x) == np.asarray(y)).all())
                       for x, y in zip(jax.tree.leaves(a),
                                       jax.tree.leaves(b)))

        out = {}
        # local block is C/8 = 2 rows: (-2..2) is the one-block halo
        # window; the rest exercise mix_shift_halo's q-block decomposition
        halo_grids = [(-1, 0, 1), (-2, -1, 0, 1, 2)]
        shift_grids = [(5,), (-7,), (0, 8), (3, 13), (1, 6, 11)]
        for offs in halo_grids:
            dense = aggregation.mix_rolls(tree, offs, 1.0 / len(offs))
            halo = sharded(lambda t: aggregation.mix_neighbor_halo(
                t, offs, 1.0 / len(offs), axes))(tree)
            out[f"halo{offs}"] = bitwise(dense, halo)
        for offs in halo_grids + shift_grids:
            dense = aggregation.mix_rolls(tree, offs, 1.0 / len(offs))
            shift = sharded(lambda t: aggregation.mix_shift_halo(
                t, offs, 1.0 / len(offs), axes))(tree)
            out[f"shift{offs}"] = bitwise(dense, shift)
        for g in (2, 4):   # pod-aligned and unaligned cluster counts
            dense = aggregation.mix_cluster(tree, g, 0.3)
            shard = sharded(lambda t: aggregation.mix_cluster(
                t, g, 0.3, axes, n_shards=8))(tree)
            out[f"cluster_g{g}"] = bitwise(dense, shard)
        print(json.dumps(out))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res and all(res.values()), res
