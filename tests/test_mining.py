"""PoW simulation (§2.2/§3.1 Step 3)."""
import jax.numpy as jnp
import numpy as np

from repro.core import mining


def test_mix_hash_deterministic_and_sensitive():
    h1 = mining.mix_hash(jnp.uint32(1), jnp.uint32(2), jnp.uint32(3))
    h2 = mining.mix_hash(jnp.uint32(1), jnp.uint32(2), jnp.uint32(3))
    h3 = mining.mix_hash(jnp.uint32(1), jnp.uint32(2), jnp.uint32(4))
    assert int(h1) == int(h2)
    assert int(h1) != int(h3)


def test_mix_hash_distribution():
    nonces = jnp.arange(4096, dtype=jnp.uint32)
    hs = np.asarray(mining.mix_hash(jnp.uint32(7), jnp.uint32(9), nonces))
    # roughly uniform over uint32: mean near 2^31, plenty of unique values
    assert len(np.unique(hs)) > 4000
    assert 0.4 < hs.mean() / 2**32 < 0.6


def test_pow_search_matches_bruteforce():
    prev, payload = jnp.uint32(123), jnp.uint32(456)
    n = 3000
    bh, bn = mining.pow_search(prev, payload, jnp.uint32(0), n, chunk=512)
    salt = mining._avalanche(jnp.uint32(0) * jnp.uint32(2246822519))
    nonces = jnp.arange(n, dtype=jnp.uint32)
    hs = mining.mix_hash(prev, payload ^ salt, nonces)
    assert int(bh) == int(jnp.min(hs))


def test_pow_search_respects_attempt_budget():
    """Tail chunk must not search past the calibrated budget (eq. 1):
    n_attempts=1500, chunk=1024 -> the 2nd chunk is masked to 476 live
    nonces, so the returned nonce stays < offset + 1500."""
    prev, payload = jnp.uint32(9), jnp.uint32(77)
    offset = 4096
    for seed_payload in range(8):
        bh, bn = mining.pow_search(prev, jnp.uint32(77 + seed_payload),
                                   jnp.uint32(0), 1500, nonce_offset=offset,
                                   chunk=1024)
        assert offset <= int(bn) < offset + 1500, int(bn)
    # masked search == brute force over exactly n_attempts nonces
    salt = mining._avalanche(jnp.uint32(0) * jnp.uint32(2246822519))
    nonces = jnp.uint32(offset) + jnp.arange(1500, dtype=jnp.uint32)
    hs = mining.mix_hash(prev, payload ^ salt, nonces)
    bh, bn = mining.pow_search(prev, payload, jnp.uint32(0), 1500,
                               nonce_offset=offset, chunk=1024)
    assert int(bh) == int(jnp.min(hs))
    assert int(bn) == int(nonces[jnp.argmin(hs)])


def test_pow_search_clients_disjoint():
    prev, payload = jnp.uint32(1), jnp.uint32(2)
    h0, _ = mining.pow_search(prev, payload, jnp.uint32(0), 256)
    h1, _ = mining.pow_search(prev, payload, jnp.uint32(1), 256)
    assert int(h0) != int(h1)  # different salt -> different race


def test_winner_argmin():
    assert int(mining.winner_of(jnp.array([5, 3, 9], jnp.uint32))) == 1


def test_difficulty_threshold():
    assert int(mining.difficulty_threshold(0)) == 0xFFFFFFFF
    assert int(mining.difficulty_threshold(8)) == 0x00FFFFFF


def test_digest_tree_changes_with_params():
    t1 = {"a": jnp.ones((4, 4)), "b": jnp.zeros((3,))}
    t2 = {"a": jnp.ones((4, 4)) * 2, "b": jnp.zeros((3,))}
    d1, d2 = mining.digest_tree(t1), mining.digest_tree(t2)
    assert int(d1) != int(d2)
    assert int(d1) == int(mining.digest_tree(t1))
