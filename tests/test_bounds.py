"""Theorems 1-4 + Corollaries 1-5: bound math validated numerically."""
import math

import pytest

from repro.core import allocation, bounds


def make_params(**kw):
    base = dict(eta=0.01, L=10.0, xi=1.0, delta=0.5, alpha=1.0, beta=10.0,
                t_sum=100.0, w0_dist=1.0)
    base.update(kw)
    return bounds.BoundParams(**base)


class TestBound:
    def test_bound_positive_and_finite_on_feasible_grid(self):
        p = make_params()
        ks = allocation.feasible_rounds(p.t_sum, p.alpha, p.beta)
        assert ks, "no feasible K"
        vals = [bounds.loss_bound(p, k) for k in ks]
        assert all(v > 0 for v in vals)
        assert any(math.isfinite(v) for v in vals)

    def test_convex_in_k(self):
        # Theorem 2
        for eta in (0.005, 0.01, 0.05):
            p = make_params(eta=eta)
            assert bounds.is_convex_in_k(p)

    def test_interior_minimum_exists(self):
        p = make_params()
        ks = allocation.feasible_rounds(p.t_sum, p.alpha, p.beta)
        vals = [bounds.loss_bound(p, k) for k in ks]
        finite = [(k, v) for k, v in zip(ks, vals) if math.isfinite(v)]
        k_best = min(finite, key=lambda kv: kv[1])[0]
        assert finite[0][0] < k_best or finite[0][1] > min(v for _, v in finite)


class TestKStar:
    def test_closed_form_matches_numeric(self):
        # Theorem 3 approximation is valid when eta*L*tau << 1
        p = make_params(eta=0.002, L=5.0, beta=4.0, t_sum=400.0)
        k_cf = bounds.k_star_closed_form(p)
        k_num = bounds.k_star_numeric(p)
        assert abs(k_cf - k_num) <= max(2, 0.35 * k_num)

    def test_corollary1_k_decreases_with_alpha_and_beta(self):
        base = make_params(eta=0.002, L=5.0, t_sum=400.0, beta=4.0)
        k0 = bounds.k_star_closed_form(base)
        assert bounds.k_star_closed_form(make_params(
            eta=0.002, L=5.0, t_sum=400.0, beta=4.0, alpha=2.0)) < k0
        assert bounds.k_star_closed_form(make_params(
            eta=0.002, L=5.0, t_sum=400.0, beta=8.0)) < k0

    def test_corollary4_k_increases_with_eta(self):
        ks = [bounds.k_star_closed_form(make_params(eta=e, L=5.0))
              for e in (0.001, 0.01, 0.05)]
        assert ks[0] < ks[1] < ks[2]

    def test_corollary2_k_increases_with_delta_numeric(self):
        ks = [bounds.k_star_numeric(make_params(delta=d, eta=0.005))
              for d in (0.1, 0.5, 2.0)]
        assert ks[0] <= ks[1] <= ks[2]


class TestLazyBound:
    def test_lazy_bound_weakly_worse(self):
        # Theorem 4: lazy terms only shrink g -> larger bound
        p = make_params()
        for k in (2, 4, 6):
            g0 = bounds.loss_bound(p, k)
            g1 = bounds.loss_bound(p, k, M=4, N=20, theta=0.3, sigma2=0.1)
            assert g1 >= g0

    def test_remark1_plagiarism_dominates_noise(self):
        # M/N term vs sqrt(M)/N term at equal magnitudes
        p = make_params()
        k = 4
        g_theta = bounds.loss_bound(p, k, M=8, N=20, theta=0.2, sigma2=0.0)
        g_sigma = bounds.loss_bound(p, k, M=8, N=20, theta=0.0, sigma2=0.2)
        assert g_theta >= g_sigma

    def test_corollary5_kstar_decreases_with_lazy_and_noise(self):
        p = make_params(eta=0.005)
        k_clean = bounds.k_star_numeric(p)
        k_lazy = bounds.k_star_numeric(p, M=8, N=20, theta=0.5, sigma2=0.0)
        k_noisy = bounds.k_star_numeric(p, M=8, N=20, theta=0.5, sigma2=0.5)
        assert k_lazy <= k_clean
        assert k_noisy <= k_lazy


class TestConvexityGrid:
    """Regression: second differences must never span a vacuous-bound gap."""

    def _patch_curve(self, monkeypatch, curve):
        # is_convex_in_k's grid for the default params is K = 1..9; fake the
        # bound values per K (inf = vacuous bound inside the grid).
        def fake_loss_bound(p, k, **lazy):
            return curve[k - 1]
        monkeypatch.setattr(bounds, "loss_bound", fake_loss_bound)

    def test_gap_in_grid_does_not_fake_nonconvexity(self, monkeypatch):
        # Each contiguous finite window is (vacuously) convex, but the
        # filtered concatenation [1.0, 1.5, 2.5, 2.0] has a negative second
        # difference — the pre-fix code diffed across the gap and returned
        # False here.
        inf = float("inf")
        self._patch_curve(monkeypatch,
                          [1.0, 1.5, inf, 2.5, 2.0, inf, inf, inf, inf])
        assert bounds.is_convex_in_k(make_params())

    def test_nonconvex_within_window_still_detected(self, monkeypatch):
        inf = float("inf")
        self._patch_curve(monkeypatch,
                          [1.0, 3.0, 2.0, 6.0, inf, inf, inf, inf, inf])
        assert not bounds.is_convex_in_k(make_params())

    def test_real_params_still_convex(self):
        assert bounds.is_convex_in_k(make_params())

    def test_finite_runs_helper(self):
        inf = float("inf")
        assert bounds._finite_runs([1.0, inf, 2.0, 3.0, inf]) == \
            [[1.0], [2.0, 3.0]]
        assert bounds._finite_runs([inf, inf]) == []


class TestEstimate:
    def test_estimate_constants_sane(self):
        c = bounds.estimate_constants([2.0, 1.5, 1.2, 1.0, 0.9])
        assert c["L"] > 0 and c["xi"] > 0 and c["delta"] > 0

    def test_grad_norms_are_read(self):
        # Regression: the pre-fix code accepted grad_norms and ignored it.
        curve = [2.0, 1.5, 1.2]
        c_loss = bounds.estimate_constants(curve)
        c_grad = bounds.estimate_constants(curve, grad_norms=[1.0, 0.8, 0.5])
        assert c_grad != c_loss
        # xi is a gradient-norm bound: with observations, it's max |g|
        assert c_grad["xi"] == pytest.approx(1.0)
        # L = max_t |dg_t| * g_t / |dl_t|: max(0.2*1.0/0.5, 0.3*0.8/0.3)
        assert c_grad["L"] == pytest.approx(0.8)
        # delta comes from the loss curve either way
        assert c_grad["delta"] == c_loss["delta"]

    def test_grad_norms_plateau_round_does_not_explode_l(self):
        # a flat loss increment with a nonzero gradient change must not
        # dominate the L max via the near-zero denominator
        c = bounds.estimate_constants([1.0, 1.0, 0.8],
                                      grad_norms=[0.5, 0.3, 0.2])
        assert c["L"] == pytest.approx(0.1 * 0.3 / 0.2)   # the moved round

    def test_grad_norms_degenerate_falls_back(self):
        # one gradient observation can't form a difference -> loss heuristic
        c1 = bounds.estimate_constants([2.0, 1.5, 1.2], grad_norms=[1.0])
        c0 = bounds.estimate_constants([2.0, 1.5, 1.2])
        assert c1 == c0
        # flat loss curve: the increment ratio is guarded, L falls back 2*xi
        c = bounds.estimate_constants([1.0, 1.0, 1.0],
                                      grad_norms=[0.5, 0.5, 0.5])
        assert math.isfinite(c["L"]) and c["L"] > 0
