"""Sharding specs + launch plumbing (1-device where possible; an 8-device
subprocess exercises real multi-device semantics)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, arch_ids, get_arch
from repro.launch import analysis, hlo_analysis, steps
from repro.models import registry
from repro.sharding import plans, specs

from conftest import make_fake_mesh as _fake_mesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.parametrize("arch", list(arch_ids()))
def test_param_pspecs_cover_tree_and_divide(arch):
    cfg = get_arch(arch)
    mesh = _fake_mesh()
    plan = plans.train_plan(cfg, INPUT_SHAPES["train_4k"], mesh, False)
    abs_params = registry.params_specs(cfg, jnp.bfloat16,
                                       n_clients=plan.n_clients)
    pspecs = specs.param_pspecs(cfg, mesh, plan, abs_params)
    flat_p = jax.tree.leaves(abs_params)
    flat_s = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    ext = dict(zip(mesh.axis_names, mesh.axis_sizes)) \
        if hasattr(mesh, "axis_sizes") else dict(mesh.shape)
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= len(leaf.shape)
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in axes:
                n *= ext[a]
            assert dim % n == 0, (arch, spec, leaf.shape)


@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_plans_batch_divisible(shape_name):
    mesh = _fake_mesh()
    shape = INPUT_SHAPES[shape_name]
    for arch in arch_ids():
        cfg = get_arch(arch)
        if shape.kind == "train":
            plan = plans.train_plan(cfg, shape, mesh, False)
            assert shape.global_batch % plan.n_clients == 0
        else:
            plan = plans.serve_plan(cfg, shape, mesh, False)
            assert plan.n_clients == 1


def test_skip_rules():
    hubert = get_arch("hubert-xlarge")
    assert steps.skip_reason(hubert, INPUT_SHAPES["decode_32k"])
    assert steps.skip_reason(hubert, INPUT_SHAPES["long_500k"])
    assert steps.skip_reason(hubert, INPUT_SHAPES["train_4k"]) is None
    qwen = get_arch("qwen3-32b")
    assert steps.skip_reason(qwen, INPUT_SHAPES["long_500k"]) is None
    assert steps.resolve_cfg(qwen, INPUT_SHAPES["long_500k"]).sliding_window > 0


def test_hlo_analysis_counts_loop_trips():
    def f(x):
        def body(c, _):
            return c @ c, None
        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(f).lower(x).compile().as_text()
    d = hlo_analysis.analyze_dict(txt)
    assert d["flops"] == 7 * 2 * 64 ** 3


def test_roofline_terms():
    r = analysis.roofline(197e12, 819e9, 50e9, chips=256)
    assert abs(r["compute_s"] - 1.0) < 1e-6
    assert abs(r["memory_s"] - 1.0) < 1e-6
    assert abs(r["collective_s"] - 1.0) < 1e-6
    assert r["chips"] == 256


def test_model_flops():
    assert analysis.model_flops(10, 100, backward=True) == 6000
    assert analysis.model_flops(10, 100, backward=False) == 2000


@pytest.mark.slow
def test_multidevice_fl_semantics_subprocess():
    """8 host devices: L1 layout — client-sharded round equals the
    single-device reference bit-for-bit (aggregation = all-reduce)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np, json
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core import rounds
        from repro.models.mlp import init_mlp, mlp_loss
        from repro.data.pipeline import FLDataSource

        C = 8
        key = jax.random.key(0)
        src = FLDataSource(key, C, 32)
        params = init_mlp(jax.random.fold_in(key, 1))
        spec = rounds.RoundSpec(n_clients=C, tau=2, eta=0.1,
                                n_lazy=2, sigma2=0.0, mine_attempts=64)
        fn = rounds.make_integrated_round(mlp_loss, spec)
        st = rounds.init_state(params, jax.random.key(2), C)
        batch = src.round_batch(0)

        # reference: single device
        ref_state, ref_m = jax.jit(fn)(st, batch)

        # sharded: client axis over 8 devices
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        cl = NamedSharding(mesh, P("data"))
        rep = NamedSharding(mesh, P())
        st_sh = rounds.RoundState(
            params=jax.tree.map(lambda _: cl, st.params),
            key=rep, round_idx=rep, prev_hash=rep)
        b_sh = jax.tree.map(lambda _: cl, batch)
        m_sh = jax.tree.map(lambda _: rep, ref_m)
        f2 = jax.jit(fn, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, m_sh))
        out_state, out_m = f2(st, batch)
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(jax.tree.leaves(ref_state.params),
                                  jax.tree.leaves(out_state.params)))
        print(json.dumps({"err": err,
                          "loss_ref": float(np.mean(np.asarray(ref_m["local_loss"]))),
                          "loss_sh": float(np.mean(np.asarray(out_m["local_loss"])))}))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-5, res
    assert abs(res["loss_ref"] - res["loss_sh"]) < 1e-5


@pytest.mark.slow
def test_multidevice_decode_step_lowers_subprocess():
    """8 host devices, (data=2, model=4) mesh: build_decode_step's sharding
    specs bind and the step lowers+compiles for a reduced arch."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import ShapeConfig, get_smoke_arch
        from repro.launch import steps
        from repro.sharding.specs import ShardingPlan

        results = {}
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        for arch in ("phi4-mini-3.8b", "jamba-1.5-large-398b",
                     "deepseek-v2-236b"):
            cfg = get_smoke_arch(arch)
            shape = ShapeConfig("t", 64, 4, "decode")
            plan = ShardingPlan(n_clients=1, client_axes=(),
                                batch_axes=("data",), seq_axes=("model",))
            with mesh:
                step, abs_in, _ = steps.build_decode_step(
                    cfg, shape, mesh, False, jnp.float32, plan=plan)
                compiled = step.lower(*abs_in).compile()
            results[arch] = bool(compiled.as_text())
        print(json.dumps(results))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert all(res.values()), res
