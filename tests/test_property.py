"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dep (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import aggregation, allocation, bounds, chain, lazy, mining

SETTINGS = dict(max_examples=30, deadline=None)


# ---------------------------------------------------------------------------
# Resource allocation (eq. 3)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(t_sum=st.floats(10, 1000), k=st.integers(1, 50),
       alpha=st.floats(0.1, 10), beta=st.floats(0.1, 20))
def test_allocation_never_overspends(t_sum, k, alpha, beta):
    tau = allocation.tau_from_budget(t_sum, k, alpha, beta)
    assert tau >= 0
    if tau >= 1:
        assert k * (tau * alpha + beta) <= t_sum + 1e-6


@settings(**SETTINGS)
@given(t_sum=st.floats(20, 500), alpha=st.floats(0.1, 5), beta=st.floats(0.1, 10))
def test_tau_monotone_decreasing_in_k(t_sum, alpha, beta):
    taus = [allocation.tau_from_budget(t_sum, k, alpha, beta)
            for k in range(1, 20)]
    assert all(a >= b for a, b in zip(taus, taus[1:]))


# ---------------------------------------------------------------------------
# Bounds (Theorems 1-4)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(eta=st.floats(0.001, 0.05), L=st.floats(1.0, 15.0),
       delta=st.floats(0.05, 2.0), beta=st.floats(1.0, 20.0))
def test_lazy_bound_dominates_clean(eta, L, delta, beta):
    p = bounds.BoundParams(eta=eta, L=L, xi=1.0, delta=delta, alpha=1.0,
                           beta=beta, t_sum=200.0)
    for k in (1, 3, 5):
        if bounds.gamma(p, k) / k < 1:
            continue
        assert bounds.loss_bound(p, k, M=5, N=20, theta=0.3, sigma2=0.2) >= \
            bounds.loss_bound(p, k)


@settings(**SETTINGS)
@given(eta=st.floats(0.001, 0.02), beta=st.floats(1.0, 15.0))
def test_kstar_closed_form_positive_and_feasible_scale(eta, beta):
    p = bounds.BoundParams(eta=eta, L=8.0, xi=1.0, delta=0.5, alpha=1.0,
                           beta=beta, t_sum=300.0)
    k = bounds.k_star_closed_form(p)
    assert 0 < k < p.t_sum / beta  # mining alone must fit the budget


# ---------------------------------------------------------------------------
# Aggregation
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(c=st.integers(2, 8), n=st.integers(1, 40), seed=st.integers(0, 10_000),
       a=st.floats(-3, 3), b=st.floats(-3, 3))
def test_fedavg_linearity(c, n, seed, a, b):
    x = jax.random.normal(jax.random.key(seed), (c, n))
    lhs = aggregation.fedavg({"w": a * x + b})["w"]
    rhs = a * aggregation.fedavg({"w": x})["w"] + b
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(c=st.integers(2, 8), n=st.integers(1, 40), seed=st.integers(0, 10_000))
def test_fedavg_preserves_mean(c, n, seed):
    x = jax.random.normal(jax.random.key(seed), (c, n))
    out = aggregation.fedavg({"w": x})["w"]
    np.testing.assert_allclose(np.asarray(out.mean(0)),
                               np.asarray(x.mean(0)), atol=1e-5)


# ---------------------------------------------------------------------------
# Lazy clients
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(n=st.integers(2, 32), data=st.data())
def test_plagiarism_sources_always_honest(n, data):
    m = data.draw(st.integers(0, n - 1))
    src = lazy.plagiarism_sources(n, m)
    assert all(src[i] >= m for i in range(m))
    assert all(src[i] == i for i in range(m, n))


@settings(**SETTINGS)
@given(n=st.integers(2, 8), seed=st.integers(0, 1000), data=st.data())
def test_lazy_preserves_honest_clients(n, seed, data):
    m = data.draw(st.integers(1, n - 1))
    x = jax.random.normal(jax.random.key(seed), (n, 12))
    out = lazy.apply_lazy({"w": x}, jax.random.key(seed + 1), n, m, 0.01)["w"]
    np.testing.assert_array_equal(np.asarray(out[m:]), np.asarray(x[m:]))


# ---------------------------------------------------------------------------
# Mining / chain
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(a=st.integers(0, 2**32 - 1), b=st.integers(0, 2**32 - 1))
def test_mix_hash_bit_sensitivity(a, b):
    h1 = int(mining.mix_hash(jnp.uint32(a), jnp.uint32(b), jnp.uint32(0)))
    h2 = int(mining.mix_hash(jnp.uint32(a ^ 1), jnp.uint32(b), jnp.uint32(0)))
    assert h1 != h2


@settings(**SETTINGS)
@given(digests=st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=8))
def test_chain_roundtrip_and_tamper(digests):
    led = chain.Ledger()
    for i, d in enumerate(digests):
        led.append(chain.make_block(i, led.head_hash, d, 0, i, i))
    assert led.validate_chain()
    if len(digests) > 1:
        bad = led.tampered_copy(0, model_digest=digests[0] ^ 0xFFFF)
        assert not bad.validate_chain()


# ---------------------------------------------------------------------------
# Topology mixing (Steps 2+5 generalized)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(c=st.integers(2, 10), seed=st.integers(0, 1000),
       n_leaves=st.integers(1, 3), weighted=st.booleans())
def test_mix_full_mesh_equals_fedavg_on_random_pytrees(c, seed, n_leaves,
                                                       weighted):
    """aggregation.mix with the full-mesh W reproduces fedavg on arbitrary
    random pytrees, with and without |D_i| weights."""
    from repro.core import topology

    key = jax.random.key(seed)
    keys = jax.random.split(key, n_leaves + 1)
    shapes = [(c, 3), (c, 2, 4), (c, 5, 1, 2)]
    p = {f"l{i}": jax.random.normal(keys[i], shapes[i % 3])
         for i in range(n_leaves)}
    w = jnp.abs(jax.random.normal(keys[-1], (c,))) + 0.1 if weighted else None
    got = aggregation.mix(p, topology.FullMesh().matrix(c), weights=w)
    want = aggregation.fedavg(p, weights=w)
    for k in p:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(c=st.sampled_from([2, 4, 6, 8]), shift=st.integers(0, 25),
       seed=st.integers(0, 1000))
def test_shift_halo_rolls_and_dense_mix_agree(c, shift, seed):
    """For ANY static shift s (wrapping included: s >= C) and client count,
    the three PairShift mix forms agree: the sharded block-ppermute halo
    (`mix_shift_halo` under shard_map) is BITWISE the dense roll form
    (`mix_rolls`), and both match the dense matrix mix (`aggregation.mix`
    with PairShift(s).matrix) to float tolerance (matmul reassociates)."""
    import jax.experimental.shard_map as shard_map_lib
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.core import topology

    x = jax.random.normal(jax.random.key(seed), (c, 3, 2))
    p = {"w": x}
    offsets = (0, shift)
    rolls = aggregation.mix_rolls(p, offsets, 0.5)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    halo = jax.jit(shard_map_lib.shard_map(
        lambda q: aggregation.mix_shift_halo(q, offsets, 0.5, "data"),
        mesh=mesh, in_specs=P("data"), out_specs=P("data"),
        check_rep=False))(p)
    np.testing.assert_array_equal(np.asarray(halo["w"]),
                                  np.asarray(rolls["w"]))
    dense = aggregation.mix(p, topology.PairShift(shift=shift).matrix(c))
    np.testing.assert_allclose(np.asarray(rolls["w"]),
                               np.asarray(dense["w"]), atol=1e-6)


@settings(**SETTINGS)
@given(c=st.integers(2, 12), seed=st.integers(0, 1000),
       ring_k=st.integers(1, 4), p_link=st.floats(0.0, 1.0))
def test_shipped_topologies_row_stochastic(c, seed, ring_k, p_link):
    from repro.core import topology

    topos = [topology.FullMesh(), topology.Ring(min(ring_k, max(c // 2, 1))),
             topology.RandomGraph(p_link),
             topology.PartialParticipation(n_active=max(c // 2, 1)),
             topology.PairShift(shift=seed % (c + 2)),
             topology.GossipRotation(step=1 + seed % 3),
             topology.AlternatingSchedule((
                 (topology.Ring(neighbors=1), 1 + seed % 3),
                 (topology.RandomGraph(p_link), 1),
                 (topology.FullMesh(), 1))),
             topology.LinkQualitySchedule(fading_period=1 + seed % 5)]
    for t in topos:
        w = np.asarray(t.matrix(c, key=jax.random.key(seed),
                                round_idx=jnp.int32(seed % 7)))
        assert (w >= 0).all()
        np.testing.assert_allclose(w.sum(axis=1), np.ones(c), atol=1e-5)


# ---------------------------------------------------------------------------
# Robust consensus reducers (aggregation.robust_*)
# ---------------------------------------------------------------------------


def _client_stack(c, p, seed, spread):
    x = jax.random.normal(jax.random.key(seed), (c, p)) * spread
    return {"w": x, "b": jax.random.normal(jax.random.key(seed + 1), (c, 3))}


@settings(**SETTINGS)
@given(c=st.integers(3, 10), p=st.integers(1, 17), seed=st.integers(0, 500),
       spread=st.floats(0.1, 100.0), perm_seed=st.integers(0, 500))
def test_robust_reducers_permutation_invariant(c, p, seed, spread, perm_seed):
    """Order statistics cannot depend on WHO holds each model: permuting
    the client axis leaves the sorting reducers' aggregate BITWISE
    unchanged (sort canonicalizes the order before any arithmetic), and
    the Weiszfeld geometric median unchanged to float tolerance (its
    weighted sums run in client order, so a permutation reassociates
    fp32 — value-invariant, not bit-invariant)."""
    full = _client_stack(c, p, seed, spread)
    perm = np.asarray(jax.random.permutation(
        jax.random.key(perm_seed), c))
    shuffled = jax.tree.map(lambda l: l[perm], full)
    for reduce_full in (aggregation.robust_median,
                        lambda t: aggregation.robust_trimmed(t, (c - 1) // 2)):
        a = reduce_full(full)
        b = reduce_full(shuffled)
        for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(la)[0],
                                          np.asarray(lb)[0])
    a = aggregation.robust_geomedian(full, 8)
    b = aggregation.robust_geomedian(shuffled, 8)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la)[0], np.asarray(lb)[0],
                                   rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(c=st.integers(2, 10), p=st.integers(1, 17), seed=st.integers(0, 500))
def test_robust_reducers_agree_with_mean_on_identical_rows(c, p, seed):
    """Full consensus input (every client broadcasts the same model) is a
    fixed point of every aggregator — robust or linear."""
    row = {"w": jax.random.normal(jax.random.key(seed), (p,)),
           "b": jax.random.normal(jax.random.key(seed + 1), (3,))}
    full = jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (c,) + l.shape), row)
    for reduce_full in (aggregation.robust_median,
                        lambda t: aggregation.robust_trimmed(t, (c - 1) // 2),
                        lambda t: aggregation.robust_geomedian(t, 8)):
        out = reduce_full(full)
        for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(full)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-6, atol=1e-7)


@settings(**SETTINGS)
@given(c=st.integers(2, 12), p=st.integers(1, 33), seed=st.integers(0, 500),
       spread=st.floats(0.1, 1000.0))
def test_trimmed_zero_is_the_mean_to_ulp(c, p, seed, spread):
    """trimmed(0) IS the arithmetic mean up to fp32 reassociation of the
    sorted sum. Two-tier claim, pinned so neither bound silently grows:
    on same-sign data (condition number ~1) the two agree to <= 16 ULP;
    on centered data cancellation makes a relative bound meaningless, and
    the error obeys the classic backward bound
    ``(c-1) * eps * sum_i |x_i| / c`` per coordinate (x2 margin)."""
    from equivalence import tree_max_ulp

    x = jax.random.normal(jax.random.key(seed), (c, p)) * spread

    pos = {"w": x + 4.0 * spread}      # same sign: well-conditioned sum
    trimmed = aggregation.robust_trimmed(pos, 0)
    mean = jax.tree.map(
        lambda l: jnp.broadcast_to(jnp.mean(l.astype(jnp.float32), axis=0),
                                   l.shape), pos)
    assert tree_max_ulp(trimmed, mean) <= 16

    t0 = np.asarray(aggregation.robust_trimmed({"w": x}, 0)["w"][0])
    m0 = np.asarray(jnp.mean(x.astype(jnp.float32), axis=0))
    bound = (c - 1) * np.finfo(np.float32).eps \
        * np.abs(np.asarray(x)).sum(axis=0) / c
    assert (np.abs(t0 - m0) <= 2.0 * bound + 1e-30).all()
