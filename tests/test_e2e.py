"""End-to-end: full BLADE-FL driver, serving driver, arch smoke rounds."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ShapeConfig, get_smoke_arch
from repro.core import allocation, rounds
from repro.data.pipeline import FLDataSource, LMDataSource
from repro.models import registry, transformer
from repro.models.mlp import init_mlp, mlp_loss


def test_blade_fl_full_pipeline_with_eval():
    """Paper pipeline: non-IID data -> K integrated rounds -> eval."""
    key = jax.random.key(0)
    n_clients, k_rounds = 8, 4
    src = FLDataSource(key, n_clients, 128, dirichlet_alpha=0.5)
    params = init_mlp(jax.random.fold_in(key, 1))
    tau = allocation.tau_from_budget(60, k_rounds, 1.0, 5.0)
    spec = rounds.RoundSpec(n_clients=n_clients, tau=tau, eta=0.1,
                            mine_attempts=128, difficulty_bits=2)
    state, hist, ledger = rounds.run_blade_fl(
        mlp_loss, spec, params, src.round_batch, jax.random.fold_in(key, 2),
        k_rounds)
    assert ledger.validate_chain()
    from repro.core.aggregation import aggregate_once
    final = aggregate_once(state.params)
    loss, metrics = mlp_loss(final, src.eval_data)
    assert float(metrics["accuracy"]) > 0.2   # clearly better than chance
    assert hist[-1]["global_loss"] < hist[0]["global_loss"]


@pytest.mark.slow  # full FL rounds over compiled reduced archs, ~70s
@pytest.mark.parametrize("arch", ["xlstm-125m", "deepseek-v2-236b"])
def test_blade_fl_on_reduced_arch(arch):
    """The paper's technique wrapped around an assigned-architecture family."""
    cfg = get_smoke_arch(arch)
    shape = ShapeConfig("t", 32, 4, "train")
    src = LMDataSource(cfg, shape, n_clients=2)
    key = jax.random.key(0)
    params = registry.init_model(key, cfg)
    spec = rounds.RoundSpec(n_clients=2, tau=2, eta=5e-3, n_lazy=1,
                            sigma2=1e-4, mine_attempts=64)

    def loss_fn(p, b):
        return registry.loss_fn(p, cfg, b, remat=False)

    state, hist, ledger = rounds.run_blade_fl(
        loss_fn, spec, params, src.round_batch, jax.random.fold_in(key, 1), 2)
    assert ledger.validate_chain()
    assert all(jnp.isfinite(jnp.asarray(h["global_loss"])) for h in hist)


def test_serve_greedy_generation():
    cfg = get_smoke_arch("minicpm-2b")
    b, prompt, gen = 2, 16, 8
    key = jax.random.key(0)
    params = registry.init_model(key, cfg)
    batch = registry.make_prefill_batch(
        key, cfg, ShapeConfig("t", prompt, b, "prefill"))
    logits, state = transformer.prefill(params, cfg, batch,
                                        max_len=prompt + gen)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks = [tok]
    for i in range(gen - 1):
        logits, state = transformer.decode_step(params, cfg, state, tok,
                                                jnp.int32(prompt + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(tok)
    out = jnp.stack(toks, 1)
    assert out.shape == (b, gen)
    assert jnp.all((out >= 0) & (out < cfg.vocab))


def test_bound_tracks_experiment_ordering():
    """Cheap §7 sanity: for two K values with clearly different bound values,
    the experiment ranks them the same way."""
    key = jax.random.key(42)
    n = 6
    src = FLDataSource(key, n, 96)
    p0 = init_mlp(jax.random.fold_in(key, 1))
    t_sum, alpha, beta, eta = 60.0, 1.0, 5.0, 0.1

    def run_k(k):
        tau = allocation.tau_from_budget(t_sum, k, alpha, beta)
        spec = rounds.RoundSpec(n_clients=n, tau=tau, eta=eta,
                                mine_attempts=32)
        _, hist, _ = rounds.run_blade_fl(mlp_loss, spec, p0, src.round_batch,
                                         jax.random.fold_in(key, 2), k)
        return hist[-1]["global_loss"]

    # K=1 (one aggregation) should beat K at the infeasible edge (tau tiny)
    edge_k = int(t_sum / (alpha + beta))  # tau == 1
    assert run_k(edge_k) > run_k(3) or run_k(1) > run_k(3)
