"""MixLowering dispatch + dense/lowered mixing equivalence (fast lane).

The sharded paths run under shard_map on a 1-device mesh here — that
exercises the collective code (all_gather / ppermute / local-rows slice)
without subprocesses; real >=4-device coverage is the slow
tests/test_multidevice_scan.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import aggregation, topology
from repro.sharding import plans

from conftest import make_fake_mesh


def _params(key, c=8):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (c, 6, 5)),
            "b": jax.random.normal(k2, (c, 5))}


def _one_device_mesh():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


# ---------------------------------------------------------------------------
# Lowering dispatch
# ---------------------------------------------------------------------------


def test_lowering_dispatch_kinds():
    assert topology.FullMesh().lowering(8).kind == topology.ALL_REDUCE
    assert topology.RandomGraph(0.5).lowering(8).kind == topology.GATHER
    assert topology.PartialParticipation(3).lowering(8).kind == topology.GATHER
    low = topology.Ring(neighbors=2).lowering(8)
    assert low.kind == topology.NEIGHBOR_PERMUTE
    assert low.offsets == (-2, -1, 0, 1, 2)
    assert low.weight == pytest.approx(0.2)
    # base Topology defaults to the gather fallback
    assert topology.Topology().lowering(8).kind == topology.GATHER


def test_ring_degenerate_window_falls_back_to_gather():
    # 2k+1 > C: the wrap-around window needs the dedup'd matrix
    assert topology.Ring(neighbors=3).lowering(4).kind == topology.GATHER
    assert topology.Ring(neighbors=2).lowering(5).kind == \
        topology.NEIGHBOR_PERMUTE


def test_schedule_lowering_dispatch():
    # rotation: round-dependent neighbor_permute offsets, one pair per phase
    low = topology.GossipRotation().lowering(8)
    assert low.kind == topology.NEIGHBOR_PERMUTE
    assert low.weight == pytest.approx(0.5)
    assert len(low.offsets_table) == 7
    assert low.offsets_table[0] == (0, 1) and low.offsets_table[6] == (0, 7)
    # pair shift: static neighbor_permute at any shift
    assert topology.PairShift(shift=5).lowering(8).offsets == (0, 5)
    # other schedules: gather fallback (static table / keyed draw)
    alt = topology.AlternatingSchedule(
        ((topology.Ring(neighbors=1), 2), (topology.FullMesh(), 1)))
    assert alt.lowering(8).kind == topology.GATHER
    assert topology.LinkQualitySchedule().lowering(8).kind == topology.GATHER


# ---------------------------------------------------------------------------
# Dense paths
# ---------------------------------------------------------------------------


def test_mix_all_reduce_dense_is_fedavg_bitwise():
    p = _params(jax.random.key(0))
    got = aggregation.mix_all_reduce(p)
    want = aggregation.fedavg(p)
    for k in p:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(want[k]))


def test_mix_rolls_matches_ring_matrix():
    c = 8
    p = _params(jax.random.key(1), c=c)
    for k_n in (1, 2, 3):
        low = topology.Ring(neighbors=k_n).lowering(c)
        got = aggregation.mix_rolls(p, low.offsets, low.weight)
        want = aggregation.mix(p, topology.Ring(neighbors=k_n).matrix(c))
        for key in p:
            # same mix, different fp32 association (roll-sum vs matmul)
            np.testing.assert_allclose(np.asarray(got[key]),
                                       np.asarray(want[key]), atol=1e-5)


def test_mix_rolls_identity_offset_is_noop():
    p = _params(jax.random.key(2), c=4)
    got = aggregation.mix_rolls(p, offsets=(0,), weight=1.0)
    for k in p:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(p[k]))


# ---------------------------------------------------------------------------
# Sharded paths (shard_map, 1-device mesh) == dense paths, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", [
    topology.FullMesh(),
    topology.Ring(neighbors=1),
    topology.Ring(neighbors=2),
    topology.RandomGraph(p_link=0.6),
    topology.PartialParticipation(n_active=3),
], ids=lambda t: type(t).__name__ + str(vars(t) or ""))
def test_sharded_mix_bitwise_equals_dense(topo):
    c = 8
    p = _params(jax.random.key(3), c=c)
    w = topo.matrix(c, key=jax.random.key(7), round_idx=jnp.int32(0))
    low = topo.lowering(c)
    mesh = _one_device_mesh()

    def dense(params):
        if low.kind == topology.ALL_REDUCE:
            return aggregation.mix_all_reduce(params)
        if low.kind == topology.NEIGHBOR_PERMUTE:
            return aggregation.mix_rolls(params, low.offsets, low.weight)
        return aggregation.mix_gather(params, w)

    def sharded(params):
        if low.kind == topology.ALL_REDUCE:
            return aggregation.mix_all_reduce(params, axis_name="data")
        if low.kind == topology.NEIGHBOR_PERMUTE:
            return aggregation.mix_neighbor_halo(params, low.offsets,
                                                 low.weight, "data")
        return aggregation.mix_gather(params, w, axis_name="data", n_shards=1)

    want = jax.jit(dense)(p)
    got = jax.jit(shard_map(sharded, mesh=mesh, in_specs=P("data"),
                            out_specs=P("data"), check_rep=False))(p)
    for k in p:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(want[k]))


@pytest.mark.parametrize("shift", [0, 1, 3, 5, 7, 9])
def test_mix_shift_halo_matches_rolls_bitwise(shift):
    """The arbitrary-shift halo (block ppermutes + static slice) equals the
    dense roll form bit for bit, for shifts beyond one block and wrapping."""
    c = 8
    p = _params(jax.random.key(5), c=c)
    offsets = (0, shift)
    mesh = _one_device_mesh()
    want = jax.jit(lambda q: aggregation.mix_rolls(q, offsets, 0.5))(p)
    got = jax.jit(shard_map(
        lambda q: aggregation.mix_shift_halo(q, offsets, 0.5, "data"),
        mesh=mesh, in_specs=P("data"), out_specs=P("data"),
        check_rep=False))(p)
    for k in p:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(want[k]))


def test_mix_shift_halo_dense_mode_is_rolls():
    p = _params(jax.random.key(6), c=4)
    got = aggregation.mix_shift_halo(p, (0, 2), 0.5, None)
    want = aggregation.mix_rolls(p, (0, 2), 0.5)
    for k in p:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(want[k]))


@pytest.mark.parametrize("sched", [
    topology.GossipRotation(),
    topology.AlternatingSchedule(
        ((topology.Ring(neighbors=1), 2), (topology.FullMesh(), 1))),
    topology.AlternatingSchedule(
        ((topology.RandomGraph(p_link=0.6), 1), (topology.FullMesh(), 1))),
    topology.LinkQualitySchedule(fading_period=3),
], ids=lambda t: type(t).__name__)
def test_sharded_schedule_mix_bitwise_equals_dense(sched):
    """Per-phase: the schedule's sharded mix (switch over permute branches /
    table-indexed gather) equals the dense matrix mix bitwise at every
    round of a period."""
    c = 8
    p = _params(jax.random.key(7), c=c)
    mesh = _one_device_mesh()
    low = sched.lowering(c)
    for t in range(sched.period(c)):
        key = jax.random.key(13)
        w = sched.matrix(c, key=key if sched.stochastic else None,
                         round_idx=jnp.int32(t))
        if low.offsets_table:
            offs = low.offsets_table[t]
            want = aggregation.mix_rolls(p, offs, low.weight)
            sharded = lambda q: aggregation.mix_shift_halo(  # noqa: E731
                q, offs, low.weight, "data")
        else:
            want = aggregation.mix(p, w)
            sharded = lambda q: aggregation.mix_gather(  # noqa: E731
                q, w, axis_name="data", n_shards=1)
        got = jax.jit(shard_map(sharded, mesh=mesh, in_specs=P("data"),
                                out_specs=P("data"), check_rep=False))(p)
        for k in p:
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(want[k]))


def test_client_gather_slice_roundtrip_under_shard_map():
    c = 8
    p = _params(jax.random.key(4), c=c)
    mesh = _one_device_mesh()

    def f(params):
        full = aggregation.client_all_gather(params, "data")
        return aggregation.client_local_rows(full, "data", n_shards=1)

    got = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                            out_specs=P("data"), check_rep=False))(p)
    for k in p:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(p[k]))


# ---------------------------------------------------------------------------
# Scan-carry plan
# ---------------------------------------------------------------------------


def test_scan_carry_plan_validates():
    mesh = _one_device_mesh()
    plan = plans.scan_carry_plan(mesh, 8)
    assert plan.n_shards == 1 and plan.clients_per_shard == 8
    assert plan.client_spec() == P(("data",))
    assert plan.batch_spec(stacked=False) == P(("data",))
    assert plan.batch_spec(stacked=True) == P(None, ("data",))
    with pytest.raises(ValueError):
        plans.scan_carry_plan(mesh, 8, client_axes=("model",))


def test_scan_carry_plan_divisibility():
    # fake 16x16 mesh: extent of ('data',) is 16; C must divide over it
    mesh = make_fake_mesh()
    with pytest.raises(ValueError):
        plans.scan_carry_plan(mesh, 20)          # 20 % 16 != 0
    plan = plans.scan_carry_plan(mesh, 32)
    assert plan.n_shards == 16 and plan.clients_per_shard == 2
    plan2 = plans.scan_carry_plan(mesh, 256, client_axes=("data", "model"))
    assert plan2.n_shards == 256


def test_run_blade_fl_rejects_mesh_with_callable_batches():
    from repro.core import rounds
    from repro.models.mlp import init_mlp, mlp_loss

    key = jax.random.key(0)
    params = init_mlp(key)
    spec = rounds.RoundSpec(n_clients=2, tau=1, eta=0.1, mine_attempts=8)
    with pytest.raises(ValueError, match="static batch"):
        rounds.run_blade_fl(mlp_loss, spec, params, lambda k: {}, key, 1,
                            mesh=_one_device_mesh())
