"""Lazy-client model (§5.1, eq. 7) and DP mechanism (§6)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dp, lazy


def test_sources_map_lazy_to_honest():
    for n, m in [(20, 8), (10, 1), (16, 15), (8, 0)]:
        src = lazy.plagiarism_sources(n, m)
        for i in range(m):
            assert src[i] >= m  # lazy copies an honest client
        for i in range(m, n):
            assert src[i] == i  # honest untouched


def test_apply_lazy_identity_when_no_lazy():
    params = {"w": jnp.arange(12.0).reshape(4, 3)}
    out = lazy.apply_lazy(params, jax.random.key(0), 4, 0, 0.5)
    assert jnp.array_equal(out["w"], params["w"])


def test_apply_lazy_plagiarizes():
    n, m = 6, 2
    params = {"w": jnp.arange(n, dtype=jnp.float32)[:, None] * jnp.ones((1, 5))}
    out = lazy.apply_lazy(params, jax.random.key(0), n, m, 0.0)
    src = lazy.plagiarism_sources(n, m)
    for i in range(m):
        assert jnp.allclose(out["w"][i], params["w"][src[i]])
    for i in range(m, n):
        assert jnp.array_equal(out["w"][i], params["w"][i])


def test_apply_lazy_noise_variance():
    n, m = 4, 2
    sigma2 = 0.25
    params = {"w": jnp.zeros((n, 20_000))}
    out = lazy.apply_lazy(params, jax.random.key(1), n, m, sigma2)
    noise = np.asarray(out["w"][0])
    assert abs(noise.var() - sigma2) < 0.02
    assert np.allclose(np.asarray(out["w"][m:]), 0)


def test_measure_theta():
    a = {"w": jnp.ones((3, 4))}
    b = {"w": jnp.ones((3, 4)) * 2}
    theta = lazy.measure_theta(a, b)
    assert abs(float(theta) - np.sqrt(12.0)) < 1e-5


def test_dp_sigma_calibration_roundtrip():
    s = dp.gaussian_sigma(epsilon=1.0, delta=1e-5, sensitivity=2.0)
    eps = dp.epsilon_of_sigma(s, delta=1e-5, sensitivity=2.0)
    assert abs(eps - 1.0) < 1e-9
    assert dp.gaussian_sigma(2.0, 1e-5) < dp.gaussian_sigma(1.0, 1e-5)


def test_privatize_stats_and_noop():
    params = {"w": jnp.zeros((50_000,))}
    out = dp.privatize(params, jax.random.key(0), 0.1)
    assert abs(float(jnp.std(out["w"])) - 0.1) < 0.01
    same = dp.privatize(params, jax.random.key(0), 0.0)
    assert same is params


# ---------------------------------------------------------------------------
# beyond-paper: lazy-client detection (paper §8 future work)
# ---------------------------------------------------------------------------

def _trained_like_params(key, c, p=2000, spread=1.0):
    """Simulate independently-trained client models (non-IID divergence)."""
    return {"w": jax.random.normal(key, (c, p)) * spread}


def test_detection_flags_plagiarism_pairs():
    from repro.core import detection
    n, m, sigma2 = 10, 3, 0.01
    key = jax.random.key(0)
    params = _trained_like_params(key, n)
    lazied = lazy.apply_lazy(params, jax.random.fold_in(key, 1), n, m, sigma2)
    mask, frac = detection.detect_lazy(lazied)
    met = detection.detection_metrics(mask, m)
    assert met["recall"] == 1.0, (met, np.asarray(frac))
    # sources get flagged too (expected); everyone else must be clean
    src = lazy.plagiarism_sources(n, m)
    allowed = set(range(m)) | set(src[:m].tolist())
    flagged = set(np.flatnonzero(np.asarray(mask)).tolist())
    assert flagged <= allowed, (flagged, allowed)


def test_detection_clean_cohort_no_flags():
    from repro.core import detection
    params = _trained_like_params(jax.random.key(2), 12)
    mask, _ = detection.detect_lazy(params)
    assert int(np.sum(np.asarray(mask))) == 0


def test_detection_threshold_tradeoff_at_large_noise():
    from repro.core import detection
    # sigma^2 = 0.3 (paper's largest): the copy distance rises to ~0.4x the
    # inter-client median — above the conservative 0.2 default (a REAL
    # sensitivity limit: disguise noise comparable to client divergence),
    # but a 0.5 threshold still separates copies from independent models.
    n, m = 10, 2
    key = jax.random.key(3)
    params = _trained_like_params(key, n)
    lazied = lazy.apply_lazy(params, jax.random.fold_in(key, 1), n, m, 0.3)
    mask_strict, _ = detection.detect_lazy(lazied, threshold_frac=0.2)
    assert detection.detection_metrics(mask_strict, m)["recall"] < 1.0
    mask_wide, _ = detection.detect_lazy(lazied, threshold_frac=0.5)
    met = detection.detection_metrics(mask_wide, m)
    assert met["recall"] == 1.0
    # and the wide threshold must not flag a clean cohort
    clean_mask, _ = detection.detect_lazy(params, threshold_frac=0.5)
    assert int(np.sum(np.asarray(clean_mask))) == 0


def test_detection_metrics_vacuous_edges():
    """Regression (the n_lazy == 0 edge): a detector that stays quiet on an
    attack-free cohort used to score precision = recall = 0.0 from the
    guarded denominators — reading as total failure on a perfectly handled
    round. Both empty edges now follow the vacuous-truth convention."""
    from repro.core import detection
    quiet = jnp.zeros(8, bool)
    met = detection.detection_metrics(quiet, 0)
    assert met == {"precision": 1.0, "recall": 1.0, "flagged": 0}
    # nothing flagged but attackers present: precision vacuous, recall 0
    met = detection.detection_metrics(quiet, 3)
    assert met["precision"] == 1.0 and met["recall"] == 0.0
    # flags on a clean cohort: all false positives, recall vacuous
    noisy = jnp.arange(8) < 2
    met = detection.detection_metrics(noisy, 0)
    assert met["precision"] == 0.0 and met["recall"] == 1.0
    assert met["flagged"] == 2


def _attacked_broadcast(atk, key, n=10, m=2):
    """Honest rows = shared base + small trained deltas; first-m rows
    replaced by the attack — the round-level view detect_lazy_round sees
    (params_ref = the shared base every client started from)."""
    from repro.core import attacks
    base = jax.random.normal(key, (2000,))
    deltas = 0.05 * jax.random.normal(jax.random.fold_in(key, 1), (n, 2000))
    params = {"w": base[None] + deltas}
    full = atk.apply(params, jax.random.fold_in(key, 2), n)
    return full, {"w": base}


def test_detection_roc_signflip_vs_alie():
    """Attack-stage ROC, the detectability ordering the attack zoo is built
    around: a single sign-flip broadcast sits ~2||base|| from the reference
    (a huge norm outlier -> recall 1.0), while a single ALIE broadcast
    hides inside the honest variance envelope and fully evades both the
    norm and nearest-neighbour tests (recall 0.0, zero flags). Robust
    aggregation (tests/test_robust_mix.py) is the answer to the second
    kind, detection alone is not."""
    from repro.core import attacks, detection
    n = 10
    key = jax.random.key(7)

    flipped, ref = _attacked_broadcast(
        attacks.SignFlip(n_attackers=1), key, n, 1)
    mask, _ = detection.detect_lazy_round(flipped, ref)
    met_flip = detection.detection_metrics(mask, 1)
    assert met_flip == {"precision": 1.0, "recall": 1.0, "flagged": 1}

    sneaky, ref = _attacked_broadcast(
        attacks.ALIE(n_attackers=1, z=1.0), key, n, 1)
    mask, _ = detection.detect_lazy_round(sneaky, ref)
    met_alie = detection.detection_metrics(mask, 1)
    assert met_alie["recall"] < met_flip["recall"]
    assert met_alie["flagged"] == 0, np.asarray(mask)


def test_detection_catches_colluding_alie_pair_as_plagiarism():
    """TWO ALIE attackers broadcast the IDENTICAL point, so the plagiarism
    nearest-neighbour test catches the collusion even though each broadcast
    individually sits inside the honest envelope — the lazy-client detector
    doubles as a collusion detector for free."""
    from repro.core import attacks, detection
    n, m = 10, 2
    full, ref = _attacked_broadcast(
        attacks.ALIE(n_attackers=m, z=1.0), jax.random.key(7), n, m)
    mask, _ = detection.detect_lazy_round(full, ref)
    met = detection.detection_metrics(mask, m)
    assert met == {"precision": 1.0, "recall": 1.0, "flagged": 2}
    assert int(np.sum(np.asarray(mask)[m:])) == 0
