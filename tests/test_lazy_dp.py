"""Lazy-client model (§5.1, eq. 7) and DP mechanism (§6)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dp, lazy


def test_sources_map_lazy_to_honest():
    for n, m in [(20, 8), (10, 1), (16, 15), (8, 0)]:
        src = lazy.plagiarism_sources(n, m)
        for i in range(m):
            assert src[i] >= m  # lazy copies an honest client
        for i in range(m, n):
            assert src[i] == i  # honest untouched


def test_apply_lazy_identity_when_no_lazy():
    params = {"w": jnp.arange(12.0).reshape(4, 3)}
    out = lazy.apply_lazy(params, jax.random.key(0), 4, 0, 0.5)
    assert jnp.array_equal(out["w"], params["w"])


def test_apply_lazy_plagiarizes():
    n, m = 6, 2
    params = {"w": jnp.arange(n, dtype=jnp.float32)[:, None] * jnp.ones((1, 5))}
    out = lazy.apply_lazy(params, jax.random.key(0), n, m, 0.0)
    src = lazy.plagiarism_sources(n, m)
    for i in range(m):
        assert jnp.allclose(out["w"][i], params["w"][src[i]])
    for i in range(m, n):
        assert jnp.array_equal(out["w"][i], params["w"][i])


def test_apply_lazy_noise_variance():
    n, m = 4, 2
    sigma2 = 0.25
    params = {"w": jnp.zeros((n, 20_000))}
    out = lazy.apply_lazy(params, jax.random.key(1), n, m, sigma2)
    noise = np.asarray(out["w"][0])
    assert abs(noise.var() - sigma2) < 0.02
    assert np.allclose(np.asarray(out["w"][m:]), 0)


def test_measure_theta():
    a = {"w": jnp.ones((3, 4))}
    b = {"w": jnp.ones((3, 4)) * 2}
    theta = lazy.measure_theta(a, b)
    assert abs(float(theta) - np.sqrt(12.0)) < 1e-5


def test_dp_sigma_calibration_roundtrip():
    s = dp.gaussian_sigma(epsilon=1.0, delta=1e-5, sensitivity=2.0)
    eps = dp.epsilon_of_sigma(s, delta=1e-5, sensitivity=2.0)
    assert abs(eps - 1.0) < 1e-9
    assert dp.gaussian_sigma(2.0, 1e-5) < dp.gaussian_sigma(1.0, 1e-5)


def test_privatize_stats_and_noop():
    params = {"w": jnp.zeros((50_000,))}
    out = dp.privatize(params, jax.random.key(0), 0.1)
    assert abs(float(jnp.std(out["w"])) - 0.1) < 0.01
    same = dp.privatize(params, jax.random.key(0), 0.0)
    assert same is params


# ---------------------------------------------------------------------------
# beyond-paper: lazy-client detection (paper §8 future work)
# ---------------------------------------------------------------------------

def _trained_like_params(key, c, p=2000, spread=1.0):
    """Simulate independently-trained client models (non-IID divergence)."""
    return {"w": jax.random.normal(key, (c, p)) * spread}


def test_detection_flags_plagiarism_pairs():
    from repro.core import detection
    n, m, sigma2 = 10, 3, 0.01
    key = jax.random.key(0)
    params = _trained_like_params(key, n)
    lazied = lazy.apply_lazy(params, jax.random.fold_in(key, 1), n, m, sigma2)
    mask, frac = detection.detect_lazy(lazied)
    met = detection.detection_metrics(mask, m)
    assert met["recall"] == 1.0, (met, np.asarray(frac))
    # sources get flagged too (expected); everyone else must be clean
    src = lazy.plagiarism_sources(n, m)
    allowed = set(range(m)) | set(src[:m].tolist())
    flagged = set(np.flatnonzero(np.asarray(mask)).tolist())
    assert flagged <= allowed, (flagged, allowed)


def test_detection_clean_cohort_no_flags():
    from repro.core import detection
    params = _trained_like_params(jax.random.key(2), 12)
    mask, _ = detection.detect_lazy(params)
    assert int(np.sum(np.asarray(mask))) == 0


def test_detection_threshold_tradeoff_at_large_noise():
    from repro.core import detection
    # sigma^2 = 0.3 (paper's largest): the copy distance rises to ~0.4x the
    # inter-client median — above the conservative 0.2 default (a REAL
    # sensitivity limit: disguise noise comparable to client divergence),
    # but a 0.5 threshold still separates copies from independent models.
    n, m = 10, 2
    key = jax.random.key(3)
    params = _trained_like_params(key, n)
    lazied = lazy.apply_lazy(params, jax.random.fold_in(key, 1), n, m, 0.3)
    mask_strict, _ = detection.detect_lazy(lazied, threshold_frac=0.2)
    assert detection.detection_metrics(mask_strict, m)["recall"] < 1.0
    mask_wide, _ = detection.detect_lazy(lazied, threshold_frac=0.5)
    met = detection.detection_metrics(mask_wide, m)
    assert met["recall"] == 1.0
    # and the wide threshold must not flag a clean cohort
    clean_mask, _ = detection.detect_lazy(params, threshold_frac=0.5)
    assert int(np.sum(np.asarray(clean_mask))) == 0
