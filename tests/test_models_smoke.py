"""Deliverable (f): per-architecture REDUCED smoke tests — one forward/train
step on CPU asserting output shapes + no NaNs, for every assigned arch."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SMOKE_SHAPES, arch_ids, get_arch, get_smoke_arch
from repro.models import registry, transformer

ARCHS = list(arch_ids())


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_arch(arch)
    spec = {
        "xlstm-125m": (12, 768, 4, 4, 0, 50_304),
        "qwen3-32b": (64, 5120, 64, 8, 25_600, 151_936),
        "nemotron-4-15b": (32, 6144, 48, 8, 24_576, 256_000),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24_576, 65_536),
        "paligemma-3b": (18, 2048, 8, 1, 16_384, 257_216),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200_064),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163_840),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122_753),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102_400),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == spec


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_is_reduced(arch):
    cfg = get_smoke_arch(arch)
    assert cfg.n_layers == 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4


@pytest.mark.slow  # value_and_grad compile per arch, ~10-25s each
@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, key):
    """One forward + one GD step: finite loss, grads and updated params."""
    cfg = get_smoke_arch(arch)
    shape = SMOKE_SHAPES["smoke_train"]
    params = registry.init_model(key, cfg)
    batch = registry.make_train_batch(jax.random.fold_in(key, 1), cfg, shape)

    def loss_fn(p):
        return registry.loss_fn(p, cfg, batch, remat=False)

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0
    gleaves = jax.tree.leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in gleaves), arch
    new = jax.tree.map(lambda w, g: w - 1e-2 * g, params, grads)
    loss2, _ = registry.loss_fn(new, cfg, batch, remat=False)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes(arch, key):
    cfg = get_smoke_arch(arch)
    shape = SMOKE_SHAPES["smoke_prefill"]
    params = registry.init_model(key, cfg)
    batch = registry.make_prefill_batch(jax.random.fold_in(key, 2), cfg, shape)
    x, _, _ = transformer._embed_inputs(params, cfg, batch)
    h, aux, _ = transformer.forward(params, cfg, x, remat=False)
    assert h.shape[0] == shape.global_batch
    assert h.shape[-1] == cfg.d_model
    assert jnp.all(jnp.isfinite(h)), arch
    logits = transformer._lm_head(params, cfg, h[:, -1])
    assert logits.shape == (shape.global_batch, cfg.vocab)


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_arch(a).has_decode])
def test_smoke_decode_step(arch, key):
    cfg = get_smoke_arch(arch)
    b, max_len = 2, 32
    params = registry.init_model(key, cfg)
    state = transformer.init_decode_state(cfg, b, max_len)
    tok = jnp.zeros((b,), jnp.int32)
    logits, state2 = transformer.decode_step(params, cfg, state, tok,
                                             jnp.int32(0))
    assert logits.shape == (b, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits)), arch
    # state must be structurally identical (loopable)
    assert jax.tree.structure(state) == jax.tree.structure(state2)
    for a, b2 in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
        assert a.shape == b2.shape


def test_encoder_skips_decode():
    cfg = get_arch("hubert-xlarge")
    assert not cfg.has_decode


@pytest.mark.parametrize("arch", ["xlstm-125m", "jamba-1.5-large-398b"])
def test_subquadratic_flags(arch):
    assert get_arch(arch).subquadratic


def test_dense_not_subquadratic_until_windowed():
    cfg = get_arch("qwen3-32b")
    assert not cfg.subquadratic
    assert dataclasses.replace(cfg, sliding_window=4096).subquadratic
