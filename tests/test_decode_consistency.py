"""Decode-path correctness: incremental decode == full forward, prefill
continuation, sliding-window ring buffer."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ShapeConfig, arch_ids, get_smoke_arch
from repro.models import registry, transformer

# every test here compiles a per-arch decode/prefill pair (6-25s each)
pytestmark = pytest.mark.slow

DECODE_ARCHS = [a for a in arch_ids()
                if get_smoke_arch(a).has_decode and
                get_smoke_arch(a).family != "vlm"]


def _uncapped(cfg):
    if cfg.moe is not None:
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = _uncapped(get_smoke_arch(arch))
    s, b = 16, 2
    key = jax.random.key(1)
    params = registry.init_model(key, cfg)
    batch = registry.make_prefill_batch(key, cfg, ShapeConfig("t", s, b, "prefill"))
    x, _, _ = transformer._embed_inputs(params, cfg, batch)
    h, _, _ = transformer.forward(params, cfg, x, remat=False)
    full = transformer._lm_head(params, cfg, h)
    state = transformer.init_decode_state(cfg, b, s)
    toks = batch["tokens"]
    errs = []
    for t in range(s):
        logits, state = transformer.decode_step(params, cfg, state,
                                                toks[:, t], jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(logits - full[:, t, :]))))
    assert max(errs) < 2e-4, (arch, max(errs))


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "jamba-1.5-large-398b",
                                  "xlstm-125m", "deepseek-v2-236b"])
def test_prefill_then_decode_continues(arch):
    """prefill(s0) + decode steps == full forward over the whole sequence."""
    cfg = _uncapped(get_smoke_arch(arch))
    s0, s1, b = 8, 4, 2
    key = jax.random.key(2)
    params = registry.init_model(key, cfg)
    full_batch = registry.make_prefill_batch(
        key, cfg, ShapeConfig("t", s0 + s1, b, "prefill"))
    toks = full_batch["tokens"]
    x, _, _ = transformer._embed_inputs(params, cfg, {"tokens": toks})
    h, _, _ = transformer.forward(params, cfg, x, remat=False)
    full = transformer._lm_head(params, cfg, h)

    logits, state = transformer.prefill(params, cfg,
                                        {"tokens": toks[:, :s0]},
                                        max_len=s0 + s1)
    assert float(jnp.max(jnp.abs(logits - full[:, s0 - 1]))) < 2e-4
    for t in range(s0, s0 + s1):
        logits, state = transformer.decode_step(params, cfg, state,
                                                toks[:, t], jnp.int32(t))
        err = float(jnp.max(jnp.abs(logits - full[:, t])))
        assert err < 2e-4, (arch, t, err)


def test_sliding_window_decode_matches_windowed_forward():
    cfg = get_smoke_arch("phi4-mini-3.8b")
    cfg = dataclasses.replace(cfg, sliding_window=8)
    s, b = 24, 2
    key = jax.random.key(3)
    params = registry.init_model(key, cfg)
    batch = registry.make_prefill_batch(key, cfg, ShapeConfig("t", s, b, "prefill"))
    toks = batch["tokens"]
    x, _, _ = transformer._embed_inputs(params, cfg, batch)
    h, _, _ = transformer.forward(params, cfg, x, remat=False)
    full = transformer._lm_head(params, cfg, h)
    # ring-buffer cache has capacity == window only
    state = transformer.init_decode_state(cfg, b, s)
    k_leaf = state["period"]["j0"]["k"]
    assert k_leaf.shape[2] == 8  # [n_per, B, W, Hkv, hd]
    errs = []
    for t in range(s):
        logits, state = transformer.decode_step(params, cfg, state,
                                                toks[:, t], jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(logits - full[:, t]))))
    assert max(errs) < 2e-4, max(errs)


def test_sliding_window_prefill_ring_layout():
    """prefill with S > window produces a ring cache decode can continue."""
    cfg = dataclasses.replace(get_smoke_arch("phi4-mini-3.8b"), sliding_window=8)
    s0, s1, b = 12, 4, 1
    key = jax.random.key(4)
    params = registry.init_model(key, cfg)
    toks = registry.make_prefill_batch(
        key, cfg, ShapeConfig("t", s0 + s1, b, "prefill"))["tokens"]
    x, _, _ = transformer._embed_inputs(params, cfg, {"tokens": toks})
    h, _, _ = transformer.forward(params, cfg, x, remat=False)
    full = transformer._lm_head(params, cfg, h)
    logits, state = transformer.prefill(params, cfg, {"tokens": toks[:, :s0]},
                                        max_len=s0 + s1)
    assert float(jnp.max(jnp.abs(logits - full[:, s0 - 1]))) < 2e-4
    for t in range(s0, s0 + s1):
        logits, state = transformer.decode_step(params, cfg, state,
                                                toks[:, t], jnp.int32(t))
        assert float(jnp.max(jnp.abs(logits - full[:, t]))) < 2e-4, t


def test_vlm_prefill_decode_runs():
    cfg = get_smoke_arch("paligemma-3b")
    b, s = 2, 32
    key = jax.random.key(5)
    params = registry.init_model(key, cfg)
    batch = registry.make_prefill_batch(key, cfg, ShapeConfig("t", s, b, "prefill"))
    logits, state = transformer.prefill(params, cfg, batch, max_len=s + 4)
    assert jnp.all(jnp.isfinite(logits))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for t in range(s, s + 4):
        logits, state = transformer.decode_step(params, cfg, state, tok,
                                                jnp.int32(t))
        assert jnp.all(jnp.isfinite(logits))
