"""Data pipeline, optimizers, checkpointing, metrics."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chain
from repro.data import synthetic
from repro.data.pipeline import FLDataSource, LMDataSource
from repro.configs import ShapeConfig, get_smoke_arch
from repro.training import checkpoint, metrics, optim, train_state
from repro.models.mlp import init_mlp, mlp_loss


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_mnist_proxy_shapes_and_range():
    d = synthetic.mnist_proxy(jax.random.key(0), 256)
    assert d["x"].shape == (256, 784)
    assert d["y"].shape == (256,)
    assert float(d["x"].min()) >= 0 and float(d["x"].max()) <= 1
    assert int(d["y"].min()) >= 0 and int(d["y"].max()) <= 9


def test_dirichlet_partition_noniid():
    y = np.repeat(np.arange(10), 200)
    part_iid = synthetic.dirichlet_partition(y, 8, alpha=100.0,
                                             samples_per_client=100, seed=0)
    part_skew = synthetic.dirichlet_partition(y, 8, alpha=0.1,
                                              samples_per_client=100, seed=0)

    def label_entropy(part):
        ents = []
        for i in range(part.shape[0]):
            counts = np.bincount(y[part[i]], minlength=10) / part.shape[1]
            nz = counts[counts > 0]
            ents.append(-(nz * np.log(nz)).sum())
        return np.mean(ents)

    assert label_entropy(part_skew) < label_entropy(part_iid) - 0.3


def test_fl_source_eval_same_distribution():
    src = FLDataSource(jax.random.key(0), 4, 64)
    # train a client's data and eval data come from the same templates:
    # a nearest-template classifier fit on train should beat chance on eval
    xs = np.asarray(src.data["x"]); ys = np.asarray(src.data["y"])
    cent = np.stack([xs[ys == c].mean(0) for c in range(10)])
    ev_x = np.asarray(src.eval_data["x"]); ev_y = np.asarray(src.eval_data["y"])
    pred = np.argmin(((ev_x[:, None] - cent[None]) ** 2).sum(-1), axis=1)
    assert (pred == ev_y).mean() > 0.3


def test_lm_stream_deterministic():
    a = synthetic.lm_token_stream(jax.random.key(3), 2, 32, 100)
    b = synthetic.lm_token_stream(jax.random.key(3), 2, 32, 100)
    assert jnp.array_equal(a, b)
    assert int(a.max()) < 100


def test_lm_datasource_shapes():
    cfg = get_smoke_arch("paligemma-3b")
    shape = ShapeConfig("t", 64, 8, "train")
    src = LMDataSource(cfg, shape, n_clients=4)
    b = src.round_batch(0)
    assert b["patches"].shape == (4, 2, cfg.vlm_prefix_len, cfg.d_model)
    assert b["tokens"].shape == (4, 2, 64 - cfg.vlm_prefix_len)


# ---------------------------------------------------------------------------
# optim
# ---------------------------------------------------------------------------


def _quadratic_converges(opt, steps=200):
    target = jnp.array([3.0, -2.0])
    params = {"w": jnp.zeros(2)}
    state = opt.init(params)
    for i in range(steps):
        grads = {"w": 2 * (params["w"] - target)}
        params, state = opt.update(grads, state, params, jnp.int32(i))
    return float(jnp.max(jnp.abs(params["w"] - target)))


def test_sgd_converges():
    assert _quadratic_converges(optim.sgd(0.1)) < 1e-3


def test_sgd_momentum_converges():
    assert _quadratic_converges(optim.sgd(0.05, momentum=0.9)) < 1e-3


def test_adamw_converges():
    assert _quadratic_converges(optim.adamw(0.1)) < 1e-2


def test_wsd_schedule_phases():
    lr = optim.wsd_schedule(1.0, warmup_steps=10, stable_steps=50,
                            decay_steps=20)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert abs(float(lr(40)) - 1.0) < 1e-6
    assert float(lr(75)) < 1.0
    assert float(lr(200)) >= 0.1 - 1e-6  # floor


def test_train_step_decreases_loss():
    key = jax.random.key(0)
    data = synthetic.mnist_proxy(key, 256)
    params = init_mlp(jax.random.fold_in(key, 1))
    step = train_state.make_train_step(mlp_loss, optim.adamw(1e-2))
    st = train_state.create(params, optim.adamw(1e-2))
    batch = {"x": data["x"], "y": data["y"]}
    losses = []
    for _ in range(20):
        st, m = step(st, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_microbatched_train_step_matches():
    key = jax.random.key(1)
    data = synthetic.mnist_proxy(key, 64)
    batch = {"x": data["x"], "y": data["y"]}
    params = init_mlp(jax.random.fold_in(key, 1))
    opt = optim.sgd(0.1)
    s1 = train_state.make_train_step(mlp_loss, opt, microbatches=1)
    s4 = train_state.make_train_step(mlp_loss, opt, microbatches=4)
    st1, _ = s1(train_state.create(params, opt), batch)
    st4, _ = s4(train_state.create(params, opt), batch)
    for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st4.params)):
        assert jnp.allclose(a, b, atol=1e-5)


# ---------------------------------------------------------------------------
# checkpoint / metrics
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    key = jax.random.key(0)
    tree = {"a": jax.random.normal(key, (4, 3)),
            "nested": {"b": jnp.arange(5)},
            "lst": [jnp.ones(2), jnp.zeros(3)]}
    led = chain.Ledger()
    led.append(chain.make_block(0, led.head_hash, 1, 2, 3, 4))
    checkpoint.save(str(tmp_path), tree, step=7, ledger=led)
    got, step, led2 = checkpoint.restore(str(tmp_path), tree)
    assert step == 7
    assert led2.validate_chain() and len(led2.blocks) == 1
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        assert jnp.array_equal(a, b)


def test_metric_logger(tmp_path):
    log = metrics.MetricLogger(str(tmp_path), "t")
    log.log(0, loss=2.0)
    log.log(1, loss=1.0)
    assert log.series("loss") == [2.0, 1.0]
    assert log.best("loss")["step"] == 1
    assert os.path.exists(tmp_path / "t.jsonl")
