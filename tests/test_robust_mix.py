"""Byzantine-robust aggregation (aggregation.robust_* + RoundSpec.robust_agg).

Reducer side: coordinate-wise median / trimmed mean and the Weiszfeld
geometric median against independent numpy references, rank-1 broadcast
shape, and outlier immunity.

Resolver side: ``robust_agg`` routes through ``topology.resolve_mix_plan``
as first-class EXEC modes (RL205 discipline — the executor switches only on
``plan.mode``), conflicts with the linear fast paths are rejected once and
identically by report and trace, and ``dispatch_plan`` reports the robust
tier.

Engine side (the test-matrix centerpiece, with tests/test_attacks.py): the
full attack x aggregator grid — every shipped attack under every robust mix
— agrees scan-vs-loop bitwise on this host; the mesh-lowered runs live in
the TOLERANCE tier (all-gather + replicated order statistics, rtol=1e-5 on
4 fake devices). The breakdown-point test pins the theory the family
exists for: f = ⌊(C-1)/2⌋ colluding sign-flippers at 1e6 scale leave every
robust aggregate inside the honest envelope while the linear mean is
dragged 5 orders of magnitude away.
"""
import itertools
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from equivalence import assert_trees_close
from repro.core import aggregation, attacks, rounds, topology
from repro.data.pipeline import FLDataSource
from repro.models.mlp import init_mlp, mlp_loss
from test_attacks import ATTACKS

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

C = 8

# The defense axis of the grid (None = the linear-mean baseline).
ROBUST = [None, "median", "trimmed:2", "geomed:4"]

needs4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs 4 devices (CI multidevice lane: "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _full(key, c=C, p=19):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (c, 3, p), jnp.float32),
            "b": jax.random.normal(k2, (c, p), jnp.float32)}


# ---------------------------------------------------------------------------
# Reducers vs numpy references
# ---------------------------------------------------------------------------


def test_median_matches_numpy():
    full = _full(jax.random.key(0))
    out = aggregation.robust_median(full)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(full)):
        want = np.median(np.asarray(b), axis=0)
        for row in np.asarray(a):                  # rank-1: every row = agg
            np.testing.assert_allclose(row, want, rtol=1e-6)


def test_trimmed_matches_numpy():
    full = _full(jax.random.key(1))
    for t in (0, 1, 2, 3):
        out = aggregation.robust_trimmed(full, t)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(full)):
            kept = np.sort(np.asarray(b), axis=0)[t:C - t]
            want = kept.sum(axis=0) / (C - 2 * t)
            # numpy's pairwise fp32 sum associates differently than XLA's
            np.testing.assert_allclose(np.asarray(a)[0], want,
                                       rtol=1e-5, atol=1e-6)


def test_trimmed_rejects_degenerate_trim():
    full = _full(jax.random.key(2))
    for t in (-1, C // 2, C):
        with pytest.raises(ValueError):
            aggregation.robust_trimmed(full, t)


def test_geomedian_matches_numpy_weiszfeld():
    """Same fixed-iteration Weiszfeld recurrence in numpy, same eps floor —
    the fori_loop lowering reproduces it to float tolerance."""
    full = _full(jax.random.key(3))
    iters, eps = 6, 1e-6
    out = aggregation.robust_geomedian(full, iters, eps=eps)

    flat = np.concatenate([np.asarray(l).reshape(C, -1)
                           for l in jax.tree.leaves(full)], axis=1)
    y = flat.mean(axis=0)
    for _ in range(iters):
        d = np.sqrt(((flat - y[None]) ** 2).sum(axis=1))
        w = 1.0 / np.maximum(d, eps)
        w = w / w.sum()
        y = w @ flat
    got = np.concatenate([np.asarray(l)[0].ravel()
                          for l in jax.tree.leaves(out)])
    np.testing.assert_allclose(got, y, rtol=1e-5, atol=1e-6)


def test_geomedian_finds_the_center_of_symmetric_points():
    """Four models at the corners of a square -> geometric median at the
    center (the analytic optimum, not just the Weiszfeld fixed point)."""
    pts = jnp.array([[1.0, 0.0], [-1.0, 0.0], [0.0, 1.0], [0.0, -1.0]])
    out = aggregation.robust_geomedian({"w": pts}, n_iters=32)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.0, atol=1e-5)


@pytest.mark.parametrize("reduce_full", [
    aggregation.robust_median,
    lambda t: aggregation.robust_trimmed(t, 1),
], ids=["median", "trimmed1"])
def test_coordinatewise_reducers_ignore_one_outlier(reduce_full):
    """One arbitrarily corrupted row cannot move a per-coordinate order
    statistic outside the honest per-coordinate range."""
    full = _full(jax.random.key(4))
    spiked = jax.tree.map(lambda l: l.at[0].set(jnp.float32(1e8)), full)
    out = reduce_full(spiked)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(full)):
        honest = np.asarray(b)[1:]
        agg = np.asarray(a)[0]
        assert (agg >= honest.min(axis=0) - 1e-6).all()
        assert (agg <= honest.max(axis=0) + 1e-6).all()


# ---------------------------------------------------------------------------
# Resolver routing (single decision surface)
# ---------------------------------------------------------------------------


def _spec(robust=None, topo=None, **kw):
    kw.setdefault("mine_attempts", 8)
    return rounds.RoundSpec(n_clients=C, tau=1, eta=0.1, difficulty_bits=1,
                            topology=topo or topology.Ring(neighbors=1),
                            robust_agg=robust, **kw)


def test_parse_robust_grammar():
    assert topology.parse_robust("median", C) == (topology.EXEC_MEDIAN, 0, 0)
    assert topology.parse_robust("trimmed", C) == \
        (topology.EXEC_TRIMMED, 1, 0)
    assert topology.parse_robust("trimmed:3", C) == \
        (topology.EXEC_TRIMMED, 3, 0)
    assert topology.parse_robust("geomed", C) == \
        (topology.EXEC_GEOMED, 0, topology.GEOMED_DEFAULT_ITERS)
    assert topology.parse_robust("geomed:4", C) == (topology.EXEC_GEOMED, 0, 4)
    with pytest.raises(ValueError):
        topology.parse_robust("trimmed:4", C)      # 2t = C
    with pytest.raises(ValueError):
        topology.parse_robust("geomed:0", C)
    with pytest.raises(ValueError):
        topology.parse_robust("krum", C)


@pytest.mark.parametrize("robust, mode", [
    ("median", topology.EXEC_MEDIAN),
    ("trimmed:2", topology.EXEC_TRIMMED),
    ("geomed:4", topology.EXEC_GEOMED),
], ids=["median", "trimmed", "geomed"])
def test_resolver_routes_robust_over_any_topology(robust, mode):
    """robust_agg preempts the linear ladder for every topology shape —
    the MixPlan is the rank-1 robust override, kind ROBUST, mix tier
    'robust'."""
    for topo in (topology.FullMesh(), topology.Ring(neighbors=1),
                 topology.ClusterTopology(n_clusters=2)):
        plan = topology.resolve_mix_plan(_spec(robust, topo))
        assert plan.mode == mode
        assert plan.kind == topology.ROBUST
        assert plan.mix == "robust"
    plan = topology.resolve_mix_plan(_spec(robust))
    assert (plan.trim, plan.robust_iters) == \
        {"median": (0, 0), "trimmed:2": (2, 0), "geomed:4": (0, 4)}[robust]


def test_robust_agg_mean_falls_through_to_linear():
    """'mean' is the explicit linear baseline: identical routing decision
    to robust_agg=None (the plan holds array payloads, so compare the
    decision fields, not the dataclass)."""
    base = topology.resolve_mix_plan(_spec(None))
    mean = topology.resolve_mix_plan(_spec("mean"))
    assert (mean.mode, mean.kind, mean.mix) == \
        (base.mode, base.kind, base.mix)
    assert base.kind != topology.ROBUST


def test_resolver_rejects_linear_fast_path_conflicts():
    """The psum/fused/sparse/data-weight fast tiers are linear-mix
    machinery; combining them with a robust override fails ONCE in the
    resolver — and make_communicate fails identically (report == trace
    even for the error path)."""
    conflicts = [dict(fast_allreduce=True), dict(fused_mix=True),
                 dict(sparse_mix=True),
                 dict(data_weights=tuple(float(i + 1) for i in range(C)))]
    for kw in conflicts:
        bad = _spec("median", **kw)
        with pytest.raises(ValueError):
            topology.resolve_mix_plan(bad)
        with pytest.raises(ValueError):
            rounds.make_communicate(bad)


def test_dispatch_reports_robust_tier():
    batch = {"x": jnp.zeros((C, 4, 3)), "y": jnp.zeros((C, 4), jnp.int32)}
    plan = rounds.dispatch_plan(_spec("geomed"), batch, 3)
    assert plan["mix"] == "robust"
    assert plan["mix_mode"] == topology.EXEC_GEOMED
    assert plan["mix_mode"] == rounds.make_communicate(_spec("geomed")).plan.mode


# ---------------------------------------------------------------------------
# Attack x aggregator grid (scan vs loop, bitwise on one host)
# ---------------------------------------------------------------------------


def _run_pair(robust, atk, k_rounds=2, seed=53):
    key = jax.random.key(seed)
    src = FLDataSource(key, C, samples_per_client=16, seed=seed)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = _spec(robust, attack=atk, mine_attempts=16)
    run_key = jax.random.fold_in(key, 2)
    loop = rounds.run_blade_fl(
        mlp_loss, spec, params, src.round_batch, run_key, k_rounds)
    scan = rounds.run_blade_fl_scan(
        mlp_loss, spec, params, src.static_batch(), run_key, k_rounds)
    return loop, scan


@pytest.mark.parametrize("atk", ATTACKS,
                         ids=lambda a: type(a).__name__)
@pytest.mark.parametrize("robust", ROBUST,
                         ids=["mean", "median", "trimmed", "geomed"])
def test_grid_scan_matches_loop(robust, atk):
    """Every cell of the attack x aggregator matrix: compiled scan ==
    Python loop bitwise (params, history, hash links) — the robust
    executors and the attack stage both compile into the scan."""
    (st_py, hist_py, led_py), (st_sc, hist_sc, led_sc) = \
        _run_pair(robust, atk)
    for a, b in zip(jax.tree.leaves(st_py.params),
                    jax.tree.leaves(st_sc.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert hist_py == hist_sc
    assert led_sc.validate_chain()
    assert [b.header_hash for b in led_py.blocks] == \
        [b.header_hash for b in led_sc.blocks]


def test_robust_consensus_is_rank1():
    """Under a robust override every client adopts the same aggregate
    (rank-1, like FullMesh) regardless of the configured ring."""
    (st, _, _), _ = _run_pair("median", None)
    for leaf in jax.tree.leaves(st.params):
        rows = np.asarray(leaf)
        for i in range(1, rows.shape[0]):
            np.testing.assert_array_equal(rows[i], rows[0])


# ---------------------------------------------------------------------------
# Breakdown points
# ---------------------------------------------------------------------------


def test_breakdown_point_reducer_level():
    """f = ⌊(C-1)/2⌋ = 3 colluding sign-flippers at 1e6 scale: median,
    trimmed(3) and the geometric median stay inside the honest envelope;
    the linear mean is dragged ~5 orders of magnitude out. Exactly the
    breakdown-point table in docs/architecture.md."""
    f = (C - 1) // 2
    full = _full(jax.random.key(5))
    attacked = attacks.SignFlip(n_attackers=f, scale=1e6).apply(
        full, jax.random.key(0), C)

    honest_scale = max(float(jnp.max(jnp.abs(l[f:])))
                       for l in jax.tree.leaves(full))
    for reduce_full in (aggregation.robust_median,
                        lambda t: aggregation.robust_trimmed(t, f),
                        lambda t: aggregation.robust_geomedian(t, 16)):
        out = reduce_full(attacked)
        worst = max(float(jnp.max(jnp.abs(l))) for l in jax.tree.leaves(out))
        assert worst <= 2.0 * honest_scale, worst

    mean = jax.tree.map(lambda l: jnp.mean(l, axis=0), attacked)
    mean_scale = max(float(jnp.max(jnp.abs(l))) for l in jax.tree.leaves(mean))
    assert mean_scale > 1e4 * honest_scale


def test_breakdown_point_engine_level():
    """Same story end-to-end: 3/8 sign-flipping clients at 1e4 scale. The
    median-aggregated run keeps finite, honest-sized params; the linear
    ring is blown up by the attack within two rounds."""
    atk = attacks.SignFlip(n_attackers=3, scale=1e4)
    (st_rob, hist_rob, _), _ = _run_pair("median", atk, seed=61)
    (st_lin, _, _), _ = _run_pair(None, atk, seed=61)
    rob_norm = max(float(jnp.max(jnp.abs(l)))
                   for l in jax.tree.leaves(st_rob.params))
    lin_norm = max(float(jnp.max(jnp.abs(l)))
                   for l in jax.tree.leaves(st_lin.params))
    assert rob_norm < 1e2, rob_norm
    assert lin_norm > 1e3 * rob_norm, (lin_norm, rob_norm)
    assert np.isfinite(hist_rob[-1]["global_loss"])


# ---------------------------------------------------------------------------
# Mesh lowering (tolerance tier)
# ---------------------------------------------------------------------------


def test_sharded_robust_single_device_mesh():
    """The shard_map lowering (gather + replicated reducer + local rows) on
    a 1-device mesh — cheap coverage of the mesh code path everywhere."""
    from jax.sharding import Mesh
    key = jax.random.key(67)
    src = FLDataSource(key, C, samples_per_client=16, seed=67)
    params = init_mlp(jax.random.fold_in(key, 1))
    batch = src.static_batch()
    run_key = jax.random.fold_in(key, 2)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    for robust in ("median", "trimmed:2", "geomed:4"):
        spec = _spec(robust, attack=attacks.ALIE(n_attackers=2, z=1.2),
                     mine_attempts=16)
        st, hist, _ = rounds.run_blade_fl_scan(
            mlp_loss, spec, params, batch, run_key, 2)
        st_m, hist_m, _ = rounds.run_blade_fl_scan(
            mlp_loss, spec, params, batch, run_key, 2, mesh=mesh)
        assert_trees_close(st_m.params, st.params, rtol=1e-5)
        assert hist == hist_m


@needs4
@pytest.mark.tolerance
def test_sharded_robust_four_devices_tolerance():
    """The acceptance bar: every robust mix under attack on a real 4-way
    client-sharded mesh agrees with the single-device scan to rtol=1e-5
    (tolerance tier — robust reductions are not psum-associative, so no
    bitwise claim; hash forks are allowed and not asserted)."""
    from jax.sharding import Mesh
    key = jax.random.key(71)
    src = FLDataSource(key, C, samples_per_client=16, seed=71)
    params = init_mlp(jax.random.fold_in(key, 1))
    batch = src.static_batch()
    run_key = jax.random.fold_in(key, 2)
    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    for robust, atk in itertools.product(
            ("median", "trimmed:2", "geomed:4"),
            (None, attacks.ALIE(n_attackers=2, z=1.2),
             attacks.SignFlip(n_attackers=2, scale=2.0))):
        spec = _spec(robust, attack=atk, mine_attempts=16)
        st, _, _ = rounds.run_blade_fl_scan(
            mlp_loss, spec, params, batch, run_key, 2)
        st_m, _, led_m = rounds.run_blade_fl_scan(
            mlp_loss, spec, params, batch, run_key, 2, mesh=mesh)
        assert_trees_close(st_m.params, st.params, rtol=1e-5)
        assert led_m.validate_chain()


@pytest.mark.slow
def test_sharded_robust_grid_subprocess():
    """4 fake host devices via subprocess: the full robust x attack grid,
    mesh-lowered vs single-device, within the tolerance tier's rtol=1e-5
    on every param leaf."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import itertools, json
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.core import attacks, rounds, topology
        from repro.data.pipeline import FLDataSource
        from repro.models.mlp import init_mlp, mlp_loss

        C = 8
        key = jax.random.key(73)
        src = FLDataSource(key, C, samples_per_client=16, seed=73)
        params = init_mlp(jax.random.fold_in(key, 1))
        batch = src.static_batch()
        run_key = jax.random.fold_in(key, 2)
        mesh = Mesh(np.array(jax.devices()), ("data",))

        ATTACKS = [None,
                   attacks.SignFlip(n_attackers=2, scale=2.0),
                   attacks.ScaledNoise(n_attackers=2, sigma2=0.5),
                   attacks.ALIE(n_attackers=2, z=1.2),
                   attacks.ModelReplacement(n_attackers=1)]
        out = {}
        for robust, atk in itertools.product(
                ("median", "trimmed:2", "geomed:4"), ATTACKS):
            spec = rounds.RoundSpec(
                n_clients=C, tau=1, eta=0.1, mine_attempts=16,
                difficulty_bits=1, topology=topology.Ring(neighbors=1),
                robust_agg=robust, attack=atk)
            st, _, _ = rounds.run_blade_fl_scan(
                mlp_loss, spec, params, batch, run_key, 2)
            st_m, _, led_m = rounds.run_blade_fl_scan(
                mlp_loss, spec, params, batch, run_key, 2, mesh=mesh)
            ok = led_m.validate_chain()
            for a, b in zip(jax.tree.leaves(st_m.params),
                            jax.tree.leaves(st.params)):
                a, b = np.asarray(a), np.asarray(b)
                ok &= bool(np.allclose(a, b, rtol=1e-5, atol=1e-7))
            name = type(atk).__name__ if atk else "none"
            out[robust + "|" + name] = bool(ok)
        print(json.dumps(out))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert len(res) == 15 and all(res.values()), res
