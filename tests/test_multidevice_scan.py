"""K-round sharded-carry equivalence: the compiled scan engine under the L1
client-sharded layout (shard_map over a >=4-device host mesh) reproduces the
single-device scan BIT FOR BIT — params, every metric, and the hash-linked
ledger — for every shipped topology. Companion to the single-round
``test_multidevice_fl_semantics_subprocess``; this one covers the whole
horizon, where a single reassociated fp32 reduction anywhere would snowball
through the digest into broken hash links."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_sharded_scan_bitwise_equivalence_subprocess():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import json, math
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.core import rounds, topology
        from repro.data.pipeline import FLDataSource
        from repro.models.mlp import init_mlp, mlp_loss

        C, K = 8, 3
        key = jax.random.key(0)
        src = FLDataSource(key, C, samples_per_client=32, seed=0)
        params = init_mlp(jax.random.fold_in(key, 1))
        mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))
        rk = jax.random.fold_in(key, 2)

        def eqf(a, b):
            return a == b or (isinstance(a, float)
                              and math.isnan(a) and math.isnan(b))

        cases = [
            ("full_mesh", topology.FullMesh(),
             dict(n_lazy=1, sigma2=0.05, dp_sigma=0.05)),
            ("full_mesh_detect", topology.FullMesh(),
             dict(detect_lazy=True, n_lazy=2, sigma2=0.01)),
            ("ring1_halo", topology.Ring(neighbors=1),
             dict(n_lazy=1, sigma2=0.05)),
            ("ring2_halo_edge", topology.Ring(neighbors=2), {}),
            ("random_graph_stride", topology.RandomGraph(p_link=0.6),
             dict(eval_every=2)),
            ("partial", topology.PartialParticipation(n_active=3), {}),
            # schedules: rotation = switch over shift-halo permute branches
            # (shifts run past the 2-client block), alternating = static W
            # table scanned by round_idx (with a stochastic-phase variant),
            # snr = table + |D_i|-weighted rows
            ("rotate_schedule", topology.GossipRotation(),
             dict(n_lazy=1, sigma2=0.05)),
            ("alt_schedule", topology.AlternatingSchedule(
                ((topology.Ring(neighbors=1), 2), (topology.FullMesh(), 1))),
             {}),
            ("alt_schedule_random", topology.AlternatingSchedule(
                ((topology.RandomGraph(p_link=0.6), 1),
                 (topology.FullMesh(), 1))), {}),
            ("snr_weighted", topology.LinkQualitySchedule(fading_period=3),
             dict(data_weights=tuple(float(i + 1) for i in range(8)))),
            ("pair_shift_cross_block", topology.PairShift(shift=5), {}),
        ]
        out = {}
        for name, topo, extra in cases:
            spec = rounds.RoundSpec(n_clients=C, tau=2, eta=0.1,
                                    mine_attempts=64, difficulty_bits=2,
                                    topology=topo, **extra)
            batch = src.static_batch()
            st1, h1, l1 = rounds.run_blade_fl_scan(
                mlp_loss, spec, params, batch, rk, K)
            st2, h2, l2 = rounds.run_blade_fl_scan(
                mlp_loss, spec, params, batch, rk, K, mesh=mesh)
            out[name] = {
                "params_bitwise": all(
                    bool((np.asarray(a) == np.asarray(b)).all())
                    for a, b in zip(jax.tree.leaves(st1.params),
                                    jax.tree.leaves(st2.params))),
                "history_bitwise": all(
                    eqf(a[k], b[k]) for a, b in zip(h1, h2) for k in a),
                "ledger_bitwise": [b.header_hash for b in l1.blocks]
                    == [b.header_hash for b in l2.blocks],
                "chain_valid": l2.validate_chain(),
                "n_blocks": len(l2.blocks),
            }
        print(json.dumps(out))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    for name, r in res.items():
        assert r["params_bitwise"], (name, r)
        assert r["history_bitwise"], (name, r)
        assert r["ledger_bitwise"], (name, r)
        assert r["chain_valid"] and r["n_blocks"] == 3, (name, r)


@pytest.mark.slow
def test_sharded_scan_stacked_batches_subprocess():
    """The [K, C, ...] stacked-xs path (per-round data) also holds the
    bitwise contract under the sharded carry, and the donated carry accepts
    a plan with a validated client-axis extent."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import json
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.core import rounds, topology
        from repro.data.pipeline import FLDataSource
        from repro.models.mlp import init_mlp, mlp_loss
        from repro.sharding import plans

        C, K = 8, 3
        key = jax.random.key(3)
        src = FLDataSource(key, C, samples_per_client=32, seed=3)
        params = init_mlp(jax.random.fold_in(key, 1))
        mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))
        plan = plans.scan_carry_plan(mesh, C)
        stacked = jax.tree.map(
            lambda *xs: np.stack(xs), *[src.round_batch(k) for k in range(K)])
        spec = rounds.RoundSpec(n_clients=C, tau=2, eta=0.1, n_lazy=1,
                                sigma2=0.02, mine_attempts=64,
                                difficulty_bits=2,
                                topology=topology.Ring(neighbors=1))
        rk = jax.random.fold_in(key, 2)
        st1, h1, l1 = rounds.run_blade_fl_scan(
            mlp_loss, spec, params, stacked, rk, K, stacked=True)
        st2, h2, l2 = rounds.run_blade_fl_scan(
            mlp_loss, spec, params, stacked, rk, K, stacked=True,
            mesh=mesh, plan=plan)
        print(json.dumps({
            "plan_shards": plan.n_shards,
            "params_bitwise": all(
                bool((np.asarray(a) == np.asarray(b)).all())
                for a, b in zip(jax.tree.leaves(st1.params),
                                jax.tree.leaves(st2.params))),
            "history_bitwise": h1 == h2,
            "ledger_bitwise": [b.header_hash for b in l1.blocks]
                == [b.header_hash for b in l2.blocks],
        }))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res == {"plan_shards": 4, "params_bitwise": True,
                   "history_bitwise": True, "ledger_bitwise": True}


@pytest.mark.slow
def test_cluster_topology_sharded_bitwise_subprocess():
    """ClusterTopology's two-level mix on a 2x4 ('pod', 'data') mesh —
    in-pod all-gather mean + cross-pod cluster-ring ppermute — is bit-for-
    bit the single-device kron(B, J/S) mix across the whole K-round scan:
    params, every metric, and every ledger hash link.

    C=16 keeps >=2 client rows per shard: a size-1 vmap block inside
    value_and_grad fuses differently from the full-width program on CPU
    builds and the materialized per-client loss (a metric dead-end — params
    and digests are unaffected) drifts a ULP. The bitwise-metrics contract
    holds for n_clients >= 2x the device count."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, math
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.core import rounds, topology
        from repro.data.pipeline import FLDataSource
        from repro.models.mlp import init_mlp, mlp_loss
        from repro.sharding import plans

        C, K = 16, 3
        key = jax.random.key(7)
        src = FLDataSource(key, C, samples_per_client=32, seed=7)
        params = init_mlp(jax.random.fold_in(key, 1))
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))
        plan = plans.scan_carry_plan(mesh, C, client_axes=("pod", "data"))
        rk = jax.random.fold_in(key, 2)

        def eqf(a, b):
            return a == b or (isinstance(a, float)
                              and math.isnan(a) and math.isnan(b))

        cases = [
            # cluster-aligned: G == pod extent, in-pod mean + pod-ring halo
            ("cluster_aligned", topology.ClusterTopology(n_clusters=2),
             dict(n_lazy=1, sigma2=0.05)),
            # unaligned G: gathered dense cluster math, still bitwise
            ("cluster_unaligned",
             topology.ClusterTopology(n_clusters=4, inter_weight=0.5), {}),
            # weighted reroute: |D_i| weights send cluster through its
            # dense kron matrix
            ("cluster_weighted",
             topology.ClusterTopology(n_clusters=2, inter_weight=0.4),
             dict(data_weights=tuple(float(i + 1) for i in range(16)))),
            # multi-axis linearized halo: ring window crosses the pod seam
            ("ring2_multi_axis", topology.Ring(neighbors=2),
             dict(n_lazy=1, sigma2=0.02)),
            # shift past the one-block halo window on the compound axis
            ("pair_shift_multi_axis", topology.PairShift(shift=5), {}),
        ]
        out = {}
        for name, topo, extra in cases:
            spec = rounds.RoundSpec(n_clients=C, tau=2, eta=0.1,
                                    mine_attempts=64, difficulty_bits=2,
                                    topology=topo, **extra)
            batch = src.static_batch()
            st1, h1, l1 = rounds.run_blade_fl_scan(
                mlp_loss, spec, params, batch, rk, K)
            st2, h2, l2 = rounds.run_blade_fl_scan(
                mlp_loss, spec, params, batch, rk, K, mesh=mesh, plan=plan)
            out[name] = {
                "params_bitwise": all(
                    bool((np.asarray(a) == np.asarray(b)).all())
                    for a, b in zip(jax.tree.leaves(st1.params),
                                    jax.tree.leaves(st2.params))),
                "history_bitwise": all(
                    eqf(a[k], b[k]) for a, b in zip(h1, h2) for k in a),
                "ledger_bitwise": [b.header_hash for b in l1.blocks]
                    == [b.header_hash for b in l2.blocks],
                "chain_valid": l2.validate_chain(),
                "n_blocks": len(l2.blocks),
            }
        print(json.dumps(out))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    for name, r in res.items():
        assert r["params_bitwise"], (name, r)
        assert r["history_bitwise"], (name, r)
        assert r["ledger_bitwise"], (name, r)
        assert r["chain_valid"] and r["n_blocks"] == 3, (name, r)
