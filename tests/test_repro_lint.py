"""repro-lint analyzer tests (tier-1).

Per-rule fixture snippets prove each code fires on a minimal violation and
goes quiet under an inline ``# repro-lint: disable=RLxxx``; the baseline
machinery is exercised directly; and a regression pins the shipped rule set
green on the live tree (the same invocation the CI lint lane runs). Pure
stdlib on the tool side — these tests never need a JAX runtime.
"""
import os
import shutil
import subprocess
import sys
import textwrap

import pytest

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, REPO_ROOT)

from tools.repro_lint import (  # noqa: E402
    RULES, Finding, apply_baseline, lint_paths, lint_source)

# Each fixture is a minimal positive: the marked line must yield exactly the
# rule's code. Suppression is tested by appending the disable comment to the
# flagged line.
FIXTURES = {
    "RL101": """
        import jax

        def make_stage(spec):
            def stage(params, batch):
                assert params is not None  # <-- flagged
                return params
            return stage
        """,
    "RL102": """
        import dataclasses
        from typing import List, Tuple

        @dataclasses.dataclass(frozen=True)
        class Spec:
            good: Tuple[int, ...] = ()
            bad: List[int] = dataclasses.field(default_factory=list)  # <-- flagged
        """,
    "RL103": """
        import numpy as np

        def make_perturb(spec):
            def perturb(params):
                noise = np.random.normal(size=3)  # <-- flagged
                return params + noise
            return perturb
        """,
    "RL104": """
        def plagiarism_sources(n_clients, n_lazy):
            assert n_lazy < n_clients  # <-- flagged
            return list(range(n_clients))
        """,
    "RL201": """
        import jax

        def make_communicate(spec):
            def communicate(x):
                return jax.lax.psum(x, "data")  # <-- flagged
            return communicate
        """,
    "RL202": """
        import jax

        def run(fn, xs):
            return jax.pmap(fn)(xs)  # <-- flagged
        """,
    "RL203": """
        def drive(runner, state, xs):
            out, metrics = runner(state, xs)
            return state, metrics  # <-- flagged: donated `state` read
        """,
    "RL205": """
        from repro.core import topology

        def make_communicate(spec):
            low = spec.topology.lowering(spec.n_clients)
            if low.kind == topology.GATHER:  # <-- flagged
                return "dense"
            return "permute"
        """,
    "RL301": """
        import jax.numpy as jnp
        from repro.core import aggregation

        def make_finalize(spec, axis_name):
            def finalize(losses):
                losses = aggregation.client_all_gather(losses, axis_name)
                return jnp.mean(losses)  # <-- flagged
            return finalize
        """,
    "RL302": """
        import jax

        def make_mine(spec, axis_name):
            def mine(x):
                return jax.lax.all_gather(x, axis_name)  # <-- flagged
            return mine
        """,
    "RL303": """
        import jax

        def make_window(spec, weights):
            def window(chunks):
                acc = 0.0
                for c, w in zip(chunks, weights):
                    acc = acc + c * w  # <-- flagged: scale inside the sum
                return acc
            return window
        """,
    "RL401": """
        from jax.experimental import pallas as pl

        def launch(kernel, x, n, block):
            return pl.pallas_call(kernel, grid=(n // block,),  # <-- flagged
                                  interpret=True)(x)
        """,
    "RL402": """
        from jax.experimental import pallas as pl

        def launch(kernel, x, grid):
            return pl.pallas_call(kernel, grid=grid)(x)  # <-- flagged
        """,
}


def _lint(snippet: str, path: str = "src/repro/fixture.py"):
    return lint_source(textwrap.dedent(snippet), path)


@pytest.mark.parametrize("code", sorted(FIXTURES))
def test_rule_fires_on_fixture(code):
    findings = _lint(FIXTURES[code])
    assert code in {f.code for f in findings}, \
        f"{code} did not fire on its fixture: {findings}"


@pytest.mark.parametrize("code", sorted(FIXTURES))
def test_rule_suppressed_inline(code):
    src = textwrap.dedent(FIXTURES[code]).replace(
        "# <-- flagged", f"# repro-lint: disable={code}  # was flagged")
    findings = [f for f in _lint(src) if f.code == code]
    assert findings == [], f"disable={code} comment did not suppress"


def test_suppression_on_preceding_comment_line():
    src = textwrap.dedent("""
        def plagiarism_sources(n_clients, n_lazy):
            # repro-lint: disable=RL104
            assert n_lazy < n_clients
            return n_lazy
        """)
    assert [f for f in _lint(src) if f.code == "RL104"] == []


def test_suppression_is_per_code():
    src = textwrap.dedent("""
        def plagiarism_sources(n_clients, n_lazy):
            assert n_lazy < n_clients  # repro-lint: disable=RL999
            return n_lazy
        """)
    assert "RL104" in {f.code for f in _lint(src)}


def test_baseline_waives_by_path_and_code():
    findings = [f for f in _lint(FIXTURES["RL104"]) if f.code == "RL104"]
    assert findings
    entry = {"path": findings[0].path, "code": "RL104",
             "line": findings[0].line + 40}  # stale line: still waives
    fresh, waived, stale = apply_baseline(findings, [entry])
    assert fresh == [] and waived == findings and stale == {}


def test_baseline_allowance_is_counted():
    f = Finding(path="src/x.py", line=1, code="RL104", message="m")
    g = Finding(path="src/x.py", line=9, code="RL104", message="m")
    entry = {"path": "src/x.py", "code": "RL104", "line": 1}
    fresh, waived, _ = apply_baseline([f, g], [entry])
    assert len(waived) == 1 and len(fresh) == 1  # one entry waives one finding


def test_stale_baseline_entries_reported():
    fresh, waived, stale = apply_baseline(
        [], [{"path": "src/gone.py", "code": "RL104", "line": 3}])
    assert stale == {("src/gone.py", "RL104"): 1}


def test_clean_code_yields_nothing():
    src = """
        import jax
        import jax.numpy as jnp

        def make_stage(spec, axis_name):
            def stage(params):
                return jax.lax.psum(params, axis_name)
            return stage
        """
    assert _lint(src) == []


def test_at_least_eight_rules_registered():
    _lint("x = 1")  # force registration
    assert len(RULES) >= 8
    assert set(FIXTURES) == set(RULES), "every rule needs a fixture"


def test_live_tree_is_green():
    """The invocation CI runs: src + benchmarks, repo baseline, exit 0."""
    out = subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", "src", "benchmarks"],
        capture_output=True, text=True, cwd=REPO_ROOT)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "warning: stale baseline entry" not in out.stdout, out.stdout


def test_lint_paths_walks_src():
    findings = lint_paths([os.path.join(REPO_ROOT, "src", "repro", "core")])
    # core/ must stay violation-free (this PR fixed it); posix relpaths
    assert all(f.path.startswith("src/repro/core/") for f in findings)
    assert findings == []


@pytest.mark.skipif(shutil.which("ruff") is None,
                    reason="ruff not installed (CI lint lane runs it)")
def test_ruff_clean():
    out = subprocess.run(["ruff", "check", "src", "tests", "tools",
                          "benchmarks", "examples"],
                         capture_output=True, text=True, cwd=REPO_ROOT)
    assert out.returncode == 0, out.stdout + out.stderr


def test_rl205_fires_on_robust_kind_dispatch():
    """The ROBUST MixLowering kind joined the RL205 frozensets with the
    robust-aggregation family — re-deriving 'is this spec robust?' from the
    kind outside core/topology.py is the same dispatch drift for the new
    tier (standalone case: the 1:1 FIXTURES<->RULES map keeps one canonical
    fixture per rule, this pins the new kind specifically)."""
    const = """
        from repro.core import topology

        def make_communicate(spec, plan):
            if plan.kind == topology.ROBUST:  # <-- flagged
                return "median"
            return "linear"
        """
    findings = _lint(const)
    assert "RL205" in {f.code for f in findings}, findings

    literal = """
        def pick_mix(plan):
            if plan.kind == "robust":  # <-- flagged
                return "median"
            return "linear"
        """
    findings = _lint(literal)
    assert "RL205" in {f.code for f in findings}, findings

    # ...and the one legal home stays legal
    assert _lint(const, path="src/repro/core/topology.py") == []
