"""Eq. (3) resource-allocation accounting."""
from repro.core import allocation, bounds


def test_tau_floor():
    # tau = floor((t/K - beta)/alpha)
    assert allocation.tau_from_budget(100, 5, 1.0, 10.0) == 10
    assert allocation.tau_from_budget(100, 5, 2.0, 10.0) == 5
    assert allocation.tau_from_budget(100, 9, 1.0, 10.0) == 1
    assert allocation.tau_from_budget(100, 10, 1.0, 10.0) == 0


def test_plan_accounting():
    p = allocation.plan(100, 5, 1.0, 10.0)
    assert p.tau == 10
    assert p.train_time == 50
    assert p.mine_time == 50
    assert p.slack == 0
    assert p.feasible


def test_slack_nonnegative_and_small():
    for k in range(1, 12):
        p = allocation.plan(100, k, 1.3, 7.7)
        if p.tau >= 1:
            assert p.slack >= -1e-9
            assert p.slack < k * 1.3 + 1e-9  # floor loses < alpha per round


def test_feasible_rounds():
    ks = allocation.feasible_rounds(100, 1.0, 10.0)
    assert ks and max(ks) <= 9
    for k in ks:
        assert allocation.tau_from_budget(100, k, 1.0, 10.0) >= 1


def test_optimal_plan_feasible():
    p = bounds.BoundParams(eta=0.01, L=10.0, xi=1.0, delta=0.5, alpha=1.0,
                           beta=10.0, t_sum=100.0)
    plan = allocation.optimal_plan(p)
    assert plan.feasible


def test_mining_iterations_calibration():
    assert allocation.mining_iterations(10.0, hash_rate=100.0) == 1000
    assert allocation.mining_iterations(0.0001) >= 1


def test_tradeoff_monotonicity():
    # eq. 3: larger K -> smaller tau (fundamental tradeoff)
    taus = [allocation.tau_from_budget(100, k, 1.0, 6.0) for k in range(1, 10)]
    assert all(a >= b for a, b in zip(taus, taus[1:]))
