"""The cohort-sampled population path: ``topology.CohortSchedule``,
``rounds.PopulationStore`` and ``rounds.run_blade_fl_cohort``.

The contracts pinned here:

  * **sampler statistics** — uniform draws hit every enrolled client at the
    uniform rate (chi-square-style bound over a deterministic key stream);
    Pareto weights skew participation toward the head exactly as the
    ``weights()`` ordering says; ``prefix`` is literally ``arange(A)``.
  * **replayability** — cohort membership is a pure function of the
    engine's per-round ``k_topo`` stream: ``rounds.topology_keys`` replays
    a run's recorded cohorts exactly, and a shifted key stream (the
    negative control) does not.
  * **degenerate-cohort regression** — with A = C_enrolled the cohort
    driver IS the plain driver: params, history metrics and the ledger
    chain agree bitwise with ``run_blade_fl``.
  * **PartialParticipation reroute** — the sparse segment mix vs the old
    masked-dense mix on the same PartialParticipation spec: tolerance-tier
    params, round-1 digest bitwise (the digest is pre-mix), chains fork
    deterministically after — pinned exactly like the fast_allreduce fork.
  * **store laziness** — host memory scales with TOUCHED clients, never
    with C_enrolled; gather/scatter validate their indices.
  * **sharded carry** — the 4-device cohort run is bitwise the
    single-device one (skips without devices; the CI cohort lane and the
    slow subprocess case supply them).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import chain, rounds, topology
from repro.models.mlp import init_mlp, mlp_loss
from repro.sharding import plans

from equivalence import assert_trees_close

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

needs4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs 4 host devices (CI cohort lane sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _mesh4():
    return Mesh(np.array(jax.devices()[:4]), ("data",))


def _tiny_params(key):
    return init_mlp(key, in_dim=12, hidden=6)


def _batch_fn(key, m=5):
    def fn(round_idx, cohort_idx):
        ks = jax.vmap(lambda i: jax.random.fold_in(key, i))(
            jnp.asarray(cohort_idx, jnp.int32))
        x = jax.vmap(lambda k: jax.random.normal(k, (m, 12)))(ks)
        y = jax.vmap(lambda k: jax.random.randint(k, (m,), 0, 10))(ks)
        return {"x": x, "y": y.astype(jnp.int32)}
    return fn


def _spec(a, **kw):
    kw.setdefault("topology", topology.FullMesh())
    return rounds.RoundSpec(n_clients=a, tau=2, eta=0.1, mine_attempts=16,
                            difficulty_bits=1, **kw)


# ---------------------------------------------------------------------------
# CohortSchedule: validation + sampling statistics
# ---------------------------------------------------------------------------


def test_cohort_schedule_validation():
    with pytest.raises(ValueError):
        topology.CohortSchedule(n_enrolled=4, cohort_size=5)
    with pytest.raises(ValueError):
        topology.CohortSchedule(n_enrolled=4, cohort_size=0)
    with pytest.raises(ValueError):
        topology.CohortSchedule(n_enrolled=4, cohort_size=2, bias="bogus")
    with pytest.raises(ValueError):
        topology.CohortSchedule(n_enrolled=4, cohort_size=2, bias="pareto",
                                pareto_alpha=0.0)


def test_from_spec_parses_bias_strings():
    cs = topology.CohortSchedule.from_spec(100, 8, "pareto:2.5")
    assert cs.bias == "pareto" and cs.pareto_alpha == 2.5
    assert topology.CohortSchedule.from_spec(100, 8, "uniform").bias == \
        "uniform"
    assert topology.CohortSchedule.from_spec(100, 8, "prefix").bias == \
        "prefix"
    with pytest.raises(ValueError):
        topology.CohortSchedule.from_spec(100, 8, "zipf")
    with pytest.raises(ValueError):
        topology.CohortSchedule.from_spec(100, 8, "pareto:nope")


def test_weights_shapes_and_ordering():
    uni = topology.CohortSchedule(n_enrolled=10, cohort_size=3).weights()
    np.testing.assert_allclose(uni, np.full(10, 0.1), rtol=1e-12)
    par = topology.CohortSchedule(n_enrolled=10, cohort_size=3,
                                  bias="pareto", pareto_alpha=1.5).weights()
    assert par.shape == (10,) and abs(par.sum() - 1.0) < 1e-12
    assert np.all(np.diff(par) < 0)           # strictly head-heavy
    pre = topology.CohortSchedule(n_enrolled=10, cohort_size=3,
                                  bias="prefix").weights()
    assert pre[:3].sum() == pytest.approx(1.0) and np.all(pre[3:] == 0)


def test_cohort_at_is_sorted_unique_in_range():
    cs = topology.CohortSchedule(n_enrolled=50, cohort_size=7)
    for k in rounds.topology_keys(jax.random.key(0), 5):
        idx = np.asarray(cs.cohort_at(k))
        assert idx.shape == (7,) and idx.dtype == np.int32
        assert np.all(np.diff(idx) > 0)        # sorted, distinct
        assert idx.min() >= 0 and idx.max() < 50


def test_prefix_cohort_is_arange():
    cs = topology.CohortSchedule(n_enrolled=50, cohort_size=7, bias="prefix")
    for k in rounds.topology_keys(jax.random.key(0), 3):
        np.testing.assert_array_equal(np.asarray(cs.cohort_at(k)),
                                      np.arange(7))


def test_uniform_sampling_frequencies_chi_square():
    """Over many keyed draws every enrolled client participates at the
    uniform rate: chi-square statistic over the per-client counts stays
    under the 99.9th percentile of chi2(C-1). Deterministic keys, so this
    never flakes."""
    c, a, n_draws = 10, 3, 3000
    cs = topology.CohortSchedule(n_enrolled=c, cohort_size=a)
    keys = jnp.stack(rounds.topology_keys(jax.random.key(7), n_draws))
    idx = np.asarray(jax.vmap(cs.cohort_at)(keys))
    counts = np.bincount(idx.ravel(), minlength=c)
    assert counts.sum() == n_draws * a
    expected = n_draws * a / c
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    assert chi2 < 27.9, f"chi2={chi2}, counts={counts}"   # chi2(9) @ .999


def test_pareto_sampling_is_head_heavy():
    c, a, n_draws = 20, 4, 1500
    cs = topology.CohortSchedule(n_enrolled=c, cohort_size=a,
                                 bias="pareto", pareto_alpha=1.5)
    keys = jnp.stack(rounds.topology_keys(jax.random.key(3), n_draws))
    counts = np.bincount(
        np.asarray(jax.vmap(cs.cohort_at)(keys)).ravel(), minlength=c)
    # participation decreases over quartiles of the id range, and the head
    # dominates the tail outright
    quartiles = counts.reshape(4, 5).sum(1)
    assert np.all(np.diff(quartiles) < 0), quartiles
    assert counts[0] > 3 * counts[-1]


def test_uniform_draws_differ_across_rounds():
    cs = topology.CohortSchedule(n_enrolled=200, cohort_size=5)
    keys = rounds.topology_keys(jax.random.key(0), 6)
    draws = [tuple(np.asarray(cs.cohort_at(k))) for k in keys]
    assert len(set(draws)) > 1


# ---------------------------------------------------------------------------
# PopulationStore
# ---------------------------------------------------------------------------


def test_population_store_is_lazy():
    params = _tiny_params(jax.random.key(0))
    store = rounds.PopulationStore(params, 10_000)
    assert store.touched == 0
    base = store.materialized_bytes()          # just the shared init model
    got = store.gather(np.array([3, 9_999]))
    for leaf, init in zip(jax.tree.leaves(got), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(leaf[0]), np.asarray(init))
        np.testing.assert_array_equal(np.asarray(leaf[1]), np.asarray(init))
    assert store.touched == 0                  # gather alone touches nothing
    store.scatter(np.array([3, 9_999]), got)
    assert store.touched == 2
    assert store.materialized_bytes() > base


def test_population_store_scatter_round_trips():
    params = _tiny_params(jax.random.key(1))
    store = rounds.PopulationStore(params, 100)
    cohort = jax.tree.map(
        lambda x: jnp.stack([x + 1.0, x + 2.0, x + 3.0]), params)
    store.scatter(np.array([5, 50, 99]), cohort)
    back = store.gather(np.array([50, 99, 5]))
    want = jax.tree.map(
        lambda x: jnp.stack([x + 2.0, x + 3.0, x + 1.0]), params)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_population_store_validates_indices():
    params = _tiny_params(jax.random.key(0))
    with pytest.raises(ValueError):
        rounds.PopulationStore(params, 0)
    store = rounds.PopulationStore(params, 10)
    with pytest.raises(ValueError):
        store.gather(np.array([0, 10]))        # out of range
    with pytest.raises(ValueError):
        store.gather(np.array([-1]))
    cohort = jax.tree.map(lambda x: jnp.stack([x, x]), params)
    with pytest.raises(ValueError):
        store.scatter(np.array([0, 1, 2]), cohort)   # leading-dim mismatch


# ---------------------------------------------------------------------------
# The cohort driver
# ---------------------------------------------------------------------------


def test_cohort_driver_validates_sizes():
    params = _tiny_params(jax.random.key(0))
    cs = topology.CohortSchedule(n_enrolled=20, cohort_size=4)
    with pytest.raises(ValueError, match="cohort_size"):
        rounds.run_blade_fl_cohort(mlp_loss, _spec(5), params,
                                   _batch_fn(jax.random.key(3)),
                                   jax.random.key(2), 2, cs)
    wrong_store = rounds.PopulationStore(params, 30)
    with pytest.raises(ValueError, match="n_enrolled"):
        rounds.run_blade_fl_cohort(mlp_loss, _spec(4), params,
                                   _batch_fn(jax.random.key(3)),
                                   jax.random.key(2), 2, cs,
                                   store=wrong_store)


def test_cohort_replay_from_topology_keys():
    """The recorded per-round cohorts are a pure function of the run key's
    topology stream — and of nothing else. Shifted keys (the negative
    control) produce different memberships."""
    params = _tiny_params(jax.random.key(0))
    run_key = jax.random.key(2)
    cs = topology.CohortSchedule(n_enrolled=60, cohort_size=4)
    _, hist, _ = rounds.run_blade_fl_cohort(
        mlp_loss, _spec(4), params, _batch_fn(jax.random.key(3)),
        run_key, 4, cs)
    keys = rounds.topology_keys(run_key, 4)
    replayed = [[int(i) for i in np.asarray(cs.cohort_at(k))] for k in keys]
    assert replayed == [h["cohort"] for h in hist]
    shifted = [[int(i) for i in np.asarray(cs.cohort_at(
        jax.random.fold_in(k, 1)))] for k in keys]
    assert shifted != [h["cohort"] for h in hist]


def test_degenerate_cohort_equals_plain_driver_bitwise():
    """A = C_enrolled: every client participates every round, so the cohort
    driver must BE run_blade_fl — params, metrics and the hash-linked chain
    agree bitwise (the host key mirror reproduces the device split chain
    exactly)."""
    c, k = 6, 4
    key = jax.random.key(0)
    params = _tiny_params(jax.random.fold_in(key, 1))
    batch = {"x": jax.random.normal(jax.random.fold_in(key, 3), (c, 40, 12)),
             "y": jax.random.randint(jax.random.fold_in(key, 4),
                                     (c, 40), 0, 10)}
    run_key = jax.random.fold_in(key, 2)
    st, hist_d, led_d = rounds.run_blade_fl(
        mlp_loss, _spec(c), params, batch, run_key, k)
    cs = topology.CohortSchedule(n_enrolled=c, cohort_size=c)
    store, hist_c, led_c = rounds.run_blade_fl_cohort(
        mlp_loss, _spec(c), params, batch, run_key, k, cs)
    final = store.gather(np.arange(c))
    for a, b in zip(jax.tree.leaves(final), jax.tree.leaves(st.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [b_.header_hash for b_ in led_c.blocks] == \
           [b_.header_hash for b_ in led_d.blocks]
    for hc, hd in zip(hist_c, hist_d):
        assert hc["cohort"] == list(range(c))
        for k2, v in hd.items():
            assert hc[k2] == v, k2


def test_cohort_run_touches_only_participants():
    params = _tiny_params(jax.random.key(0))
    cs = topology.CohortSchedule(n_enrolled=10_000, cohort_size=4)
    store, hist, ledger = rounds.run_blade_fl_cohort(
        mlp_loss, _spec(4), params, _batch_fn(jax.random.key(3)),
        jax.random.key(2), 3, cs)
    active = {i for h in hist for i in h["cohort"]}
    assert store.touched == len(active) <= 12
    assert ledger.validate_chain() and len(ledger.blocks) == 3
    # the scatter really lands: participants moved off the init model
    init = jax.tree.leaves(params)
    some = store.gather(np.array(sorted(active)[:2]))
    moved = any(not np.array_equal(np.asarray(leaf[0]), np.asarray(i0))
                for leaf, i0 in zip(jax.tree.leaves(some), init))
    assert moved


def test_partial_participation_sparse_vs_masked_dense():
    """The reroute regression (pinned like the fast_allreduce fork): the
    SAME PartialParticipation spec mixed through mix_segment
    (sparse_mix=True) vs the masked dense matmul (sparse_mix=False).
    Tolerance-tier params/metrics; the round-1 digest is BITWISE (digests
    hash the pre-mix broadcast set); both chains stay valid and the sparse
    chain reproduces itself deterministically."""
    c, k = 16, 3
    key = jax.random.key(5)
    params = _tiny_params(jax.random.fold_in(key, 1))
    batch = {"x": jax.random.normal(jax.random.fold_in(key, 3), (c, 8, 12)),
             "y": jax.random.randint(jax.random.fold_in(key, 4),
                                     (c, 8), 0, 10)}
    run_key = jax.random.fold_in(key, 2)
    outs = {}
    for sparse in (True, False):
        spec = _spec(c, sparse_mix=sparse,
                     topology=topology.PartialParticipation(n_active=4))
        outs[sparse] = rounds.run_blade_fl(
            mlp_loss, spec, params, batch, run_key, k)
    st_s, hist_s, led_s = outs[True]
    st_d, hist_d, led_d = outs[False]
    assert_trees_close(st_s.params, st_d.params, rtol=1e-5, atol=1e-6)
    assert led_s.blocks[0].model_digest == led_d.blocks[0].model_digest
    assert led_s.validate_chain() and led_d.validate_chain()
    for hs, hd in zip(hist_s, hist_d):
        assert hs["local_loss_mean"] == pytest.approx(
            hd["local_loss_mean"], rel=1e-5)
    _, _, led_s2 = rounds.run_blade_fl(
        mlp_loss, _spec(c, sparse_mix=True,
                        topology=topology.PartialParticipation(n_active=4)),
        params, batch, run_key, k)
    assert [b.header_hash for b in led_s.blocks] == \
           [b.header_hash for b in led_s2.blocks]


def test_cohort_carry_plan_validation(fake_mesh):
    mesh = fake_mesh((4,), ("data",))
    plan = plans.cohort_carry_plan(mesh, 1000, 8)
    assert plan.clients_per_shard == 2
    with pytest.raises(ValueError):
        plans.cohort_carry_plan(mesh, 1000, 6)      # 6 % 4 != 0
    with pytest.raises(ValueError):
        plans.cohort_carry_plan(mesh, 4, 8)         # A > C_enrolled
    with pytest.raises(ValueError):
        plans.cohort_carry_plan(mesh, 1000, 8, client_axes=("model",))
    with pytest.raises(ValueError):
        plans.cohort_carry_plan(mesh, 1000, 8, client_axes=())


@needs4
def test_sharded_cohort_bitwise_vs_single_device():
    """The 4-device cohort carry (cohort sharded over the mesh, population
    host-side) reproduces the single-device run bit-for-bit: cohorts,
    ledger chain, history metrics, and every touched store row."""
    a, enrolled, k = 8, 50, 3
    key = jax.random.key(0)
    params = _tiny_params(jax.random.fold_in(key, 1))
    run_key = jax.random.fold_in(key, 2)
    cs = topology.CohortSchedule(n_enrolled=enrolled, cohort_size=a)
    batch_fn = _batch_fn(jax.random.fold_in(key, 3))
    st1, hist1, led1 = rounds.run_blade_fl_cohort(
        mlp_loss, _spec(a), params, batch_fn, run_key, k, cs)
    st4, hist4, led4 = rounds.run_blade_fl_cohort(
        mlp_loss, _spec(a), params, batch_fn, run_key, k, cs,
        mesh=_mesh4())
    assert [h["cohort"] for h in hist1] == [h["cohort"] for h in hist4]
    assert [b.header_hash for b in led1.blocks] == \
           [b.header_hash for b in led4.blocks]
    touched = sorted({i for h in hist1 for i in h["cohort"]})
    r1, r4 = st1.gather(np.array(touched)), st4.gather(np.array(touched))
    for x, y in zip(jax.tree.leaves(r1), jax.tree.leaves(r4)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for h1, h4 in zip(hist1, hist4):
        assert h1 == h4


@pytest.mark.slow
def test_cohort_suite_on_4_devices_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-k", "sharded",
         os.path.abspath(__file__)],
        capture_output=True, text=True, env=env, timeout=900,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-2000:]


def test_genesis_linkage_matches_chain_module():
    """The cohort driver's host-mirrored prev_hash starts at the same
    genesis constant the ledger validates against."""
    params = _tiny_params(jax.random.key(0))
    cs = topology.CohortSchedule(n_enrolled=12, cohort_size=4)
    _, _, ledger = rounds.run_blade_fl_cohort(
        mlp_loss, _spec(4), params, _batch_fn(jax.random.key(3)),
        jax.random.key(2), 1, cs)
    assert ledger.blocks[0].prev_hash == chain.GENESIS_HASH
    assert ledger.validate_chain()
