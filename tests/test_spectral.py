"""Spectral-gap diagnostics (core/spectral.py): invariants of 1 - |lambda_2|
per topology/schedule, the ergodic product-matrix gap, and the link to the
engine's observed consensus contraction."""
import jax
import numpy as np
import pytest

from repro.core import rounds, spectral, topology

STATIC_TOPOLOGIES = [
    topology.FullMesh(),
    topology.Ring(neighbors=1),
    topology.Ring(neighbors=2),
    topology.PartialParticipation(n_active=3),
    topology.PairShift(shift=1),
    topology.ClusterTopology(n_clusters=2),
    topology.ClusterTopology(n_clusters=4, inter_weight=0.5),
]

SCHEDULES = [
    topology.GossipRotation(),
    topology.AlternatingSchedule(
        ((topology.Ring(neighbors=1), 2), (topology.FullMesh(), 1))),
    topology.LinkQualitySchedule(fading_period=3),
]


def _ids(t):
    return type(t).__name__


# ---------------------------------------------------------------------------
# Gap invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", STATIC_TOPOLOGIES + SCHEDULES, ids=_ids)
def test_gap_in_unit_interval_every_round(topo):
    gaps = spectral.per_round_gaps(topo, 8, 5)
    assert ((gaps >= 0.0) & (gaps <= 1.0)).all()
    erg = spectral.ergodic_gap(topo, 8, n_rounds=5)
    assert 0.0 <= erg <= 1.0


def test_full_mesh_gap_is_one():
    assert spectral.spectral_gap(topology.FullMesh().matrix(8)) == \
        pytest.approx(1.0)


def test_identity_and_partial_participation_gap_zero():
    assert spectral.spectral_gap(np.eye(6)) == pytest.approx(0.0)
    # inactive clients never mix: a disagreement mode survives every round
    w = topology.PartialParticipation(n_active=3).matrix(6)
    assert spectral.spectral_gap(w) == pytest.approx(0.0)


def test_ring_gap_monotone_in_window():
    c = 12
    gaps = [spectral.spectral_gap(topology.Ring(neighbors=k).matrix(c))
            for k in range(1, c // 2 + 1)]
    assert all(a < b for a, b in zip(gaps, gaps[1:]))
    assert gaps[-1] == pytest.approx(1.0)   # window covers the mesh


def test_stochastic_topology_needs_keys():
    with pytest.raises(ValueError, match="stochastic"):
        spectral.per_round_gaps(topology.RandomGraph(0.5), 6, 3)
    keys = rounds.topology_keys(jax.random.key(0), 3)
    gaps = spectral.per_round_gaps(topology.RandomGraph(0.5), 6, 3, keys=keys)
    assert ((gaps >= 0.0) & (gaps <= 1.0)).all()


def test_topology_keys_match_engine_stream():
    """topology_keys replays the ENGINE's per-round k_topo stream: mixing a
    distinct-per-client params tree through the replayed round-0 RandomGraph
    matrix reproduces the params the real round body emits (tau=0 isolates
    the mix: no training, no lazy/DP perturbation)."""
    import jax.numpy as jnp
    from repro.core import aggregation

    c, run_key = 6, jax.random.key(7)
    topo = topology.RandomGraph(p_link=0.5)
    spec = rounds.RoundSpec(n_clients=c, tau=0, eta=0.1, mine_attempts=8,
                            difficulty_bits=1, eval_global_loss=False,
                            topology=topo)

    def loss_fn(p, b):
        return jnp.mean(p["w"] ** 2), {}

    params = {"w": jnp.arange(float(c * 3)).reshape(c, 3)}
    batch = {"x": jnp.zeros((c, 1))}
    round_fn = rounds.make_integrated_round(loss_fn, spec)
    state = rounds.RoundState(params=params, key=run_key,
                              round_idx=jnp.int32(0),
                              prev_hash=jnp.uint32(0))
    new_state, _ = round_fn(state, batch)

    (k_topo,) = rounds.topology_keys(run_key, 1)
    w = topo.matrix(c, key=k_topo, round_idx=jnp.int32(0))
    want = aggregation.mix(params, w)
    np.testing.assert_allclose(np.asarray(new_state.params["w"]),
                               np.asarray(want["w"]), rtol=1e-6)
    # and the replay must NOT equal a naively-unsplit key's draw
    wrong = aggregation.mix(
        params, topo.matrix(c, key=run_key, round_idx=jnp.int32(0)))
    assert not np.allclose(np.asarray(new_state.params["w"]),
                           np.asarray(wrong["w"]))


# ---------------------------------------------------------------------------
# Ergodic (product-matrix) gap
# ---------------------------------------------------------------------------


def test_ergodic_gap_of_static_topology_is_its_gap():
    for topo in (topology.Ring(neighbors=1), topology.FullMesh()):
        assert spectral.ergodic_gap(topo, 8) == pytest.approx(
            spectral.spectral_gap(topo.matrix(8)), abs=1e-9)


def test_rotation_ergodic_gap_beats_every_phase():
    """The rotation's whole-period product mixes far better than any single
    pair-averaging phase — the reason per-round gaps undersell schedules."""
    c = 8
    rot = topology.GossipRotation()
    phase_gaps = spectral.per_round_gaps(rot, c, rot.period(c))
    erg = spectral.ergodic_gap(rot, c)
    assert erg > phase_gaps.max()
    assert erg > 0.9


def test_alternating_ergodic_gap_is_one_with_mesh_sync():
    """A full-mesh round anywhere in the period collapses all disagreement:
    the product matrix is rank one -> per-round ergodic gap 1."""
    sched = topology.AlternatingSchedule(
        ((topology.Ring(neighbors=1), 2), (topology.FullMesh(), 1)))
    assert spectral.ergodic_gap(sched, 8) == pytest.approx(1.0)


def test_gap_report_schema_and_consistency():
    rep = spectral.gap_report(topology.GossipRotation(), 8, 7)
    assert set(rep) == {"gap_per_round", "gap_min", "gap_mean",
                        "ergodic_gap", "predicted_consensus_rate"}
    assert len(rep["gap_per_round"]) == 7
    assert rep["gap_min"] == min(rep["gap_per_round"])
    assert rep["predicted_consensus_rate"] == \
        pytest.approx(1.0 - rep["ergodic_gap"])


# ---------------------------------------------------------------------------
# Gap vs the engine's observed consensus contraction
# ---------------------------------------------------------------------------


def test_gap_orders_observed_consensus():
    """Higher ergodic gap -> faster observed divergence decay in the real
    engine (same data, same seeds). FullMesh (gap 1) collapses the spread;
    Ring(1) (small gap) leaves the most; the rotation sits strictly
    between its phase gaps and the mesh."""
    from repro.data.pipeline import FLDataSource
    from repro.models.mlp import init_mlp, mlp_loss
    from repro.core.aggregation import client_divergence

    c, k = 8, 7
    key = jax.random.key(3)
    src = FLDataSource(key, c, samples_per_client=32, seed=3)
    params = init_mlp(jax.random.fold_in(key, 1))

    def final_spread(topo):
        spec = rounds.RoundSpec(n_clients=c, tau=2, eta=0.1, mine_attempts=32,
                                difficulty_bits=2, topology=topo)
        st, _, _ = rounds.run_blade_fl(
            mlp_loss, spec, params, src.static_batch(),
            jax.random.fold_in(key, 2), k)
        return float(client_divergence(st.params))

    spreads = {name: final_spread(t) for name, t in [
        ("mesh", topology.FullMesh()),
        ("rotate", topology.GossipRotation()),
        ("ring", topology.Ring(neighbors=1))]}
    gaps = {name: spectral.ergodic_gap(t, c, n_rounds=k) for name, t in [
        ("mesh", topology.FullMesh()),
        ("rotate", topology.GossipRotation()),
        ("ring", topology.Ring(neighbors=1))]}
    # over a full period the rotation's product mixes completely (gap -> 1,
    # like the mesh); the ring never does — and the observed spread follows
    assert gaps["mesh"] >= gaps["rotate"] > gaps["ring"]
    assert spreads["mesh"] < spreads["rotate"] < spreads["ring"]


# ---------------------------------------------------------------------------
# Sparse lowerings through the diagnostics (densified under the small-C guard)
# ---------------------------------------------------------------------------


def test_lambda2_accepts_sparse_lowering():
    ring = topology.Ring(neighbors=1)
    sp = ring.sparse_lowering(9)
    assert spectral.lambda2_modulus(sp) == pytest.approx(
        spectral.lambda2_modulus(np.asarray(ring.matrix(9))), abs=1e-9)


def test_round_matrices_accepts_raw_sparse_lowering():
    sp = topology.Ring(neighbors=2).sparse_lowering(8)
    ws = spectral.round_matrices(sp, 8, 3)
    assert len(ws) == 3
    np.testing.assert_allclose(ws[0], np.asarray(
        topology.Ring(neighbors=2).matrix(8)), atol=1e-7)
    with pytest.raises(ValueError, match="n_clients"):
        spectral.round_matrices(sp, 12, 3)


def test_gap_report_on_explicit_sparse_topology():
    topo = topology.ExplicitSparse(neighbors=topology.ring_neighbors(8, 1))
    rep = spectral.gap_report(topo, 8, 3)
    want = spectral.gap_report(topology.Ring(neighbors=1), 8, 3)
    assert rep["ergodic_gap"] == pytest.approx(want["ergodic_gap"], abs=1e-7)


# ---------------------------------------------------------------------------
# Two-level ClusterTopology: analytic gap vs eigensolve, coupling monotonicity
# ---------------------------------------------------------------------------


def test_cluster_gap_analytic_matches_eigensolve():
    """cluster_spectral_gap's closed form — circulant eigenvalues
    (1-a) + a*cos(2*pi*k/G) of B, plus the zero modes J/S contributes —
    equals the dense eigensolve of kron(B, J/S) for aligned and degenerate
    shapes."""
    for g, a, c in [(2, 0.3, 8), (4, 0.5, 12), (8, 0.7, 24), (3, 0.0, 9)]:
        w = topology.ClusterTopology(n_clusters=g, inter_weight=a).matrix(c)
        assert spectral.cluster_spectral_gap(g, a, cluster_size=c // g) == \
            pytest.approx(spectral.spectral_gap(w), abs=1e-6)
    # single cluster: J/S is rank one, perfect consensus in one round
    assert spectral.cluster_spectral_gap(1, 0.5, cluster_size=4) == 1.0


def test_cluster_gap_monotone_in_inter_weight():
    """More inter-cluster coupling mixes faster — the gap grows monotonically
    in the ring weight over the useful range (up to the a where the
    traveling-wave mode takes over)."""
    gaps = [spectral.cluster_spectral_gap(8, a)
            for a in (0.1, 0.3, 0.5, 0.7)]
    assert all(a < b for a, b in zip(gaps, gaps[1:]))
    # and so does the observed dense-matrix gap at C=24 (S=3 per cluster)
    dense = [spectral.spectral_gap(topology.ClusterTopology(
        n_clusters=8, inter_weight=a).matrix(24)) for a in (0.1, 0.3, 0.5)]
    assert all(a < b for a, b in zip(dense, dense[1:]))


def test_cluster_ergodic_gap_beats_same_degree_ring():
    """The hierarchy buys spectrum per edge: at C=24 every client in an
    8-cluster topology touches 9 models (3 in-cluster + 6 in the two
    neighbor clusters) — the same degree as Ring(neighbors=4)'s 9-wide
    window — but the dense in-cluster block kills the slow intra-cluster
    modes outright and the ergodic gap is strictly larger."""
    c, g, a = 24, 8, 0.8
    cluster = topology.ClusterTopology(n_clusters=g, inter_weight=a)
    ring = topology.Ring(neighbors=4)
    deg_cluster = int((np.asarray(cluster.matrix(c)) > 0).sum(axis=1)[0])
    deg_ring = int((np.asarray(ring.matrix(c)) > 0).sum(axis=1)[0])
    assert deg_cluster == deg_ring == 9
    gap_cluster = spectral.ergodic_gap(cluster, c)
    gap_ring = spectral.ergodic_gap(ring, c)
    assert gap_cluster > gap_ring
    # static topologies: the ergodic gap is the per-matrix gap, and the
    # cluster one is the analytic closed form
    assert gap_cluster == pytest.approx(
        spectral.cluster_spectral_gap(g, a, cluster_size=c // g), abs=1e-6)


def test_spectral_densify_guard_refuses_population_scale():
    c = topology.DENSIFY_MAX_CLIENTS + 1
    sp = topology.SparseLowering(
        np.arange(c, dtype=np.int32)[:, None],
        np.ones((c, 1), np.float32))
    with pytest.raises(ValueError, match="refusing to densify"):
        spectral.lambda2_modulus(sp)
    with pytest.raises(ValueError, match="refusing to densify"):
        spectral.round_matrices(sp, c, 2)
