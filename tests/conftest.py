import os
import re
import sys

import pytest

# Tests must never see the dry-run's 512 placeholder devices (see
# launch/dryrun.py which sets XLA_FLAGS itself). Small host-device counts
# ARE allowed: the CI multidevice lane runs the tolerance-tier suites under
# XLA_FLAGS=--xla_force_host_platform_device_count=4 (docs/architecture.md
# §The tolerance tier); tests that need >1 device skip themselves when the
# flag is absent.
_count = re.search(r"xla_force_host_platform_device_count=(\d+)",
                   os.environ.get("XLA_FLAGS", ""))
assert _count is None or int(_count.group(1)) <= 8, \
    "do not run tests with dry-run XLA_FLAGS"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def make_fake_mesh(shape=(16, 16), axes=("data", "model")):
    """Abstract mesh for spec construction (no real devices needed).

    Version-compat shim: JAX 0.4.37 wants ``AbstractMesh(shape_tuple)`` with
    a tuple of ``(name, size)`` pairs; older/newer releases took
    ``(shape, axes)`` or a dict. Any mesh test should use this one helper
    instead of growing its own fallback chain.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        pass
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(dict(zip(axes, shape)))


@pytest.fixture
def fake_mesh():
    """Factory fixture over :func:`make_fake_mesh`."""
    return make_fake_mesh
