import os
import sys

import pytest

# Tests must see the single real CPU device — never the dry-run's 512
# placeholders (see launch/dryrun.py which sets XLA_FLAGS itself).
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "do not run tests with dry-run XLA_FLAGS"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def make_fake_mesh(shape=(16, 16), axes=("data", "model")):
    """Abstract mesh for spec construction (no real devices needed).

    Version-compat shim: JAX 0.4.37 wants ``AbstractMesh(shape_tuple)`` with
    a tuple of ``(name, size)`` pairs; older/newer releases took
    ``(shape, axes)`` or a dict. Any mesh test should use this one helper
    instead of growing its own fallback chain.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        pass
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(dict(zip(axes, shape)))


@pytest.fixture
def fake_mesh():
    """Factory fixture over :func:`make_fake_mesh`."""
    return make_fake_mesh
