import os
import sys

# Tests must see the single real CPU device — never the dry-run's 512
# placeholders (see launch/dryrun.py which sets XLA_FLAGS itself).
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "do not run tests with dry-run XLA_FLAGS"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
