"""Per-kernel allclose vs pure-jnp oracle, swept over shapes and dtypes
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mining
from repro.kernels.fedavg import fedavg_flat, fedavg_flat_ref, fedavg_tree
from repro.kernels.flash_attention import attention_ref, flash_attention, mha
from repro.kernels.pow_hash import mine, pow_search_kernel, pow_search_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # b, h, s, d, causal, window, bq, bk
    (2, 4, 256, 64, True, 0, 128, 128),
    (1, 2, 128, 32, False, 0, 64, 64),
    (2, 2, 256, 64, True, 64, 64, 128),
    (1, 1, 512, 128, True, 0, 128, 128),
    (1, 2, 128, 16, True, 32, 32, 64),
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=lambda c: f"b{c[0]}h{c[1]}s{c[2]}d{c[3]}c{int(c[4])}w{c[5]}")
def test_flash_attention_allclose(case):
    b, h, s, d, causal, window, bq, bk = case
    ks = jax.random.split(jax.random.key(s + d), 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, h, s, d))
    v = jax.random.normal(ks[2], (b, h, s, d))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(dtype)
    out = flash_attention(q, k, v, interpret=True)
    ref = attention_ref(q, k, v)
    atol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)
    assert out.dtype == dtype


def test_mha_gqa_expansion():
    ks = jax.random.split(jax.random.key(9), 3)
    b, s, hq, hkv, d = 2, 128, 8, 2, 32
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    out_k = mha(q, k, v, block_q=64, block_k=64, use_kernel=True)
    out_r = mha(q, k, v, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# fedavg
# ---------------------------------------------------------------------------

FEDAVG_CASES = [
    (8, 1000, jnp.float32, False, 512),
    (20, 5000, jnp.float32, True, 1024),
    (16, 2048, jnp.bfloat16, True, 256),
    (4, 33, jnp.float32, False, 64),
    (2, 7, jnp.float32, True, 2048),
]


@pytest.mark.parametrize("case", FEDAVG_CASES,
                         ids=lambda c: f"c{c[0]}n{c[1]}{c[2].__name__}")
def test_fedavg_allclose(case):
    c, n, dtype, with_noise, block = case
    ks = jax.random.split(jax.random.key(c * n), 3)
    x = jax.random.normal(ks[0], (c, n)).astype(dtype)
    w = jax.nn.softmax(jax.random.normal(ks[1], (c,)))
    nz = (jax.random.normal(ks[2], (c, n)).astype(dtype) * 0.1
          if with_noise else None)
    out = fedavg_flat(x, w, nz, block_n=block, interpret=True)
    ref = fedavg_flat_ref(x, w, nz)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=1e-5)


def test_fedavg_tree_matches_core():
    from repro.core import aggregation
    key = jax.random.key(0)
    p = {"a": jax.random.normal(key, (6, 10, 3)),
         "b": jax.random.normal(jax.random.fold_in(key, 1), (6, 7))}
    np.testing.assert_allclose(
        np.asarray(fedavg_tree(p, use_kernel=True)["a"]),
        np.asarray(aggregation.fedavg(p)["a"]), atol=1e-5)


# ---------------------------------------------------------------------------
# pow hash
# ---------------------------------------------------------------------------

POW_CASES = [(123, 456, 0, 4096, 512), (0xDEAD, 0xBEEF, 1000, 3000, 1024),
             (7, 9, 0, 100, 64), (1, 1, 0, 1, 16)]


@pytest.mark.parametrize("case", POW_CASES, ids=lambda c: f"n{c[3]}b{c[4]}")
def test_pow_kernel_matches_ref(case):
    ph, pay, off, n, blk = case
    kh, kn = pow_search_kernel(jnp.uint32(ph), jnp.uint32(pay),
                               jnp.uint32(off), n, block=blk, interpret=True)
    rh, rn = pow_search_ref(jnp.uint32(ph), jnp.uint32(pay), off, n)
    assert int(kh) == int(rh)
    assert int(kn) == int(rn)


def test_mine_matches_core_mining():
    bh, bn = mine(jnp.uint32(11), jnp.uint32(22), jnp.uint32(3),
                  n_attempts=2048, use_kernel=True)
    ch, cn = mining.pow_search(jnp.uint32(11), jnp.uint32(22), jnp.uint32(3),
                               2048)
    assert int(bh) == int(ch)
    assert int(bn) == int(cn)


# ---------------------------------------------------------------------------
# ssm scan (S6 selective scan, VMEM-resident state)
# ---------------------------------------------------------------------------

SSM_CASES = [(2, 64, 128, 16, 16, 64), (1, 128, 256, 8, 32, 128),
             (2, 32, 64, 4, 32, 32), (1, 16, 32, 16, 16, 32)]


@pytest.mark.parametrize("case", SSM_CASES,
                         ids=lambda c: f"B{c[0]}T{c[1]}d{c[2]}s{c[3]}")
def test_ssm_scan_allclose(case):
    from repro.kernels.ssm_scan import ssm_scan, ssm_scan_ref
    b, t, d_in, ds, tt, td = case
    ks = jax.random.split(jax.random.key(t + d_in), 6)
    u = jax.random.normal(ks[0], (b, t, d_in))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, d_in)) - 2)
    bm = jax.random.normal(ks[2], (b, t, ds))
    cm = jax.random.normal(ks[3], (b, t, ds))
    a = -jnp.exp(jax.random.normal(ks[4], (d_in, ds)) * 0.3)
    d = jnp.ones((d_in,))
    y_k, h_k = ssm_scan(u, dt, bm, cm, a, d, tile_t=tt, tile_d=td,
                        interpret=True)
    y_r, h_r = ssm_scan_ref(u, dt, bm, cm, a, d)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=2e-5)


def test_ssm_forward_with_kernel_flag(monkeypatch):
    """models.ssm end-to-end parity: lax.scan path vs Pallas kernel path."""
    monkeypatch.setenv("REPRO_SSM_KERNEL", "0")
    import jax as _jax
    from repro.configs import get_smoke_arch
    from repro.models import ssm as ssm_lib
    cfg = get_smoke_arch("jamba-1.5-large-398b")
    key = _jax.random.key(0)
    params = ssm_lib.init_ssm(key, cfg)
    x = _jax.random.normal(_jax.random.fold_in(key, 1), (2, 32, cfg.d_model))
    y_ref, st_ref = ssm_lib.ssm_forward(params, cfg, x)
    monkeypatch.setenv("REPRO_SSM_KERNEL", "1")
    y_k, st_k = ssm_lib.ssm_forward(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_k["h"]), np.asarray(st_ref["h"]),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# chunkwise mLSTM (perf variant) vs sequential oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", [(2, 256, 32), (3, 64, 16), (2, 512, 128),
                                  (1, 96, 32)],
                         ids=lambda c: f"B{c[0]}T{c[1]}L{c[2]}")
def test_mlstm_chunkwise_matches_sequential(case):
    from repro.configs import get_smoke_arch
    from repro.models import xlstm as X
    b, t, chunk = case
    cfg = get_smoke_arch("xlstm-125m")
    key = jax.random.key(0)
    params = X.init_mlstm(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, t), (b, t, cfg.d_model)) * 0.5
    out_seq, st_seq = X.mlstm_forward(params, cfg, x, chunk=0)
    out_chk, st_chk = X.mlstm_forward(params, cfg, x, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out_chk), np.asarray(out_seq),
                               atol=3e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_chk["C"]), np.asarray(st_seq["C"]),
                               atol=3e-5, rtol=1e-4)


def test_mla_materialized_matches_absorbed():
    from repro.configs import get_smoke_arch
    from repro.models import attention as A
    cfg = get_smoke_arch("deepseek-v2-236b")
    key = jax.random.key(0)
    p = A.init_attention(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 24, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(24), (2, 24))
    mask_info = {"causal": True, "prefix_len": 0, "window": 0}
    o1, _ = A.mla_forward(p, cfg, x, pos, mask_info, absorbed=True)
    o2, _ = A.mla_forward(p, cfg, x, pos, mask_info, absorbed=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


@pytest.mark.parametrize("case", [(True, 0, 0), (True, 16, 0), (True, 0, 8),
                                  (False, 0, 0)],
                         ids=["causal", "window", "prefix", "bidir"])
def test_sdpa_chunked_matches_dense(case, monkeypatch):
    """A1: q-chunked online attention == dense [S,S]-mask attention."""
    from repro.models import attention as A
    causal, window, prefix = case
    b, s, h, hd = 2, 64, 2, 16
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    mask = A.build_mask(s, causal=causal, prefix_len=prefix,
                        sliding_window=window)
    dense = A._sdpa(q, k, v, mask, hd ** -0.5)
    chunked = A._sdpa_chunked(q, k, v, hd ** -0.5, causal=causal,
                              window=window, prefix_len=prefix, chunk=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               atol=3e-6, rtol=1e-5)


def test_forward_uses_chunked_above_threshold(monkeypatch):
    """End-to-end: lowering the threshold flips the path; outputs match."""
    from repro.models import attention as A
    from repro.configs import get_smoke_arch
    from repro.models import registry, transformer
    from repro.configs.base import ShapeConfig
    cfg = get_smoke_arch("phi4-mini-3.8b")
    key = jax.random.key(0)
    params = registry.init_model(key, cfg)
    batch = registry.make_prefill_batch(key, cfg, ShapeConfig("t", 64, 2, "prefill"))
    x, _, _ = transformer._embed_inputs(params, cfg, batch)
    h1, _, _ = transformer.forward(params, cfg, x, remat=False)
    monkeypatch.setattr(A, "SDPA_CHUNK_THRESHOLD", 16)
    h2, _, _ = transformer.forward(params, cfg, x, remat=False)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h1), atol=1e-4,
                               rtol=1e-4)
