"""Per-kernel allclose vs pure-jnp oracle, swept over shapes and dtypes
(interpret=True executes the kernel body on CPU). The PoW grid section is
EXACT (uint32 race outcomes, ulp=0 by construction); the fused-mix section is
tolerance tier (tests/equivalence.py helpers)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from equivalence import assert_trees_close
from repro.core import mining
from repro.kernels.fedavg import (digest_divergence_tree, fedavg_flat,
                                  fedavg_flat_ref, fedavg_tree,
                                  mix_rows_flat, mix_rows_tree)
from repro.kernels.flash_attention import attention_ref, flash_attention, mha
from repro.kernels.pow_hash import (mine, pow_race, pow_search_kernel,
                                    pow_search_ref)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # b, h, s, d, causal, window, bq, bk
    (2, 4, 256, 64, True, 0, 128, 128),
    (1, 2, 128, 32, False, 0, 64, 64),
    (2, 2, 256, 64, True, 64, 64, 128),
    (1, 1, 512, 128, True, 0, 128, 128),
    (1, 2, 128, 16, True, 32, 32, 64),
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=lambda c: f"b{c[0]}h{c[1]}s{c[2]}d{c[3]}c{int(c[4])}w{c[5]}")
def test_flash_attention_allclose(case):
    b, h, s, d, causal, window, bq, bk = case
    ks = jax.random.split(jax.random.key(s + d), 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, h, s, d))
    v = jax.random.normal(ks[2], (b, h, s, d))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 128, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 128, 64)).astype(dtype)
    out = flash_attention(q, k, v, interpret=True)
    ref = attention_ref(q, k, v)
    atol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)
    assert out.dtype == dtype


def test_mha_gqa_expansion():
    ks = jax.random.split(jax.random.key(9), 3)
    b, s, hq, hkv, d = 2, 128, 8, 2, 32
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    out_k = mha(q, k, v, block_q=64, block_k=64, use_kernel=True)
    out_r = mha(q, k, v, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# fedavg
# ---------------------------------------------------------------------------

FEDAVG_CASES = [
    (8, 1000, jnp.float32, False, 512),
    (20, 5000, jnp.float32, True, 1024),
    (16, 2048, jnp.bfloat16, True, 256),
    (4, 33, jnp.float32, False, 64),
    (2, 7, jnp.float32, True, 2048),
]


@pytest.mark.parametrize("case", FEDAVG_CASES,
                         ids=lambda c: f"c{c[0]}n{c[1]}{c[2].__name__}")
def test_fedavg_allclose(case):
    c, n, dtype, with_noise, block = case
    ks = jax.random.split(jax.random.key(c * n), 3)
    x = jax.random.normal(ks[0], (c, n)).astype(dtype)
    w = jax.nn.softmax(jax.random.normal(ks[1], (c,)))
    nz = (jax.random.normal(ks[2], (c, n)).astype(dtype) * 0.1
          if with_noise else None)
    out = fedavg_flat(x, w, nz, block_n=block, interpret=True)
    ref = fedavg_flat_ref(x, w, nz)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=1e-5)


def test_fedavg_tree_matches_core():
    from repro.core import aggregation
    key = jax.random.key(0)
    p = {"a": jax.random.normal(key, (6, 10, 3)),
         "b": jax.random.normal(jax.random.fold_in(key, 1), (6, 7))}
    np.testing.assert_allclose(
        np.asarray(fedavg_tree(p, use_kernel=True)["a"]),
        np.asarray(aggregation.fedavg(p)["a"]), atol=1e-5)


# ---------------------------------------------------------------------------
# pow hash
# ---------------------------------------------------------------------------

POW_CASES = [(123, 456, 0, 4096, 512), (0xDEAD, 0xBEEF, 1000, 3000, 1024),
             (7, 9, 0, 100, 64), (1, 1, 0, 1, 16)]


@pytest.mark.parametrize("case", POW_CASES, ids=lambda c: f"n{c[3]}b{c[4]}")
def test_pow_kernel_matches_ref(case):
    ph, pay, off, n, blk = case
    kh, kn = pow_search_kernel(jnp.uint32(ph), jnp.uint32(pay),
                               jnp.uint32(off), n, block=blk, interpret=True)
    rh, rn = pow_search_ref(jnp.uint32(ph), jnp.uint32(pay), off, n)
    assert int(kh) == int(rh)
    assert int(kn) == int(rn)


def test_mine_matches_core_mining():
    bh, bn = mine(jnp.uint32(11), jnp.uint32(22), jnp.uint32(3),
                  n_attempts=2048, use_kernel=True)
    ch, cn = mining.pow_search(jnp.uint32(11), jnp.uint32(22), jnp.uint32(3),
                               2048)
    assert int(bh) == int(ch)
    assert int(bn) == int(cn)


def test_client_salt_is_the_shared_definition():
    """Both paths salt through mining.client_salt — one definition of the
    disjoint nonce spaces. The helper must broadcast and equal the inline
    avalanche it replaced."""
    ids = jnp.arange(16, dtype=jnp.uint32)
    want = mining._avalanche(ids * mining._M2)
    np.testing.assert_array_equal(np.asarray(mining.client_salt(ids)),
                                  np.asarray(want))
    # scalar form matches the vector form elementwise
    assert int(mining.client_salt(jnp.uint32(7))) == int(want[7])


# 2-D (clients x nonce chunks) grid race: EXACT uint32 equality (ulp=0)
# against both the brute-force ref and the chunked fori_loop engine path,
# including budgets that do not divide the chunk (tail-mask semantics).
POW_GRID_CASES = [
    # n_attempts, chunk
    (4096, 512),     # divisible
    (3000, 1024),    # non-divisible tail
    (1500, 1024),    # non-divisible, 2 chunks
    (100, 64),       # tiny non-divisible
    (1, 16),         # single attempt, chunk > budget
    (1000, 384),     # non-divisible, odd chunk
]


@pytest.mark.parametrize("case", POW_GRID_CASES,
                         ids=lambda c: f"n{c[0]}b{c[1]}")
def test_pow_race_grid_matches_ref_exact(case):
    n, chunk = case
    ids = jnp.arange(5, dtype=jnp.uint32)
    ph, dig, off = jnp.uint32(123), jnp.uint32(456), jnp.uint32(7 << 10)
    gh, gn = pow_race(ph, dig, ids, n, nonce_offset=off, chunk=chunk,
                      interpret=True)
    rh, rn = jax.vmap(lambda c: pow_search_ref(
        ph, dig ^ mining.client_salt(c), off, n))(ids)
    np.testing.assert_array_equal(np.asarray(gh), np.asarray(rh))
    np.testing.assert_array_equal(np.asarray(gn), np.asarray(rn))


@pytest.mark.parametrize("case", POW_GRID_CASES,
                         ids=lambda c: f"n{c[0]}b{c[1]}")
def test_pow_race_grid_matches_fori_loop_exact(case):
    """Grid vs the engine's vmap(fori_loop) path at the SAME chunk — the
    bitwise dispatch contract of make_mine(use_kernel=True)."""
    n, chunk = case
    ids = jnp.arange(6, dtype=jnp.uint32) + jnp.uint32(3)  # offset ids too
    ph, dig, off = jnp.uint32(0xDEAD), jnp.uint32(0xBEEF), jnp.uint32(1 << 20)
    gh, gn = pow_race(ph, dig, ids, n, nonce_offset=off, chunk=chunk,
                      interpret=True)
    vh, vn = jax.vmap(lambda c: mining.pow_search(
        ph, dig, c, n, nonce_offset=off, chunk=chunk))(ids)
    np.testing.assert_array_equal(np.asarray(gh), np.asarray(vh))
    np.testing.assert_array_equal(np.asarray(gn), np.asarray(vn))


def test_pow_race_chunk_invariant():
    """The race outcome is bitwise independent of the grid tile size
    (running min + first-tie argmin == full-range argmin)."""
    ids = jnp.arange(4, dtype=jnp.uint32)
    outs = [pow_race(jnp.uint32(5), jnp.uint32(9), ids, 3000,
                     nonce_offset=0, chunk=c, interpret=True)
            for c in (64, 500, 1024, 3000)]
    for h, n in outs[1:]:
        np.testing.assert_array_equal(np.asarray(h), np.asarray(outs[0][0]))
        np.testing.assert_array_equal(np.asarray(n), np.asarray(outs[0][1]))


def test_pow_race_rejects_bad_budget():
    ids = jnp.arange(2, dtype=jnp.uint32)
    with pytest.raises(ValueError):
        pow_race(jnp.uint32(1), jnp.uint32(2), ids, 0, interpret=True)


# ---------------------------------------------------------------------------
# fused mix (row-block matmul) + fused digest/divergence — tolerance tier
# ---------------------------------------------------------------------------


MIX_CASES = [
    # C, R, N, block_n
    (8, 8, 1000, 512),
    (6, 2, 333, 64),      # row subset + non-divisible N
    (20, 5, 5000, 2048),
    (4, 4, 7, 16),        # N smaller than the block
]


@pytest.mark.parametrize("case", MIX_CASES,
                         ids=lambda c: f"C{c[0]}R{c[1]}N{c[2]}")
def test_mix_rows_flat_matches_dense(case):
    c, r, n, block = case
    ks = jax.random.split(jax.random.key(c * n), 2)
    w = jax.nn.softmax(jax.random.normal(ks[0], (c, c)), axis=1)[:r]
    x = jax.random.normal(ks[1], (c, n))
    out = mix_rows_flat(w, x, block_n=block, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(w @ x),
                               atol=1e-5, rtol=1e-5)


def test_mix_rows_flat_rejects_shape_mismatch():
    with pytest.raises(ValueError):
        mix_rows_flat(jnp.zeros((2, 3)), jnp.zeros((4, 5)), interpret=True)


def test_mix_gather_kernel_matches_aggregation_mix():
    """fused-mix-vs-aggregation.mix at the tolerance tier (the fused kernel's
    contraction order replaces XLA's)."""
    from repro.core import aggregation
    key = jax.random.key(0)
    p = {"a": jax.random.normal(key, (6, 10, 3)),
         "b": jax.random.normal(jax.random.fold_in(key, 1), (6, 7)),
         "c": jax.random.normal(jax.random.fold_in(key, 2), (6, 2, 2, 5))}
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 3), (6, 6)),
                       axis=1)
    weights = jnp.arange(1.0, 7.0)
    got = aggregation.mix_gather(p, w, weights, use_kernel=True,
                                 interpret=True)
    want = aggregation.mix(p, w, weights)
    assert_trees_close(got, want, rtol=1e-5, atol=1e-6)
    # mix_psum_dense's single-device use_kernel form routes the same way
    got2 = aggregation.mix_psum_dense(p, w, weights, use_kernel=True,
                                      interpret=True)
    assert_trees_close(got2, want, rtol=1e-5, atol=1e-6)


def test_mix_rows_tree_row_subset_shapes():
    p = {"a": jnp.ones((4, 3, 2)), "b": jnp.ones((4, 5))}
    w_rows = jnp.full((2, 4), 0.25)
    out = mix_rows_tree(p, w_rows, interpret=True)
    assert out["a"].shape == (2, 3, 2) and out["b"].shape == (2, 5)


def test_digest_divergence_fused_sweep():
    """One fused sweep == digest_tree + client_divergence up to the
    documented contract: divergence to fp32 tolerance; the digest is
    deterministic and model-sensitive but FORKS from the jnp fold (tile
    partials reassociate the leaf sums)."""
    from repro.core import aggregation
    key = jax.random.key(1)
    p = {"w1": jax.random.normal(key, (8, 33, 5)),
         "b": jax.random.normal(jax.random.fold_in(key, 1), (8, 9))}
    dig, div = digest_divergence_tree(p, interpret=True)
    np.testing.assert_allclose(float(div),
                               float(aggregation.client_divergence(p)),
                               rtol=1e-5)
    dig2, _ = digest_divergence_tree(p, interpret=True)
    assert int(dig) == int(dig2)          # deterministic
    p_shift = jax.tree.map(lambda x: x + 1e-2, p)
    dig3, _ = digest_divergence_tree(p_shift, interpret=True)
    assert int(dig) != int(dig3)          # fingerprints the model


# ---------------------------------------------------------------------------
# round-loop regressions: make_mine(use_kernel=True) vs the seed path
# ---------------------------------------------------------------------------


def _round_setup(c=6, samples=24):
    from repro.data.pipeline import FLDataSource
    from repro.models.mlp import init_mlp
    key = jax.random.key(0)
    src = FLDataSource(key, c, samples, seed=0)
    params = init_mlp(jax.random.fold_in(key, 1))
    return params, src.static_batch(), jax.random.fold_in(key, 2)


def test_round_loop_pow_kernel_bitwise_vs_seed():
    """The whole K-round engine with the Pallas PoW grid is bitwise the
    fori_loop engine: params, every metric, every ledger hash — at a
    non-divisible (mine_attempts, mine_chunk)."""
    import dataclasses
    from repro.core import rounds, topology
    from repro.models.mlp import mlp_loss
    params, batch, rk = _round_setup()
    spec = rounds.RoundSpec(n_clients=6, tau=2, eta=0.1, n_lazy=1,
                            sigma2=0.01, mine_attempts=1000,
                            difficulty_bits=2, mine_chunk=384,
                            topology=topology.from_name("random:0.8"))
    spec_k = dataclasses.replace(spec, use_kernel=True, kernel_interpret=True)
    s0, h0, l0 = rounds.run_blade_fl_scan(mlp_loss, spec, params, batch,
                                          rk, 3)
    s1, h1, l1 = rounds.run_blade_fl_scan(mlp_loss, spec_k, params, batch,
                                          rk, 3)
    for a, b in zip(jax.tree.leaves(s0.params), jax.tree.leaves(s1.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert h0 == h1
    assert [b.header_hash for b in l0.blocks] == \
           [b.header_hash for b in l1.blocks]
    assert l1.validate_chain()


@pytest.mark.slow
def test_round_loop_pow_kernel_4device_regression_subprocess():
    """make_mine(use_kernel=True)-vs-seed on the 4-fake-device lane: the
    client-sharded scan with the Pallas PoW grid reproduces the single-device
    seed path (use_kernel=False) bit for bit — params, history, ledger."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import dataclasses, json, math
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.core import rounds, topology
        from repro.data.pipeline import FLDataSource
        from repro.models.mlp import init_mlp, mlp_loss

        C, K = 8, 3
        key = jax.random.key(0)
        src = FLDataSource(key, C, samples_per_client=32, seed=0)
        params = init_mlp(jax.random.fold_in(key, 1))
        mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))
        rk = jax.random.fold_in(key, 2)

        def eqf(a, b):
            return a == b or (isinstance(a, float)
                              and math.isnan(a) and math.isnan(b))

        out = {}
        for name, topo in [("full_mesh", topology.FullMesh()),
                           ("random_graph", topology.RandomGraph(p_link=0.6)),
                           ("ring1", topology.Ring(neighbors=1))]:
            spec = rounds.RoundSpec(n_clients=C, tau=2, eta=0.1, n_lazy=1,
                                    sigma2=0.05, mine_attempts=1000,
                                    difficulty_bits=2, mine_chunk=384,
                                    topology=topo)
            spec_k = dataclasses.replace(spec, use_kernel=True,
                                         kernel_interpret=True)
            batch = src.static_batch()
            st1, h1, l1 = rounds.run_blade_fl_scan(
                mlp_loss, spec, params, batch, rk, K)          # seed path
            st2, h2, l2 = rounds.run_blade_fl_scan(
                mlp_loss, spec_k, params, batch, rk, K, mesh=mesh)
            out[name] = {
                "params_bitwise": all(
                    bool((np.asarray(a) == np.asarray(b)).all())
                    for a, b in zip(jax.tree.leaves(st1.params),
                                    jax.tree.leaves(st2.params))),
                "history_bitwise": all(
                    eqf(a[k], b[k]) for a, b in zip(h1, h2) for k in a),
                "ledger_bitwise": [b.header_hash for b in l1.blocks]
                    == [b.header_hash for b in l2.blocks],
                "chain_valid": l2.validate_chain(),
            }
        print(json.dumps(out))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    for name, r in res.items():
        assert r["params_bitwise"], (name, r)
        assert r["history_bitwise"], (name, r)
        assert r["ledger_bitwise"], (name, r)
        assert r["chain_valid"], (name, r)


# ---------------------------------------------------------------------------
# ssm scan (S6 selective scan, VMEM-resident state)
# ---------------------------------------------------------------------------

SSM_CASES = [(2, 64, 128, 16, 16, 64), (1, 128, 256, 8, 32, 128),
             (2, 32, 64, 4, 32, 32), (1, 16, 32, 16, 16, 32)]


@pytest.mark.parametrize("case", SSM_CASES,
                         ids=lambda c: f"B{c[0]}T{c[1]}d{c[2]}s{c[3]}")
def test_ssm_scan_allclose(case):
    from repro.kernels.ssm_scan import ssm_scan, ssm_scan_ref
    b, t, d_in, ds, tt, td = case
    ks = jax.random.split(jax.random.key(t + d_in), 6)
    u = jax.random.normal(ks[0], (b, t, d_in))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, d_in)) - 2)
    bm = jax.random.normal(ks[2], (b, t, ds))
    cm = jax.random.normal(ks[3], (b, t, ds))
    a = -jnp.exp(jax.random.normal(ks[4], (d_in, ds)) * 0.3)
    d = jnp.ones((d_in,))
    y_k, h_k = ssm_scan(u, dt, bm, cm, a, d, tile_t=tt, tile_d=td,
                        interpret=True)
    y_r, h_r = ssm_scan_ref(u, dt, bm, cm, a, d)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), atol=2e-5)


def test_ssm_forward_with_kernel_flag(monkeypatch):
    """models.ssm end-to-end parity: lax.scan path vs Pallas kernel path."""
    monkeypatch.setenv("REPRO_SSM_KERNEL", "0")
    import jax as _jax
    from repro.configs import get_smoke_arch
    from repro.models import ssm as ssm_lib
    cfg = get_smoke_arch("jamba-1.5-large-398b")
    key = _jax.random.key(0)
    params = ssm_lib.init_ssm(key, cfg)
    x = _jax.random.normal(_jax.random.fold_in(key, 1), (2, 32, cfg.d_model))
    y_ref, st_ref = ssm_lib.ssm_forward(params, cfg, x)
    monkeypatch.setenv("REPRO_SSM_KERNEL", "1")
    y_k, st_k = ssm_lib.ssm_forward(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_k["h"]), np.asarray(st_ref["h"]),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# chunkwise mLSTM (perf variant) vs sequential oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", [(2, 256, 32), (3, 64, 16), (2, 512, 128),
                                  (1, 96, 32)],
                         ids=lambda c: f"B{c[0]}T{c[1]}L{c[2]}")
def test_mlstm_chunkwise_matches_sequential(case):
    from repro.configs import get_smoke_arch
    from repro.models import xlstm as X
    b, t, chunk = case
    cfg = get_smoke_arch("xlstm-125m")
    key = jax.random.key(0)
    params = X.init_mlstm(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, t), (b, t, cfg.d_model)) * 0.5
    out_seq, st_seq = X.mlstm_forward(params, cfg, x, chunk=0)
    out_chk, st_chk = X.mlstm_forward(params, cfg, x, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out_chk), np.asarray(out_seq),
                               atol=3e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_chk["C"]), np.asarray(st_seq["C"]),
                               atol=3e-5, rtol=1e-4)


def test_mla_materialized_matches_absorbed():
    from repro.configs import get_smoke_arch
    from repro.models import attention as A
    cfg = get_smoke_arch("deepseek-v2-236b")
    key = jax.random.key(0)
    p = A.init_attention(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 24, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(24), (2, 24))
    mask_info = {"causal": True, "prefix_len": 0, "window": 0}
    o1, _ = A.mla_forward(p, cfg, x, pos, mask_info, absorbed=True)
    o2, _ = A.mla_forward(p, cfg, x, pos, mask_info, absorbed=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


@pytest.mark.parametrize("case", [(True, 0, 0), (True, 16, 0), (True, 0, 8),
                                  (False, 0, 0)],
                         ids=["causal", "window", "prefix", "bidir"])
def test_sdpa_chunked_matches_dense(case, monkeypatch):
    """A1: q-chunked online attention == dense [S,S]-mask attention."""
    from repro.models import attention as A
    causal, window, prefix = case
    b, s, h, hd = 2, 64, 2, 16
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    mask = A.build_mask(s, causal=causal, prefix_len=prefix,
                        sliding_window=window)
    dense = A._sdpa(q, k, v, mask, hd ** -0.5)
    chunked = A._sdpa_chunked(q, k, v, hd ** -0.5, causal=causal,
                              window=window, prefix_len=prefix, chunk=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               atol=3e-6, rtol=1e-5)


def test_forward_uses_chunked_above_threshold(monkeypatch):
    """End-to-end: lowering the threshold flips the path; outputs match."""
    from repro.models import attention as A
    from repro.configs import get_smoke_arch
    from repro.models import registry, transformer
    from repro.configs.base import ShapeConfig
    cfg = get_smoke_arch("phi4-mini-3.8b")
    key = jax.random.key(0)
    params = registry.init_model(key, cfg)
    batch = registry.make_prefill_batch(key, cfg, ShapeConfig("t", 64, 2, "prefill"))
    x, _, _ = transformer._embed_inputs(params, cfg, batch)
    h1, _, _ = transformer.forward(params, cfg, x, remat=False)
    monkeypatch.setattr(A, "SDPA_CHUNK_THRESHOLD", 16)
    h2, _, _ = transformer.forward(params, cfg, x, remat=False)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h1), atol=1e-4,
                               rtol=1e-4)
