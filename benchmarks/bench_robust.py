"""Attack x defense sweep: what each robust aggregator buys under each
Byzantine attack, and what it costs.

Grid: the shipped attack zoo (core/attacks.py) crossed with the linear mean
and the three robust consensus reducers (RoundSpec.robust_agg). Every cell
is a full compiled-scan BLADE-FL run; the table reports the held-out loss /
accuracy of the final aggregate, the attacked-run loss gap against the
clean baseline under the same aggregator, and wall clock. The robust rows
also carry their communication price: a gathered mix moves
``plans.gathered_mix_models_moved(C, D)`` models per device per round where
the psum fast tier moves O(1) — the volume robust order statistics cannot
reclaim (not psum-associative).

A second sweep scales the sign-flip strength to show the breakdown
structure: the linear mean degrades with attack scale (unbounded), the
trimmed mean's loss stays flat (bounded by the honest envelope).

  PYTHONPATH=src python -m benchmarks.bench_robust [--samples 64]
"""
from __future__ import annotations

import argparse
import time

import jax

from benchmarks import common
from repro.core import attacks, rounds
from repro.core.aggregation import aggregate_once
from repro.models.mlp import init_mlp, mlp_loss
from repro.sharding import plans

ATTACKS = (
    ("clean", None),
    ("signflip2", attacks.SignFlip(n_attackers=3, scale=2.0)),
    ("alie", attacks.ALIE(n_attackers=3, z=1.5)),
    ("replace", attacks.ModelReplacement(n_attackers=1)),
)

AGGREGATORS = ("mean", "median", "trimmed:3", "geomed:8")


def _run_cell(src, params, *, n_clients, k, tau, atk, robust, seed):
    spec = rounds.RoundSpec(
        n_clients=n_clients, tau=tau, eta=0.05, mine_attempts=32,
        difficulty_bits=2, attack=atk,
        robust_agg=None if robust == "mean" else robust)
    key = jax.random.key(seed)
    t0 = time.time()
    state, hist, ledger = rounds.run_blade_fl(
        mlp_loss, spec, params, src.static_batch(),
        jax.random.fold_in(key, 2), k)
    wall = time.time() - t0
    final = aggregate_once(state.params)
    eval_loss, m = mlp_loss(final, src.eval_data)
    return {
        "eval_loss": float(eval_loss), "accuracy": float(m["accuracy"]),
        "final_loss": hist[-1]["global_loss"],
        "chain_valid": ledger.validate_chain(),
        "wall_s": wall, "us_per_round": wall / k * 1e6,
    }


def bench(samples: int = 64, n_clients: int = 16, k: int = 6, tau: int = 2,
          seed: int = 0) -> dict:
    src = common.build_source(n_clients=n_clients, samples=samples,
                              seed=seed)
    params = init_mlp(jax.random.fold_in(jax.random.key(seed), 1))
    # the gathered-mix price every robust aggregator pays on a 4-way mesh
    moved = plans.gathered_mix_models_moved(n_clients, 4)

    results = {"models_moved_per_device_4way": moved}
    print(f"{'attack':>10} {'aggregator':>10} {'eval_loss':>9} "
          f"{'accuracy':>8} {'loss_gap':>9} {'us_per_round':>12}")
    for agg in AGGREGATORS:
        clean = None
        for atk_name, atk in ATTACKS:
            cell = _run_cell(src, params, n_clients=n_clients, k=k, tau=tau,
                             atk=atk, robust=agg, seed=seed)
            if atk_name == "clean":
                clean = cell["eval_loss"]
            cell["loss_gap_vs_clean"] = cell["eval_loss"] - clean
            results[f"{agg}|{atk_name}"] = cell
            print(f"{atk_name:>10} {agg:>10} {cell['eval_loss']:>9.4f} "
                  f"{cell['accuracy']:>8.3f} "
                  f"{cell['loss_gap_vs_clean']:>9.4f} "
                  f"{cell['us_per_round']:>12.0f}")
            common.csv_line(
                f"robust_{agg.replace(':', '_')}_{atk_name}",
                cell["us_per_round"],
                f"eval_loss={cell['eval_loss']:.4f} "
                f"gap={cell['loss_gap_vs_clean']:.4f} moved={moved}")

    # breakdown structure: loss vs sign-flip scale, mean vs trimmed
    strength = {}
    for scale in (1.0, 4.0, 16.0):
        atk = attacks.SignFlip(n_attackers=3, scale=scale)
        for agg in ("mean", "trimmed:3"):
            cell = _run_cell(src, params, n_clients=n_clients, k=k, tau=tau,
                             atk=atk, robust=agg, seed=seed)
            strength[f"{agg}|scale{scale:g}"] = cell["eval_loss"]
            common.csv_line(
                f"robust_strength_{agg.replace(':', '_')}_s{scale:g}",
                cell["us_per_round"], f"eval_loss={cell['eval_loss']:.4f}")
    results["signflip_strength_sweep"] = strength
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=64)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--k", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    bench(samples=args.samples, n_clients=args.clients, k=args.k,
          seed=args.seed)


if __name__ == "__main__":
    main()
