"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines and writes the full structured
results to experiments/bench_results.json.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig3,table6
  PYTHONPATH=src python -m benchmarks.run --fast     # mnist proxy only
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import (bench_cohort, bench_hierarchy, bench_kernels,  # noqa: E402
                        bench_multidevice, bench_robust, bench_rounds,
                        bench_schedules, bench_topology, paper_tables,
                        roofline)

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                   "bench_results.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig3,table2,...,fig10,kernels,rounds,"
                         "topology,schedules,cohort,multidevice,hierarchy,"
                         "robust,roofline")
    ap.add_argument("--fast", action="store_true",
                    help="mnist proxy only (skip fashion)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    datasets = ["mnist"] if args.fast else ["mnist", "fashion"]

    benches = {
        "fig3": lambda ds: paper_tables.fig3_bound_gap(ds, args.seed),
        "table2": lambda ds: paper_tables.table2_alpha(ds, args.seed),
        "table3": lambda ds: paper_tables.table3_beta(ds, args.seed),
        "table4": lambda ds: paper_tables.table4_clients(ds, args.seed),
        "table5": lambda ds: paper_tables.table5_eta(ds, args.seed),
        "table6": lambda ds: paper_tables.table6_lazy(ds, args.seed),
        "table7": lambda ds: paper_tables.table7_sigma(ds, args.seed),
        "fig10": lambda ds: paper_tables.fig10_dp(ds, args.seed),
    }

    results = {}
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in benches.items():
        if only and name not in only:
            continue
        for ds in datasets:
            try:
                results[f"{name}_{ds}"] = fn(ds)
            except Exception as e:  # keep the harness running
                print(f"{name}_{ds},0,ERROR:{type(e).__name__}:{e}",
                      flush=True)
                results[f"{name}_{ds}"] = {"error": str(e)}
    if only is None or "kernels" in only:
        results["kernels"] = bench_kernels.run()
    if only is None or "rounds" in only:
        results["rounds_scan_vs_loop"] = bench_rounds.bench()
        results["rounds_kernel_path"] = bench_rounds.bench_kernel_path()
    if only is None or "topology" in only:
        results["topology_loss_vs_k"] = bench_topology.bench()
    if only is None or "schedules" in only:
        results["schedules_loss_vs_k"] = bench_schedules.bench()
    if only is None or "cohort" in only:
        results["cohort_population_scaling"] = bench_cohort.bench()
    if only is None or "multidevice" in only:
        results["multidevice_rounds_per_s"] = bench_multidevice.bench()
    if only is None or "hierarchy" in only:
        results["hierarchy_flat_vs_cluster"] = bench_hierarchy.bench()
    if only is None or "robust" in only:
        results["robust_attack_defense"] = bench_robust.bench()
    if only is None or "roofline" in only:
        results["roofline_pod16x16"] = roofline.run("pod16x16")
        results["roofline_pod2x16x16"] = roofline.run("pod2x16x16")

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    if only is not None and os.path.exists(OUT):
        # partial runs merge over the previous results instead of dropping
        # every section they didn't re-run
        with open(OUT) as f:
            merged = json.load(f)
        merged.update(results)
        results = merged
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"# total {time.time() - t0:.1f}s -> {OUT}")


if __name__ == "__main__":
    main()
