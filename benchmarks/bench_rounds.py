"""Round-loop throughput: per-round Python driver vs the compiled lax.scan
engine (core/rounds.run_blade_fl_scan).

The Python loop pays one dispatch per round plus an ``int()``/``float()``
host sync per metric per round; the scan engine runs all K integrated rounds
on device and transfers once. Both paths are timed warm (compile excluded),
so the gap shown is pure per-round dispatch + sync overhead — the quantity
the ROADMAP's "fast as the hardware allows" target cares about.

At the paper-scale default (C=20, 128 samples) the scan path measures
~1.1-1.2x the per-round driver on CPU with the current engine (the PR 1
monolithic round measured ~2x; the stage pipeline and the fusion barriers
behind the sharded engine's bitwise contract narrowed the CPU gap — see
README "Current benchmark anchors"). At toy sizes (C<=4, <=32 samples)
XLA:CPU executes
the per-round program faster than the same body nested in the scan's while
loop — a dispatch-vs-loop-overhead crossover, not a bug; see
"Micro-sim dispatch behavior" in docs/architecture.md for the explanation
and the rule of thumb (use the scan engine at paper scale and above, the
per-round driver for micro-sims below the crossover).

``bench_kernel_path`` times the same scan with the Pallas tier on —
``use_kernel`` (bitwise PoW grid) and ``use_kernel + fused_mix`` (tolerance
mix + one-sweep diagnostics) — against the kernel-off engine at a budget
above the dispatch threshold, so the JSON records kernel-on vs kernel-off
rounds/sec plus the analytic bytes the fused path saves
(``roofline.round_hot_block_bytes``). Interpret-mode wall-clock on CPU is a
COST number (the kernel body runs as jnp per grid step); the bitwise/
tolerance contracts are what transfer to a real TPU lowering.

  PYTHONPATH=src python -m benchmarks.bench_rounds [--rounds 32] [--clients 20]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from benchmarks import common, roofline
from repro.core import rounds
from repro.data.pipeline import FLDataSource
from repro.models.mlp import init_mlp, mlp_loss


def _setup(n_clients: int, samples: int, tau: int,
           mine_attempts: int = 256):
    key = jax.random.key(0)
    src = FLDataSource(key, n_clients, samples, seed=0)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(n_clients=n_clients, tau=tau, eta=0.05,
                            n_lazy=2, sigma2=0.01,
                            mine_attempts=mine_attempts,
                            difficulty_bits=2)
    return spec, params, src.static_batch(), jax.random.fold_in(key, 2)


def bench(n_rounds: int = 32, n_clients: int = 20, samples: int = 128,
          tau: int = 4, reps: int = 3) -> dict:
    spec, params, batch, key = _setup(n_clients, samples, tau)

    def python_loop_jit():
        # per-round jit dispatch, callable batch keeps it off the scan path
        return rounds.run_blade_fl(mlp_loss, spec, params, lambda k: batch,
                                   key, n_rounds)

    def scan():
        return rounds.run_blade_fl_scan(mlp_loss, spec, params, batch, key,
                                        n_rounds)

    out = {}
    for name, fn in (("python_loop_jit", python_loop_jit), ("scan", scan)):
        fn()  # warm: compile (scan runner is lru-cached across calls)
        t0 = time.time()
        for _ in range(reps):
            state, hist, ledger = fn()
        wall = (time.time() - t0) / reps
        rps = n_rounds / wall
        out[name] = rps
        common.csv_line(f"rounds_{name}_K{n_rounds}_C{n_clients}",
                        wall / n_rounds * 1e6,
                        f"rounds_per_s={rps:.1f}")
    out["speedup"] = out["scan"] / out["python_loop_jit"]
    print(f"scan speedup over per-round jit driver: {out['speedup']:.2f}x")
    return out


def bench_kernel_path(n_rounds: int = 8, n_clients: int = 20,
                      samples: int = 128, tau: int = 4, reps: int = 3,
                      mine_attempts: int = 1024) -> dict:
    """Kernel-on vs kernel-off rounds/sec through ``run_blade_fl``'s auto
    dispatch (so each row's note records the actual (pow, mix) lowering
    taken) plus the analytic hot-block bytes each tier moves per round."""
    spec_off, params, batch, key = _setup(n_clients, samples, tau,
                                          mine_attempts)
    model_bytes = 4 * sum(x.size for x in jax.tree.leaves(params))
    tiers = {
        "kernel_off": spec_off,
        "pow_kernel": dataclasses.replace(spec_off, use_kernel=True,
                                          kernel_interpret=True),
        "pow_and_fused_mix": dataclasses.replace(spec_off, use_kernel=True,
                                                 fused_mix=True,
                                                 kernel_interpret=True),
    }
    out = {}
    for name, spec in tiers.items():
        def go():
            return rounds.run_blade_fl(mlp_loss, spec, params, batch, key,
                                       n_rounds)
        go()  # warm: compile (scan runner is lru-cached across calls)
        t0 = time.time()
        for _ in range(reps):
            state, hist, ledger = go()
        wall = (time.time() - t0) / reps
        disp = dict(rounds.LAST_DISPATCH)
        est = roofline.round_hot_block_bytes(
            model_bytes, n_clients, mine_attempts,
            fused_mix=spec.fused_mix)
        out[name] = {"rounds_per_s": n_rounds / wall, "wall_s": wall,
                     "dispatch": disp,
                     "est_hot_block_bytes_per_round": est["total_bytes"],
                     "chain_valid": ledger.validate_chain()}
        common.csv_line(
            f"rounds_{name}_K{n_rounds}_C{n_clients}",
            wall / n_rounds * 1e6,
            f"rounds_per_s={n_rounds / wall:.1f};"
            f"dispatch={disp['driver']}/{disp['pow']}/{disp['mix']};"
            f"est_bytes_per_round={est['total_bytes']:.3g}")
    off = out["kernel_off"]
    for name in ("pow_kernel", "pow_and_fused_mix"):
        out[name]["vs_kernel_off"] = (out[name]["rounds_per_s"]
                                      / off["rounds_per_s"])
    out["note"] = ("interpret=True on CPU: kernel rows price the grid's "
                   "structure, not TPU wall-clock; bytes column is the "
                   "transferable win")
    return out


def run():
    out = {"scan_vs_loop": bench()}
    out["kernel_path"] = bench_kernel_path()
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=32)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--samples", type=int, default=128)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--reps", type=int, default=3)
    a = ap.parse_args()
    bench(a.rounds, a.clients, a.samples, a.tau, a.reps)
    bench_kernel_path(min(a.rounds, 8), a.clients, a.samples, a.tau, a.reps)
