"""Kernel micro-benchmarks: wall time of the jnp reference path (the
interpret-mode Pallas timing is not hardware-representative — correctness is
asserted in tests; the TPU-side perf claim is structural: VMEM tiling +
online softmax remove the [S,S] HBM round-trip).

``bench_pow`` times the Pallas 2-D PoW race next to the fori_loop reference
it is bitwise-equal to, both as mhash/s, and notes which lowering
``run_blade_fl``'s auto dispatch would pick for that budget — so the CSV
shows the kernel's throughput AND whether the engine would actually use it.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import aggregation, mining, rounds
from repro.kernels.flash_attention import attention_ref
from repro.kernels.pow_hash import pow_race


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def bench_attention():
    b, h, s, d = 1, 4, 1024, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, h, s, d))
    v = jax.random.normal(ks[2], (b, h, s, d))
    f = jax.jit(lambda q, k, v: attention_ref(q, k, v))
    us = _time(f, q, k, v)
    flops = 4 * b * h * s * s * d
    common.csv_line("kernel_attention_ref_s1024", us,
                    f"gflops_per_s={flops / us / 1e3:.1f}")
    return {"attention_ref_us": us}


def bench_fedavg():
    c, n = 20, 1_000_000
    x = jax.random.normal(jax.random.key(0), (c, n))
    f = jax.jit(lambda x: aggregation.fedavg({"w": x})["w"])
    us = _time(f, x)
    gb = c * n * 4 * 2 / 1e9
    common.csv_line("kernel_fedavg_20x1M", us,
                    f"gbytes_per_s={gb / (us / 1e6):.1f}")
    return {"fedavg_us": us}


def bench_pow(n_attempts: int = 65536, n_clients: int = 8,
              chunk: int = 2048) -> dict:
    """fori_loop reference vs the Pallas grid race, side by side in mhash/s.

    The interpret-mode grid timing is a structural number (the kernel body
    runs as jnp on CPU); the comparable quantity is hashes/s at the SAME
    total budget C x n_attempts. The note records the lowering
    ``run_blade_fl`` would dispatch for this budget (see
    ``rounds.dispatch_plan``)."""
    # per-client fori_loop engine path, vmapped over the same C clients
    ids = jnp.arange(n_clients, dtype=jnp.uint32)
    ref = jax.jit(lambda ph: jax.vmap(
        lambda c: mining.pow_search(ph, jnp.uint32(1), c, n_attempts,
                                    chunk=chunk)[0])(ids))
    us_ref = _time(ref, jnp.uint32(3))
    total = n_clients * n_attempts
    spec = rounds.RoundSpec(n_clients=n_clients, tau=1, eta=0.1,
                            mine_attempts=n_attempts, use_kernel=True)
    pow_choice = rounds.dispatch_plan(spec, lambda k: None, 1)["pow"]
    common.csv_line(f"kernel_pow_ref_C{n_clients}x{n_attempts // 1024}k",
                    us_ref, f"mhash_per_s={total / us_ref:.2f};"
                            f"dispatch_pow={pow_choice}")
    # the Pallas 2-D (clients x nonce chunks) race, interpret on CPU
    grid = jax.jit(lambda ph: pow_race(ph, jnp.uint32(1), ids, n_attempts,
                                       chunk=chunk, interpret=True)[0])
    us_k = _time(grid, jnp.uint32(3), reps=2)
    common.csv_line(f"kernel_pow_race_C{n_clients}x{n_attempts // 1024}k",
                    us_k, f"mhash_per_s={total / us_k:.2f};interpret=True;"
                          f"dispatch_pow={pow_choice}")
    return {"ref_us": us_ref, "ref_mhash_per_s": total / us_ref,
            "race_interpret_us": us_k,
            "race_interpret_mhash_per_s": total / us_k,
            "dispatch_pow": pow_choice, "n_clients": n_clients,
            "n_attempts": n_attempts, "chunk": chunk}


def run() -> dict:
    out = {}
    out.update(bench_attention())
    out.update(bench_fedavg())
    out["pow"] = bench_pow()
    return out
