"""Kernel micro-benchmarks: wall time of the jnp reference path (the
interpret-mode Pallas timing is not hardware-representative — correctness is
asserted in tests; the TPU-side perf claim is structural: VMEM tiling +
online softmax remove the [S,S] HBM round-trip)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import aggregation, mining
from repro.kernels.flash_attention import attention_ref


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def bench_attention():
    b, h, s, d = 1, 4, 1024, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, h, s, d))
    v = jax.random.normal(ks[2], (b, h, s, d))
    f = jax.jit(lambda q, k, v: attention_ref(q, k, v))
    us = _time(f, q, k, v)
    flops = 4 * b * h * s * s * d
    common.csv_line("kernel_attention_ref_s1024", us,
                    f"gflops_per_s={flops / us / 1e3:.1f}")


def bench_fedavg():
    c, n = 20, 1_000_000
    x = jax.random.normal(jax.random.key(0), (c, n))
    f = jax.jit(lambda x: aggregation.fedavg({"w": x})["w"])
    us = _time(f, x)
    gb = c * n * 4 * 2 / 1e9
    common.csv_line("kernel_fedavg_20x1M", us,
                    f"gbytes_per_s={gb / (us / 1e6):.1f}")


def bench_pow():
    f = jax.jit(lambda ph: mining.pow_search(ph, jnp.uint32(1),
                                             jnp.uint32(0), 65536)[0])
    us = _time(f, jnp.uint32(3))
    common.csv_line("kernel_pow_64k", us,
                    f"mhash_per_s={65536 / us:.2f}")


def run():
    bench_attention()
    bench_fedavg()
    bench_pow()
