"""Shared experiment runner for the paper-table benchmarks (§7 substrate:
MLP on synthetic non-IID MNIST/Fashion proxies, N clients, BLADE-FL rounds).

Time is normalized by alpha, like the paper: t_sum=100, beta default 10.
"""
from __future__ import annotations

import math
import time
from typing import Dict, List, Optional

import jax

from repro.core import allocation, bounds, rounds
from repro.core.aggregation import aggregate_once
from repro.core.topology import FullMesh, Topology
from repro.data.pipeline import FLDataSource
from repro.models.mlp import init_mlp, mlp_loss

# Single source of truth for the dataset-shaping defaults, shared by
# build_source / run_once / sweep_k so a prebuilt src can never silently
# drift from what run_once would have built itself.
DATA_DEFAULTS = dict(n_clients=20, samples=256, dataset="mnist", seed=0,
                     dirichlet_alpha=0.2)


def build_source(**kw) -> FLDataSource:
    """The FLDataSource `run_once` derives from the same kwargs — exposed so
    sweeps build it once and reuse it across every K (the build is a pure
    function of these arguments, so hoisting is result-identical). Accepts
    the DATA_DEFAULTS keys."""
    cfg = {**DATA_DEFAULTS, **kw}
    return FLDataSource(jax.random.key(cfg["seed"]), cfg["n_clients"],
                        cfg["samples"], cfg["dirichlet_alpha"],
                        dataset=cfg["dataset"], seed=cfg["seed"])


def _last_finite(curve: List[float]) -> float:
    """Last finite entry of a possibly NaN-masked (eval_every > 1) curve."""
    for v in reversed(curve):
        if math.isfinite(v):
            return v
    return float("nan")


def run_once(*, k: int, t_sum: float = 100.0, alpha: float = 1.0,
             beta: float = 10.0, eta: float = 0.05,
             n_clients: int = DATA_DEFAULTS["n_clients"],
             n_lazy: int = 0, sigma2: float = 0.0, dp_sigma: float = 0.0,
             samples: int = DATA_DEFAULTS["samples"],
             dataset: str = DATA_DEFAULTS["dataset"],
             seed: int = DATA_DEFAULTS["seed"],
             dirichlet_alpha: float = DATA_DEFAULTS["dirichlet_alpha"],
             eval_every: int = 1,
             topology: Optional[Topology] = None,
             src: Optional[FLDataSource] = None) -> Optional[Dict]:
    """One BLADE-FL run at a given K. Returns None when K is infeasible.

    Dir(0.2) heterogeneity: strong enough non-IID that aggregation matters
    and the loss-vs-K curve has the paper's interior optimum. Pass ``src``
    to reuse a prebuilt FLDataSource (sweeps), ``topology`` to run Steps 2+5
    over a non-full-mesh mixing matrix, ``eval_every`` to stride the in-scan
    global-loss eval."""
    tau = allocation.tau_from_budget(t_sum, k, alpha, beta)
    if tau < 1:
        return None
    key = jax.random.key(seed)
    if src is None:
        src = build_source(n_clients=n_clients, samples=samples,
                           dataset=dataset, seed=seed,
                           dirichlet_alpha=dirichlet_alpha)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(
        n_clients=n_clients, tau=tau, eta=eta, n_lazy=n_lazy, sigma2=sigma2,
        dp_sigma=dp_sigma, mine_attempts=max(int(beta * 16), 8),
        difficulty_bits=2, eval_every=eval_every,
        topology=topology if topology is not None else FullMesh())
    t0 = time.time()
    # static batch -> compiled scan path (all K rounds in one dispatch)
    state, hist, ledger = rounds.run_blade_fl(
        mlp_loss, spec, params, src.static_batch(), jax.random.fold_in(key, 2), k)
    wall = time.time() - t0
    final = aggregate_once(state.params)
    eval_loss, m = mlp_loss(final, src.eval_data)
    return {
        "k": k, "tau": tau,
        "train_time": k * tau * alpha, "mine_time": k * beta,
        "final_loss": _last_finite([h["global_loss"] for h in hist]),
        "eval_loss": float(eval_loss), "accuracy": float(m["accuracy"]),
        "loss_curve": [h["global_loss"] for h in hist],
        "divergence": float(hist[-1]["divergence"]),
        "chain_valid": ledger.validate_chain(),
        "wall_s": wall, "us_per_round": wall / k * 1e6,
    }


def sweep_k(ks=None, **kw) -> List[Dict]:
    t_sum = kw.get("t_sum", 100.0)
    alpha = kw.get("alpha", 1.0)
    beta = kw.get("beta", 10.0)
    if ks is None:
        kmax = int(t_sum / (alpha + beta))
        ks = sorted(set([1, 2, 3, 4, 5, 6, 8] + [kmax]))
        ks = [k for k in ks if 1 <= k <= kmax]
    # Build the dataset ONCE for the whole sweep — run_once would otherwise
    # rebuild the identical FLDataSource per K (same kwargs -> same data).
    t0 = time.time()
    src = kw.pop("src", None) or build_source(
        **{key: kw[key] for key in DATA_DEFAULTS if key in kw})
    build_s = time.time() - t0
    out = []
    for k in ks:
        r = run_once(k=k, src=src, **kw)
        if r is not None:
            out.append(r)
    # one build amortized over the sweep; saved_s counts only the rebuilds
    # actually avoided (infeasible Ks never built a source pre-hoist)
    for r in out:
        r["data_build_s"] = build_s
        r["data_build_saved_s"] = build_s * max(len(out) - 1, 0)
    return out


def best_of(results: List[Dict], key: str = "final_loss") -> Dict:
    return min(results, key=lambda r: r[key])


def fit_bound_params(results: List[Dict], *, eta: float, alpha: float,
                     beta: float, t_sum: float) -> bounds.BoundParams:
    """Calibrate (L, xi, delta) empirically and pin the one free scale
    constant w0_dist = ||w0 - w*|| so the bound dominates the empirical
    loss-vs-K curve with minimum slack (§7.2, Fig. 3 protocol).

    With the Appendix-C choice eps^2 = delta*xi/phi the bound is exactly
    LINEAR in w0_dist (g scales as 1/w0), so the tightest dominating scale
    is w0 = max_k empirical(k) / bound_{w0=1}(k).
    """
    curve = results[0]["loss_curve"] if results else [1.0]
    # eval_every > 1 NaN-masks skipped rounds; calibrate on the evaluated ones
    curve = [v for v in curve if math.isfinite(v)] or [1.0]
    c = bounds.estimate_constants(curve)
    p1 = bounds.BoundParams(eta=eta, L=min(c["L"], 0.5 / eta), xi=c["xi"],
                            delta=c["delta"], alpha=alpha, beta=beta,
                            t_sum=t_sum, w0_dist=1.0)
    ratios = []
    for r in results:
        b1 = bounds.loss_bound(p1, r["k"])
        if math.isfinite(b1) and b1 > 0:
            ratios.append(r["final_loss"] / b1)
    w0 = max(ratios) * 1.001 if ratios else 1.0
    return bounds.BoundParams(eta=p1.eta, L=p1.L, xi=p1.xi, delta=p1.delta,
                              alpha=alpha, beta=beta, t_sum=t_sum,
                              w0_dist=w0)


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line
