"""§Roofline: aggregate the dry-run JSONs into the per-(arch x shape x mesh)
three-term roofline table; identify dominant bottlenecks and what would move
them. Reads experiments/dryrun/*.json produced by repro.launch.dryrun."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks import common

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

_SUGGESTIONS = {
    "compute_s": "raise arithmetic intensity: larger microbatch per device "
                 "or fewer local iterations per aggregate",
    "memory_s": "cut HBM round-trips: chunkwise-parallel recurrence, fused "
                "kernels, larger fusion blocks, bf16 states",
    "collective_s": "overlap or shrink collectives: hierarchical aggregate, "
                    "quantized all-reduce, fewer aggregation boundaries",
}


def round_hot_block_bytes(model_bytes: float, n_clients: int,
                          mine_attempts: int, *, n_devices: int = 1,
                          fused_mix: bool = False,
                          fast_allreduce: bool = False) -> Dict[str, float]:
    """Analytic per-device bytes moved by ONE integrated round's hot block.

    Counts the model-sized traffic of each stage (the PoW race is
    compute-bound — it contributes hashes, not bytes):

      * ``train_bytes`` — each local client reads + writes its own model
        during the tau-step local update;
      * ``collective_bytes`` — the communicate stage's receive volume
        (all-gather of the C − C/D remote client blocks, or a ring
        all-reduce of ONE model when ``fast_allreduce``);
      * ``mix_bytes`` — the [C,C] x [C,P] mix matmul reads the C broadcast
        models once and writes C rows — or only the C/D LOCAL rows when the
        fused kernel's row-select does the slicing inside the contraction;
      * ``diag_bytes`` — digest + divergence sweep the broadcast set twice
        on the jnp path, ONCE with the fused single-sweep kernel.

    Benches pair this with measured rounds/sec so the JSON records what a
    kernel win is buying in bytes even where CPU wall-clock barely moves.
    """
    if n_devices < 1 or n_clients % n_devices:
        raise ValueError(f"need n_devices >= 1 dividing C={n_clients}, "
                         f"got {n_devices}")
    local = n_clients // n_devices
    train = 2.0 * local * model_bytes
    if n_devices == 1:
        coll = 0.0
    elif fast_allreduce:
        coll = 2.0 * (n_devices - 1) / n_devices * model_bytes
    else:
        coll = float(n_clients - local) * model_bytes
    rows_written = local if fused_mix else n_clients
    mix = float(n_clients + rows_written) * model_bytes
    sweeps = 1.0 if fused_mix else 2.0
    diag = sweeps * n_clients * model_bytes
    return {"train_bytes": train, "collective_bytes": coll,
            "mix_bytes": mix, "diag_bytes": diag,
            "total_bytes": train + coll + mix + diag,
            "pow_hashes": float(mine_attempts) * local}


def load_records(pattern: str = "*.json") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(mesh: str = "pod16x16") -> List[Dict]:
    rows = []
    for r in load_records():
        if r.get("mesh") != mesh:
            continue
        row = {"arch": r["arch"], "shape": r["shape"], "status": r["status"]}
        if r["status"] == "ok":
            rl = r["roofline"]
            row.update({
                "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
                "collective_s": rl["collective_s"],
                "dominant": rl["dominant"], "bound_s": rl["bound_s"],
                "useful_flops_ratio": r.get("useful_flops_ratio"),
                "model_flops": r.get("model_flops"),
                "fix": _SUGGESTIONS[rl["dominant"]],
            })
        else:
            row["reason"] = r.get("reason", r.get("error"))
        rows.append(row)
    return rows


def run(mesh: str = "pod16x16") -> List[Dict]:
    rows = table(mesh)
    ok = [r for r in rows if r["status"] == "ok"]
    if not ok:
        common.csv_line("roofline", 0.0, "no dry-run records; run "
                        "python -m repro.launch.dryrun --all first")
        return rows
    n_comp = sum(r["dominant"] == "compute_s" for r in ok)
    n_mem = sum(r["dominant"] == "memory_s" for r in ok)
    n_coll = sum(r["dominant"] == "collective_s" for r in ok)
    worst = max(ok, key=lambda r: r["bound_s"])
    common.csv_line(
        f"roofline_{mesh}", 0.0,
        f"pairs={len(ok)};compute_bound={n_comp};memory_bound={n_mem};"
        f"collective_bound={n_coll};worst={worst['arch']}x{worst['shape']}")
    for r in ok:
        print(f"  {r['arch']:24s} {r['shape']:12s} "
              f"C={r['compute_s']:9.3g}s M={r['memory_s']:9.3g}s "
              f"X={r['collective_s']:9.3g}s -> {r['dominant']}")
    return rows
