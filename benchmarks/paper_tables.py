"""One benchmark per paper table/figure (§7).

Each function sweeps K like the paper, reports the optimum and the paper's
qualitative claim, and prints a ``name,us_per_call,derived`` CSV line.
Datasets: the synthetic MNIST/Fashion proxies (offline container).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks import common
from repro.core import bounds


def fig3_bound_gap(dataset="mnist", seed=0) -> Dict:
    """Fig. 3: developed upper bound vs experimental loss across K.
    Claims: bound >= experiment everywhere; both convex-ish; same argmin
    region; gap at the optimum small (paper: < 5%)."""
    eta, alpha, beta, t_sum = 0.005, 1.0, 6.0, 100.0
    res = common.sweep_k(eta=eta, alpha=alpha, beta=beta, t_sum=t_sum,
                         dataset=dataset, seed=seed)
    p = common.fit_bound_params(res, eta=eta, alpha=alpha, beta=beta,
                                t_sum=t_sum)
    rows = []
    for r in res:
        b = bounds.loss_bound(p, r["k"])
        rows.append({"k": r["k"], "empirical": r["final_loss"], "bound": b})
    finite = [r for r in rows if np.isfinite(r["bound"])]
    above = all(r["bound"] >= r["empirical"] - 1e-6 for r in finite)
    k_emp = min(rows, key=lambda r: r["empirical"])["k"]
    k_bnd = min(finite, key=lambda r: r["bound"])["k"]
    at_opt = next(r for r in finite if r["k"] == k_bnd)
    gap = abs(at_opt["bound"] - at_opt["empirical"]) / max(at_opt["empirical"], 1e-9)
    us = float(np.mean([r["us_per_round"] for r in res]))
    common.csv_line(f"fig3_bound_gap_{dataset}", us,
                    f"gap_at_opt={gap:.3f};bound_above={above};"
                    f"k_emp={k_emp};k_bound={k_bnd}")
    return {"rows": rows, "gap": gap, "bound_above": above,
            "k_emp": k_emp, "k_bound": k_bnd}


def table2_alpha(dataset="mnist", seed=0) -> List[Dict]:
    """Table 2: training time per iteration alpha in {1,2,5}, beta=6.
    Claim (Cor. 1): optimal training time tau*alpha*K* grows with alpha;
    accuracy drops with alpha."""
    out = []
    for alpha in (1.0, 2.0, 5.0):
        res = common.sweep_k(alpha=alpha, beta=6.0, dataset=dataset, seed=seed)
        best = common.best_of(res)
        out.append({"alpha": alpha, "k_star": best["k"],
                    "train_time": best["train_time"],
                    "accuracy": best["accuracy"],
                    "us": np.mean([r["us_per_round"] for r in res])})
    mono = all(a["train_time"] <= b["train_time"] for a, b in zip(out, out[1:]))
    acc_drop = out[0]["accuracy"] >= out[-1]["accuracy"]
    common.csv_line(f"table2_alpha_{dataset}",
                    float(np.mean([r["us"] for r in out])),
                    f"train_time={[r['train_time'] for r in out]};"
                    f"mono={mono};acc_drop={acc_drop}")
    return out


def table3_beta(dataset="mnist", seed=0) -> List[Dict]:
    """Table 3: mining time per block beta in {6,8,12}.
    Claim (Cor. 1): optimal mining time beta*K* grows with beta; accuracy
    drops with beta."""
    out = []
    for beta in (6.0, 8.0, 12.0):
        res = common.sweep_k(beta=beta, dataset=dataset, seed=seed)
        best = common.best_of(res)
        out.append({"beta": beta, "k_star": best["k"],
                    "mine_time": best["mine_time"],
                    "accuracy": best["accuracy"],
                    "us": np.mean([r["us_per_round"] for r in res])})
    mono = all(a["mine_time"] <= b["mine_time"] for a, b in zip(out, out[1:]))
    common.csv_line(f"table3_beta_{dataset}",
                    float(np.mean([r["us"] for r in out])),
                    f"mine_time={[r['mine_time'] for r in out]};mono={mono}")
    return out


def table4_clients(dataset="mnist", seed=0) -> List[Dict]:
    """Table 4: N in {10,15,20,25}, beta=6.
    Claims (Cor. 3): optimal mining time drops as N grows; loss drops with
    N; K* saturates for large N."""
    out = []
    for n in (10, 15, 20, 25):
        res = common.sweep_k(n_clients=n, beta=6.0, dataset=dataset,
                             seed=seed, samples=200)
        best = common.best_of(res)
        out.append({"n": n, "k_star": best["k"], "mine_time": best["mine_time"],
                    "final_loss": best["final_loss"],
                    "accuracy": best["accuracy"],
                    "us": np.mean([r["us_per_round"] for r in res])})
    k_sat = abs(out[-1]["k_star"] - out[-2]["k_star"]) <= 1
    common.csv_line(f"table4_clients_{dataset}",
                    float(np.mean([r["us"] for r in out])),
                    f"mine_time={[r['mine_time'] for r in out]};k_sat={k_sat}")
    return out


def table5_eta(dataset="mnist", seed=0) -> List[Dict]:
    """Table 5: eta in {0.005, 0.05, 0.1}.
    Claims (Cor. 4): optimal mining time beta*K* rises with eta (while
    eta*L<1); loss drops with eta until the bound regime breaks."""
    out = []
    for eta in (0.005, 0.05, 0.1):
        res = common.sweep_k(eta=eta, beta=6.0, dataset=dataset, seed=seed)
        best = common.best_of(res)
        out.append({"eta": eta, "k_star": best["k"],
                    "mine_time": best["mine_time"],
                    "final_loss": best["final_loss"],
                    "accuracy": best["accuracy"],
                    "us": np.mean([r["us_per_round"] for r in res])})
    common.csv_line(f"table5_eta_{dataset}",
                    float(np.mean([r["us"] for r in out])),
                    f"mine_time={[r['mine_time'] for r in out]};"
                    f"loss={[round(r['final_loss'],3) for r in out]}")
    return out


def table6_lazy(dataset="mnist", seed=0) -> List[Dict]:
    """Table 6: lazy ratio M/N in {0,10%,20%,30%}, sigma2=0.01.
    Claims (Cor. 5): optimal training time tau*alpha*K* rises with M/N;
    performance degrades with M/N."""
    out = []
    for frac in (0.0, 0.1, 0.2, 0.3):
        m = int(20 * frac)
        res = common.sweep_k(n_lazy=m, sigma2=0.01, beta=6.0,
                             dataset=dataset, seed=seed)
        best = common.best_of(res)
        out.append({"lazy_frac": frac, "k_star": best["k"],
                    "train_time": best["train_time"],
                    "final_loss": best["final_loss"],
                    "accuracy": best["accuracy"],
                    "us": np.mean([r["us_per_round"] for r in res])})
    degraded = out[-1]["accuracy"] <= out[0]["accuracy"] + 0.02
    common.csv_line(f"table6_lazy_{dataset}",
                    float(np.mean([r["us"] for r in out])),
                    f"train_time={[r['train_time'] for r in out]};"
                    f"degraded={degraded}")
    return out


def table7_sigma(dataset="mnist", seed=0) -> List[Dict]:
    """Table 7: artificial-noise power sigma^2 in {0.01,0.1,0.2,0.3} at
    M/N=20%. Claims (Cor. 5): optimal training time grows with sigma^2;
    performance degrades as sigma^2 grows."""
    out = []
    for s2 in (0.01, 0.1, 0.2, 0.3):
        res = common.sweep_k(n_lazy=4, sigma2=s2, beta=6.0, dataset=dataset,
                             seed=seed)
        best = common.best_of(res)
        out.append({"sigma2": s2, "k_star": best["k"],
                    "train_time": best["train_time"],
                    "final_loss": best["final_loss"],
                    "accuracy": best["accuracy"],
                    "us": np.mean([r["us_per_round"] for r in res])})
    degraded = out[-1]["accuracy"] <= out[0]["accuracy"] + 0.02
    common.csv_line(f"table7_sigma_{dataset}",
                    float(np.mean([r["us"] for r in out])),
                    f"train_time={[r['train_time'] for r in out]};"
                    f"degraded={degraded}")
    return out


def fig10_dp(dataset="mnist", seed=0) -> List[Dict]:
    """Figs 10-11: DP privacy budget eps sweep.
    Claims: accuracy rises with eps (weaker privacy); optimal K is NOT a
    function of eps (privacy and resource allocation decouple)."""
    from repro.core import dp as dp_lib
    out = []
    for eps in (2.0, 5.0, 10.0, 50.0):
        sigma = dp_lib.gaussian_sigma(eps, delta=1e-3, sensitivity=0.05)
        res = common.sweep_k(dp_sigma=sigma, beta=6.0, dataset=dataset,
                             seed=seed)
        best = common.best_of(res)
        out.append({"eps": eps, "dp_sigma": sigma, "k_star": best["k"],
                    "final_loss": best["final_loss"],
                    "accuracy": best["accuracy"],
                    "us": np.mean([r["us_per_round"] for r in res])})
    accs = [r["accuracy"] for r in out]
    k_spread = max(r["k_star"] for r in out) - min(r["k_star"] for r in out)
    common.csv_line(f"fig10_dp_{dataset}",
                    float(np.mean([r["us"] for r in out])),
                    f"acc={[round(a,3) for a in accs]};k_spread={k_spread}")
    return out
