"""Cohort-sampled population scaling: rounds/sec and mix memory vs
C_enrolled.

The dense engine's Steps 2+5 mix is a ``[C, C]`` matmul and its carry is a
``[C, ...]`` stack — both priced by the ENROLLED count. The cohort driver
(``core.rounds.run_blade_fl_cohort``) prices the round by the ACTIVE cohort
instead: devices hold the ``[A, ...]`` cohort stack, the intra-cohort mix is
the sparse gather + ``segment_sum`` path at O(A·deg), and the enrolled
population lives in the host-side lazy ``PopulationStore``. This bench holds
A = 64 fixed and scales C_enrolled over {64, 1k, 10k} — the point being that
the timed column barely moves while the dense-mix column grows as
C_enrolled².

Reported per C_enrolled:
  * rounds/sec of the cohort driver (compile round excluded — the runner is
    warmed at the same spec before timing);
  * analytic peak mix bytes: dense ``[C_enrolled, C_enrolled]`` fp32 matrix
    vs the segment path's edge lists + gathered neighbor rows
    (O(A·deg·model), independent of C_enrolled);
  * the store's touched-client count and materialized bytes (host memory is
    O(touched·model), not O(C_enrolled·model)).

  PYTHONPATH=src python -m benchmarks.bench_cohort [--rounds 6]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import rounds, topology
from repro.models.mlp import init_mlp, mlp_loss

# tiny substrate: the bench measures driver + mix scaling, not training, so
# the model is ~1 KB and each client's local batch is 8 x 16 features
_IN_DIM, _HIDDEN, _SAMPLES = 16, 8, 8
_COHORT = 64
_DEGREE = 5  # ring_neighbors(A, 2) rows: 4 neighbors + the diagonal


def _batch_fn(key):
    """(round_idx, cohort_idx) -> [A, m, ...]: deterministic synthetic data,
    built per cohort — nothing of shape [C_enrolled, ...] ever exists."""
    def fn(round_idx, cohort_idx):
        ks = jax.vmap(lambda i: jax.random.fold_in(key, i))(
            jnp.asarray(cohort_idx, jnp.int32))
        x = jax.vmap(lambda k: jax.random.normal(
            k, (_SAMPLES, _IN_DIM), jnp.float32))(ks)
        y = jax.vmap(lambda k: jax.random.randint(
            k, (_SAMPLES,), 0, 10))(ks)
        return {"x": x, "y": y.astype(jnp.int32)}
    return fn


def _param_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))


def bench(n_rounds: int = 6, seed: int = 0) -> dict:
    key = jax.random.key(seed)
    params = init_mlp(jax.random.fold_in(key, 1), in_dim=_IN_DIM,
                      hidden=_HIDDEN)
    pbytes = _param_bytes(params)
    spec = rounds.RoundSpec(
        n_clients=_COHORT, tau=2, eta=0.05, mine_attempts=32,
        difficulty_bits=2,
        # explicit edge list -> the segment (gather + segment_sum) mix
        topology=topology.ExplicitSparse(
            neighbors=topology.ring_neighbors(_COHORT, 2)))
    batch_fn = _batch_fn(jax.random.fold_in(key, 3))
    run_key = jax.random.fold_in(key, 2)

    results = {}
    print(f"{'C_enrolled':>10} {'rounds/s':>9} {'dense_mix_MB':>12} "
          f"{'segment_mix_KB':>14} {'touched':>7} {'store_KB':>8}")
    for c_enrolled in (64, 1_000, 10_000):
        cohort = topology.CohortSchedule(n_enrolled=c_enrolled,
                                         cohort_size=_COHORT)
        # warm the (lru-cached) runner at this spec so the timed window
        # holds zero compiles — one throwaway round on a scratch store
        rounds.run_blade_fl_cohort(mlp_loss, spec, params, batch_fn,
                                   run_key, 1, cohort)
        t0 = time.time()
        store, hist, ledger = rounds.run_blade_fl_cohort(
            mlp_loss, spec, params, batch_fn, run_key, n_rounds, cohort)
        wall = time.time() - t0
        if not ledger.validate_chain():
            raise RuntimeError(f"chain invalid at C_enrolled={c_enrolled}")
        # analytic peaks: what the dense engine WOULD allocate vs what the
        # segment mix actually touches (edge ids+weights, gathered rows)
        dense_mix = 4 * c_enrolled * c_enrolled
        # per edge: int32 neighbor id + fp32 weight, plus the gathered row
        segment_mix = _COHORT * _DEGREE * (8 + pbytes)
        rps = n_rounds / wall
        results[f"C{c_enrolled}"] = {
            "n_enrolled": c_enrolled, "cohort": _COHORT,
            "rounds_per_s": rps,
            "dense_mix_bytes": dense_mix,
            "segment_mix_bytes": segment_mix,
            "touched": store.touched,
            "store_bytes": store.materialized_bytes(),
            "final_local_loss": hist[-1]["local_loss_mean"],
        }
        print(f"{c_enrolled:>10} {rps:>9.2f} {dense_mix / 1e6:>12.2f} "
              f"{segment_mix / 1e3:>14.1f} {store.touched:>7} "
              f"{store.materialized_bytes() / 1e3:>8.1f}")
        common.csv_line(
            f"cohort_C{c_enrolled}_A{_COHORT}",
            1e6 * wall / n_rounds,
            f"rounds_per_s={rps:.2f},dense_mix_mb={dense_mix / 1e6:.2f},"
            f"segment_mix_kb={segment_mix / 1e3:.1f},"
            f"touched={store.touched}")
    return results


def run():
    return bench()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    bench(a.rounds, a.seed)
