"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
JSONs (experiments/dryrun/*.json). §Perf and §Paper-validation are authored
by hand in EXPERIMENTS.md; this module prints the generated sections so they
can be spliced in (and is reused by benchmarks.roofline).

  PYTHONPATH=src python -m benchmarks.gen_experiments > experiments/generated_sections.md
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def fmt(x, unit=""):
    if x is None:
        return "-"
    if isinstance(x, str):
        return x
    a = abs(x)
    if a >= 1e4 or (a < 1e-2 and a > 0):
        return f"{x:.3g}{unit}"
    return f"{x:.3f}{unit}"


def load(mesh):
    recs = {}
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        if p.endswith(".baseline.json"):
            continue
        r = json.load(open(p))
        if r.get("mesh") == mesh:
            recs[(r["arch"], r["shape"])] = r
    return recs


SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCHS = ["xlstm-125m", "qwen3-32b", "nemotron-4-15b", "jamba-1.5-large-398b",
         "paligemma-3b", "hubert-xlarge", "phi4-mini-3.8b",
         "kimi-k2-1t-a32b", "minicpm-2b", "deepseek-v2-236b"]


def dryrun_section():
    print("## §Dry-run\n")
    for mesh, label in [("pod16x16", "single-pod (16x16 = 256 chips)"),
                        ("pod2x16x16", "multi-pod (2x16x16 = 512 chips)")]:
        recs = load(mesh)
        n_ok = sum(r["status"] == "ok" for r in recs.values())
        n_skip = sum(r["status"] == "skipped" for r in recs.values())
        n_fail = len(recs) - n_ok - n_skip
        print(f"### {label}: {n_ok} ok / {n_skip} skipped / {n_fail} failed\n")
        print("| arch | shape | status | lower s | compile s | HLO flops/dev "
              "| HBM bytes/dev | coll bytes/dev | bytes/dev (XLA args+temp) |")
        print("|---|---|---|---|---|---|---|---|---|")
        for a in ARCHS:
            for s in SHAPES:
                r = recs.get((a, s))
                if r is None:
                    continue
                if r["status"] != "ok":
                    why = r.get("reason", r.get("error", ""))[:60]
                    print(f"| {a} | {s} | {r['status']}: {why} | | | | | | |")
                    continue
                p = r["hlo_parsed"]
                ma = r.get("memory_analysis", {})
                mem = (ma.get("argument_size_in_bytes", 0)
                       + ma.get("temp_size_in_bytes", 0))
                print(f"| {a} | {s} | ok | {r['lower_s']} | {r['compile_s']} "
                      f"| {fmt(p['flops'])} | {fmt(p['hbm_bytes'])} "
                      f"| {fmt(p['collective_bytes'])} | {fmt(float(mem))} |")
        print()


def roofline_section():
    print("## §Roofline (single-pod, 256 chips; v5e: 197 TF/s bf16, "
          "819 GB/s HBM, 50 GB/s ICI)\n")
    recs = load("pod16x16")
    print("| arch | shape | compute s | memory s | collective s | dominant "
          "| MODEL_FLOPS | useful ratio |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in SHAPES:
            r = recs.get((a, s))
            if r is None or r["status"] != "ok":
                continue
            rl = r["roofline"]
            print(f"| {a} | {s} | {fmt(rl['compute_s'])} "
                  f"| {fmt(rl['memory_s'])} | {fmt(rl['collective_s'])} "
                  f"| {rl['dominant'].replace('_s','')} "
                  f"| {fmt(r['model_flops'])} "
                  f"| {fmt(r['useful_flops_ratio'])} |")
    print()


if __name__ == "__main__":
    dryrun_section()
    roofline_section()
