"""Flat gather vs two-level hierarchical aggregation on a multi-pod mesh.

Runs the SAME ``ClusterTopology`` config through the K-round scan engine
twice on 8 host devices and compares rounds/sec plus the analytic
per-device receive volume of the communicate stage:

  * ``flat``    — single-axis ``('data',)`` mesh: the resolver cannot align
    clusters to pods, so the mix falls back to the gathered dense path —
    every device receives the other shards' client blocks,
    ``(C - L) * model`` bytes per round (``L`` = local client rows).
  * ``cluster`` — ``make_cluster_mesh``'s 2-D ``('pod', 'data')`` mesh with
    the pod extent equal to ``n_clusters``: the resolver lowers to in-pod
    aggregation + a narrow cross-pod halo — one in-pod all-gather of the
    other ``S - L`` cluster rows plus TWO model-sized cross-pod
    ``ppermute``s of the cluster mean, ``(S - L + 2) * model`` bytes.

Both layouts produce bitwise-identical params/ledgers (the engine contract;
tests/test_multidevice_scan.py), so the bytes column is a pure
communication-volume win: at equal C the hierarchical lowering moves
strictly fewer bytes whenever ``C - C/D > C/G - C/D + 2`` models, i.e. for
any C comfortably above the pod count. ``bench()`` asserts that inequality
on the analytic numbers it reports.

Same caveat as bench_multidevice: host "devices" are threads sharing one
memory system, so read rounds/sec as the lowering's overhead curve — the
bytes ratio is the quantity that transfers to a real multi-pod ICI mesh.

  PYTHONPATH=src python -m benchmarks.bench_hierarchy [--clusters 2]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common  # noqa: E402

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CHILD = textwrap.dedent("""
    import os, sys, json, time
    layout = sys.argv[1]; n_clusters = int(sys.argv[2])
    n_dev = int(sys.argv[3]); n_rounds = int(sys.argv[4])
    n_clients = int(sys.argv[5]); samples = int(sys.argv[6])
    tau = int(sys.argv[7]); reps = int(sys.argv[8])
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_dev}")
    import jax
    from repro.core import rounds, topology
    from repro.data.pipeline import FLDataSource
    from repro.launch.mesh import make_client_mesh, make_cluster_mesh
    from repro.models.mlp import init_mlp, mlp_loss
    from repro.sharding import plans

    key = jax.random.key(0)
    src = FLDataSource(key, n_clients, samples, seed=0)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(
        n_clients=n_clients, tau=tau, eta=0.05, n_lazy=2, sigma2=0.01,
        mine_attempts=256, difficulty_bits=2,
        topology=topology.ClusterTopology(n_clusters=n_clusters))
    if layout == "cluster":
        mesh = make_cluster_mesh(n_clusters, n_dev)
        plan = plans.scan_carry_plan(mesh, n_clients,
                                     client_axes=("pod", "data"))
    else:
        mesh = make_client_mesh(n_dev)
        plan = plans.scan_carry_plan(mesh, n_clients)
    batch, rk = src.static_batch(), jax.random.fold_in(key, 2)

    # analytic per-device receive bytes of the communicate collectives
    model_bytes = 4 * sum(x.size for x in jax.tree.leaves(params))
    local = n_clients // n_dev
    cluster_rows = n_clients // n_clusters
    if layout == "cluster":
        # in-pod all-gather of the other S - L cluster rows + two
        # cross-pod ppermutes of the model-sized cluster mean
        mix_bytes = (cluster_rows - local + 2) * model_bytes
    else:
        # flat fallback: all-gather every other shard's client block
        mix_bytes = (n_clients - local) * model_bytes

    def run():
        return rounds.run_blade_fl_scan(mlp_loss, spec, params, batch, rk,
                                        n_rounds, mesh=mesh, plan=plan)

    run()                                  # warm: compile
    t0 = time.time()
    for _ in range(reps):
        state, hist, ledger = run()
    wall = (time.time() - t0) / reps
    mesh_axes = tuple(zip(mesh.axis_names, mesh.devices.shape))
    mix_mode = topology.resolve_mix_plan(spec, mesh_axes).mode
    print(json.dumps({"layout": layout, "devices": n_dev,
                      "n_clusters": n_clusters, "mix_mode": mix_mode,
                      "rounds_per_s": n_rounds / wall, "wall_s": wall,
                      "model_bytes": model_bytes,
                      "est_mix_bytes_per_round": mix_bytes,
                      "chain_valid": ledger.validate_chain(),
                      "final_global_loss": hist[-1]["global_loss"]}))
""")


def bench(n_clusters: int = 2, n_dev: int = 8, n_rounds: int = 16,
          n_clients: int = 16, samples: int = 64, tau: int = 4,
          reps: int = 3) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = {}
    for layout in ("flat", "cluster"):
        proc = subprocess.run(
            [sys.executable, "-c", _CHILD, layout, str(n_clusters),
             str(n_dev), str(n_rounds), str(n_clients), str(samples),
             str(tau), str(reps)],
            capture_output=True, text=True, env=env, timeout=900)
        if proc.returncode != 0:
            print(f"# hierarchy {layout} FAILED: {proc.stderr[-500:]}")
            continue
        res = json.loads(proc.stdout.strip().splitlines()[-1])
        out[layout] = res
        common.csv_line(
            f"hierarchy_{layout}_G{n_clusters}_D{n_dev}_C{n_clients}",
            res["wall_s"] / n_rounds * 1e6,
            f"rounds_per_s={res['rounds_per_s']:.1f};"
            f"mix_bytes={res['est_mix_bytes_per_round']:.0f}")
    if "flat" in out and "cluster" in out:
        flat_b = out["flat"]["est_mix_bytes_per_round"]
        hier_b = out["cluster"]["est_mix_bytes_per_round"]
        if hier_b >= flat_b:
            # the whole point of the two-level lowering: strictly fewer
            # bytes than the flat gather at equal C
            raise ValueError(
                f"hierarchical bytes {hier_b} not < flat {flat_b}")
        out["flat_vs_cluster_bytes_ratio"] = flat_b / hier_b
        out["cluster_vs_flat_speedup"] = (
            out["cluster"]["rounds_per_s"] / out["flat"]["rounds_per_s"])
    return out


def run():
    return bench()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=16)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--samples", type=int, default=64)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--reps", type=int, default=3)
    a = ap.parse_args()
    print(json.dumps(bench(a.clusters, a.devices, a.rounds, a.clients,
                           a.samples, a.tau, a.reps), indent=1))
