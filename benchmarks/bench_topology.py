"""Loss-vs-K under different communication topologies (Steps 2+5).

The paper's engine is a full mesh — every broadcast reaches every client and
all clients adopt the same aggregate. The topology subsystem
(``repro.core.topology``) generalizes Steps 2+5 to any row-stochastic mixing
matrix; this sweep shows what that costs: under the same t_sum budget, ring
gossip and per-round i.i.d. link dropout slow consensus (higher divergence,
worse held-out loss at the same K) and shift where the loss-vs-K optimum sits —
the regimes of arXiv:2012.02044 / arXiv:2406.00752 that the monolithic
full-mesh round could not express.

Every run goes through the compiled ``lax.scan`` engine, and each sweep
builds its FLDataSource once (hoisted out of the K loop by
``common.sweep_k``); the per-sweep ``data_build_saved_s`` column records the
wall time that hoist saves.

  PYTHONPATH=src python -m benchmarks.bench_topology [--samples 128]
"""
from __future__ import annotations

import argparse

from benchmarks import common
from repro.core import topology


TOPOLOGIES = (
    ("full_mesh", topology.FullMesh()),
    ("ring1", topology.Ring(neighbors=1)),
    ("p_dropout_0.5", topology.RandomGraph(p_link=0.5)),
    ("partial_half", None),  # resolved per n_clients in bench()
)


def bench(samples: int = 128, n_clients: int = 20, beta: float = 6.0,
          seed: int = 0) -> dict:
    # Rank on eval_loss (held-out data, aggregated model): the train-side
    # final_loss is each client's loss on its OWN shard, which rewards
    # non-mixing topologies for overfitting locally and would invert the
    # comparison.
    results = {}
    print(f"{'topology':>14} {'K*':>3} {'eval_loss':>9} {'accuracy':>8} "
          f"{'divergence':>10} {'build_saved_s':>13}")
    for name, topo in TOPOLOGIES:
        if topo is None:
            topo = topology.PartialParticipation(n_active=max(n_clients // 2, 1))
        res = common.sweep_k(n_clients=n_clients, samples=samples, beta=beta,
                             seed=seed, topology=topo)
        best = common.best_of(res, key="eval_loss")
        results[name] = {
            "best_k": best["k"], "eval_loss": best["eval_loss"],
            "accuracy": best["accuracy"], "final_loss": best["final_loss"],
            "divergence": best["divergence"],
            "eval_loss_vs_k": {r["k"]: r["eval_loss"] for r in res},
            "data_build_saved_s": best["data_build_saved_s"],
        }
        print(f"{name:>14} {best['k']:>3} {best['eval_loss']:>9.4f} "
              f"{best['accuracy']:>8.3f} {best['divergence']:>10.3e} "
              f"{best['data_build_saved_s']:>13.2f}")
        common.csv_line(
            f"topology_{name}_C{n_clients}",
            best["us_per_round"],
            f"best_k={best['k']},eval_loss={best['eval_loss']:.4f}")
    full = results["full_mesh"]["eval_loss"]
    for name, r in results.items():
        r["eval_gap_vs_full_mesh"] = r["eval_loss"] - full
    return results


def run():
    return bench()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=128)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--beta", type=float, default=6.0)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    bench(a.samples, a.clients, a.beta, a.seed)
