"""Rounds/sec of the client-sharded K-round scan engine vs device count,
gather-side all-reduce (bitwise) vs the opt-in psum fast path side by side.

Each device count runs in its own subprocess because
``--xla_force_host_platform_device_count`` must be set before the first jax
import — the same trick the dry-run and the multi-device tests use. The
child runs the identical config through ``run_blade_fl_scan`` with a
``make_client_mesh`` of that size (1 device = the plain single-device scan)
and reports warm rounds/sec, once per mix lowering mode:

  * ``gather`` — the default bitwise engine (all-gather the broadcast set,
    replicated full-width math);
  * ``psum``   — ``RoundSpec.fast_allreduce=True``: one model-sized
    ``lax.psum`` mixes the clients and the digest/divergence diagnostics
    psum local partials (tolerance tier, hashes fork; see
    docs/architecture.md §The tolerance tier);
  * ``kernel`` — the Pallas tier (``use_kernel + fused_mix``,
    ``kernel_interpret=True`` on host devices): the 2-D PoW grid race
    (bitwise) plus the fused row-select mix matmul and one-sweep
    digest/divergence (tolerance). Same all-gather as ``gather``, but the
    mix writes only the C/D LOCAL rows and the diagnostics sweep the
    broadcast set once instead of twice — the bytes column records that.
    Interpret-mode wall-clock prices the grid's structure, not TPU time.

Alongside rounds/sec each child reports ``est_mix_bytes_per_round`` — the
analytic per-device receive volume of the communicate stage's collectives
(all-gather of C−C/D client models vs a ring all-reduce of ONE model,
2·(D−1)/D·model) — so the JSON records the gather-vs-psum bytes-moved ratio
the fast path is buying, even on host "devices" where wall-clock barely
moves (threads share one memory system; the ratio is what transfers to a
real ICI mesh).

This bench sweeps FLAT single-axis meshes; ``bench_hierarchy`` runs the
same engine on a 2-D ``('pod', 'data')`` mesh and prices the two-level
cluster lowering (in-pod aggregation + cross-pod halo) against the flat
gather measured here.

Read CPU numbers as the COST CURVE of the sharded lowering, not a speedup
claim: host "devices" are threads carved out of the same CPU, so the
per-client math gets no new FLOPs and the all-gathers/ppermutes are pure
overhead. What the curve shows is that overhead staying small (the engine's
collectives are O(1) per round), which is the quantity that transfers to a
real mesh where D devices DO bring D× the compute. The engine's bitwise
contract (tests/test_multidevice_scan.py) holds within a process; ACROSS
the child processes here the loss values can drift in the last ulps,
because ``--xla_force_host_platform_device_count`` changes XLA:CPU's
intra-op thread partitioning and with it the association of large
reductions — the per-run ``chain_valid`` is the correctness signal.

  PYTHONPATH=src python -m benchmarks.bench_multidevice [--devices 1,2,4,8]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common  # noqa: E402

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_CHILD = textwrap.dedent("""
    import os, sys, json, time
    n_dev = int(sys.argv[1]); n_rounds = int(sys.argv[2])
    n_clients = int(sys.argv[3]); samples = int(sys.argv[4])
    tau = int(sys.argv[5]); reps = int(sys.argv[6])
    mode = sys.argv[7]
    if n_dev > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n_dev}")
    import jax
    from repro.core import rounds
    from repro.data.pipeline import FLDataSource
    from repro.launch.mesh import make_client_mesh
    from repro.models.mlp import init_mlp, mlp_loss

    key = jax.random.key(0)
    src = FLDataSource(key, n_clients, samples, seed=0)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(n_clients=n_clients, tau=tau, eta=0.05,
                            n_lazy=2, sigma2=0.01, mine_attempts=256,
                            difficulty_bits=2,
                            fast_allreduce=(mode == "psum"),
                            use_kernel=(mode == "kernel"),
                            fused_mix=(mode == "kernel"),
                            kernel_interpret=True if mode == "kernel"
                            else None)
    mesh = make_client_mesh(n_dev) if n_dev > 1 else None
    batch, rk = src.static_batch(), jax.random.fold_in(key, 2)

    # analytic per-device receive bytes of the communicate-stage collectives
    model_bytes = 4 * sum(x.size for x in jax.tree.leaves(params))
    local = n_clients // n_dev
    if n_dev == 1:
        mix_bytes = 0.0
    elif mode == "psum":
        # ring all-reduce of ONE model (reduce-scatter + all-gather)
        mix_bytes = 2.0 * (n_dev - 1) / n_dev * model_bytes
    else:
        # all-gather of every other shard's client blocks (the kernel tier
        # gathers identically; its win is rows written + diag sweeps)
        mix_bytes = (n_clients - local) * model_bytes
    # model-bytes the mix + diagnostics WRITE/SWEEP per device per round:
    # fused kernel writes only the local rows and sweeps the broadcast set
    # once; the jnp path writes all C rows and sweeps twice.
    if mode == "kernel":
        hot_bytes = (n_clients + local) * model_bytes + n_clients * model_bytes
    else:
        hot_bytes = 2 * n_clients * model_bytes + 2 * n_clients * model_bytes

    def run():
        return rounds.run_blade_fl_scan(mlp_loss, spec, params, batch, rk,
                                        n_rounds, mesh=mesh)

    run()                                  # warm: compile
    t0 = time.time()
    for _ in range(reps):
        state, hist, ledger = run()
    wall = (time.time() - t0) / reps
    print(json.dumps({"devices": n_dev, "mode": mode,
                      "rounds_per_s": n_rounds / wall, "wall_s": wall,
                      "model_bytes": model_bytes,
                      "est_mix_bytes_per_round": mix_bytes,
                      "est_mix_diag_local_bytes": hot_bytes,
                      "interpret": mode == "kernel",
                      "chain_valid": ledger.validate_chain(),
                      "final_global_loss": hist[-1]["global_loss"]}))
""")


def bench(device_counts=(1, 2, 4, 8), n_rounds: int = 16, n_clients: int = 16,
          samples: int = 64, tau: int = 4, reps: int = 3) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = {}
    for d in device_counts:
        if n_clients % d:
            print(f"# skip devices={d}: {n_clients} clients not divisible")
            continue
        modes = {}
        for mode in ("gather", "psum", "kernel"):
            proc = subprocess.run(
                [sys.executable, "-c", _CHILD, str(d), str(n_rounds),
                 str(n_clients), str(samples), str(tau), str(reps),
                 mode],
                capture_output=True, text=True, env=env, timeout=900)
            if proc.returncode != 0:
                print(f"# devices={d} {mode} FAILED: {proc.stderr[-500:]}")
                continue
            res = json.loads(proc.stdout.strip().splitlines()[-1])
            modes[mode] = res
            note = f"rounds_per_s={res['rounds_per_s']:.1f}"
            if res.get("interpret"):
                note += ";interpret=True"
            common.csv_line(
                f"multidevice_scan_{mode}_D{d}_K{n_rounds}_C{n_clients}",
                res["wall_s"] / n_rounds * 1e6, note)
        if not modes:
            continue
        if "gather" in modes and "psum" in modes:
            g, p = modes["gather"], modes["psum"]
            modes["psum_vs_gather_speedup"] = (
                p["rounds_per_s"] / g["rounds_per_s"])
            if p["est_mix_bytes_per_round"]:
                modes["gather_vs_psum_bytes_ratio"] = (
                    g["est_mix_bytes_per_round"]
                    / p["est_mix_bytes_per_round"])
        if "gather" in modes and "kernel" in modes:
            g, k = modes["gather"], modes["kernel"]
            modes["kernel_vs_gather_speedup"] = (
                k["rounds_per_s"] / g["rounds_per_s"])
            modes["gather_vs_kernel_local_bytes_ratio"] = (
                g["est_mix_diag_local_bytes"]
                / k["est_mix_diag_local_bytes"])
        out[d] = modes
    if 1 in out and "gather" in out[1]:
        base = out[1]["gather"]["rounds_per_s"]
        for d, modes in out.items():
            for mode in ("gather", "psum", "kernel"):
                if mode in modes:
                    modes[mode]["vs_single_device_gather"] = (
                        modes[mode]["rounds_per_s"] / base)
    return out


def run():
    return bench()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", default="1,2,4,8",
                    help="comma list of host-device counts to sweep")
    ap.add_argument("--rounds", type=int, default=16)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--samples", type=int, default=64)
    ap.add_argument("--tau", type=int, default=4)
    ap.add_argument("--reps", type=int, default=3)
    a = ap.parse_args()
    counts = tuple(int(x) for x in a.devices.split(","))
    print(json.dumps(bench(counts, a.rounds, a.clients, a.samples, a.tau,
                           a.reps), indent=1))
