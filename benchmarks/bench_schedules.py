"""Loss-vs-K under time-varying topology schedules, with spectral-gap
diagnostics.

Schedules (``repro.core.topology.Schedule``) make the Steps 2+5 mixing
matrix a function of the round index — one-peer gossip rotations,
epoch-alternating overlays (ring epochs + a full-mesh sync round), and
SNR-derived link-quality weighting — the wireless-scheduling regimes of
arXiv:2406.00752. The quantity that connects a schedule to the paper's
bound is the spectral gap ``1 - |lambda_2(W)|`` (``repro.core.spectral``):
per round it is how fast client disagreement (the Def. 1 divergence feeding
the bound's delta term) contracts, and for a schedule the ergodic
product-matrix gap is the honest per-round rate. This bench reports, per
schedule:

  * the loss-vs-K sweep (compiled scan engine, same budget discipline as
    ``bench_topology``) and its best K;
  * the ergodic spectral gap and the predicted per-round consensus
    contraction ``|lambda_2|``;
  * the OBSERVED contraction: the geometric decay rate of the engine's
    per-round divergence metric at a fixed K — gap up, observed rate down,
    which is the correlation the diagnostic exists to expose.

  PYTHONPATH=src python -m benchmarks.bench_schedules [--samples 128]
"""
from __future__ import annotations

import argparse
import math

import jax

from benchmarks import common
from repro.core import rounds, spectral, topology
from repro.models.mlp import init_mlp, mlp_loss


def schedules(n_clients: int):
    return (
        ("full_mesh", topology.FullMesh()),
        ("ring1", topology.Ring(neighbors=1)),
        ("rotate", topology.GossipRotation()),
        ("alt_ring3_mesh1", topology.AlternatingSchedule(
            ((topology.Ring(neighbors=1), 3), (topology.FullMesh(), 1)))),
        ("snr_fade8", topology.LinkQualitySchedule(fading_period=8)),
        # sparse segment-mix path: same ring-2 graph as an explicit edge
        # list, so its row goes through mix_segment in the engine and
        # through the SparseLowering densify guard in the spectral
        # diagnostics (small C — spectral._densify raises past
        # DENSIFY_MAX_CLIENTS by design)
        ("sparse_ring2", topology.ExplicitSparse(
            neighbors=topology.ring_neighbors(n_clients, 2))),
    )


def observed_consensus_rate(topo, *, n_clients: int, samples: int,
                            k: int, seed: int) -> float:
    """Geometric per-round decay of the engine's divergence metric over a
    fixed-K run: ``(div_K / div_1) ** (1 / (K - 1))`` (1.0 = no
    contraction). Compare against ``1 - ergodic_gap``."""
    src = common.build_source(n_clients=n_clients, samples=samples, seed=seed)
    key = jax.random.key(seed)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(n_clients=n_clients, tau=2, eta=0.05,
                            mine_attempts=32, difficulty_bits=2,
                            topology=topo)
    _, hist, _ = rounds.run_blade_fl(
        mlp_loss, spec, params, src.static_batch(),
        jax.random.fold_in(key, 2), k)
    divs = [h["divergence"] for h in hist]
    if len(divs) < 2 or divs[0] <= 0 or \
            not all(math.isfinite(d) for d in divs):
        return float("nan")
    return (max(divs[-1], 1e-12) / divs[0]) ** (1.0 / (len(divs) - 1))


def bench(samples: int = 128, n_clients: int = 20, beta: float = 6.0,
          seed: int = 0, rate_k: int = 10) -> dict:
    results = {}
    print(f"{'schedule':>16} {'K*':>3} {'eval_loss':>9} {'accuracy':>8} "
          f"{'erg_gap':>8} {'pred_rate':>9} {'obs_rate':>8}")
    for name, topo in schedules(n_clients):
        res = common.sweep_k(n_clients=n_clients, samples=samples, beta=beta,
                             seed=seed, topology=topo)
        best = common.best_of(res, key="eval_loss")
        # replay the SAME run key observed_consensus_rate passes the driver,
        # so a stochastic schedule's predicted rate uses the run's exact
        # per-round graphs
        run_key = jax.random.fold_in(jax.random.key(seed), 2)
        keys = (rounds.topology_keys(run_key, rate_k)
                if topo.stochastic else None)
        gap = spectral.ergodic_gap(topo, n_clients, n_rounds=rate_k,
                                   keys=keys)
        obs = observed_consensus_rate(topo, n_clients=n_clients,
                                      samples=samples, k=rate_k, seed=seed)
        results[name] = {
            "best_k": best["k"], "eval_loss": best["eval_loss"],
            "accuracy": best["accuracy"],
            "eval_loss_vs_k": {r["k"]: r["eval_loss"] for r in res},
            "ergodic_gap": gap,
            "predicted_consensus_rate": 1.0 - gap,
            "observed_consensus_rate": obs,
        }
        print(f"{name:>16} {best['k']:>3} {best['eval_loss']:>9.4f} "
              f"{best['accuracy']:>8.3f} {gap:>8.4f} {1.0 - gap:>9.4f} "
              f"{obs:>8.4f}")
        common.csv_line(
            f"schedule_{name}_C{n_clients}",
            best["us_per_round"],
            f"best_k={best['k']},eval_loss={best['eval_loss']:.4f},"
            f"ergodic_gap={gap:.4f}")
    # sanity of the diagnostic: schedules ordered by gap should order by
    # observed contraction (lower rate = faster consensus)
    ordered = sorted(results.items(), key=lambda kv: -kv[1]["ergodic_gap"])
    results["_gap_rate_ranking"] = [
        {"schedule": n, "ergodic_gap": r["ergodic_gap"],
         "observed_consensus_rate": r["observed_consensus_rate"]}
        for n, r in ordered]
    return results


def run():
    return bench()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=128)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--beta", type=float, default=6.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate-k", type=int, default=10)
    a = ap.parse_args()
    bench(a.samples, a.clients, a.beta, a.seed, a.rate_k)
