"""Byzantine attack vs robust aggregation, side by side.

Three identical runs on a ring schedule — same data, same keys, only the
adversary/defense axis changes:

  1. clean baseline        linear ring mix, no attack
  2. ALIE vs linear        3 colluding "a little is enough" attackers bias
                           every coordinate of the mean from inside the
                           honest variance envelope
  3. ALIE vs trimmed mean  the same attack against RoundSpec.robust_agg =
                           "trimmed:3" — the order statistic drops the
                           colluding tail per coordinate

Prints the per-round detection suspect mask (the colluding ALIE broadcasts
are identical, so the plagiarism detector flags the cabal even though each
broadcast individually evades the norm test) and the final held-out loss
gap each configuration pays.

  PYTHONPATH=src python examples/byzantine_defense.py --rounds 6
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import attacks, detection, rounds, topology
from repro.core.aggregation import aggregate_once
from repro.data.pipeline import FLDataSource
from repro.models.mlp import init_mlp, mlp_loss


def run(name, src, params, key, k_rounds, atk=None, robust=None,
        n_clients=12, tau=2):
    spec = rounds.RoundSpec(
        n_clients=n_clients, tau=tau, eta=0.05, mine_attempts=64,
        difficulty_bits=2, topology=topology.Ring(neighbors=2),
        attack=atk, robust_agg=robust)
    state, hist, ledger = rounds.run_blade_fl(
        mlp_loss, spec, params, src.static_batch(), key, k_rounds)
    eval_loss, m = mlp_loss(aggregate_once(state.params), src.eval_data)
    print(f"\n== {name} ==")
    print(f"  mix: {rounds.LAST_DISPATCH['mix']} "
          f"({rounds.LAST_DISPATCH['mix_mode']}), "
          f"chain valid: {ledger.validate_chain()}")
    for i, h in enumerate(hist):
        print(f"  round {i}: global_loss={h['global_loss']:.4f} "
              f"divergence={h['divergence']:.3e}")
    print(f"  final eval_loss={float(eval_loss):.4f} "
          f"accuracy={float(m['accuracy']):.3f}")
    return state, float(eval_loss)


def show_detection(src, params, key, atk, n_clients=12):
    """One un-aggregated round under attack: what every client's detector
    vote sees in the post-attack broadcast set (Step 2)."""
    spec = rounds.RoundSpec(n_clients=n_clients, tau=2, eta=0.05,
                            mine_attempts=64, difficulty_bits=2,
                            topology=topology.Ring(neighbors=2), attack=atk)
    local_train = jax.jit(rounds.make_local_train(mlp_loss, spec))
    attack = rounds.make_attack(spec)
    from repro.core.aggregation import replicate
    p = replicate(params, n_clients)
    p, _ = local_train(p, src.static_batch())
    p, _ = attack(p, jax.random.key(99))
    mask, _ = detection.detect_lazy_round(p, params)
    met = detection.detection_metrics(mask, atk.n_attackers)
    flags = "".join("X" if f else "." for f in np.asarray(mask))
    print(f"\nper-client suspect mask (first {atk.n_attackers} are the "
          f"cabal): [{flags}]")
    how = ("colluding ALIE broadcasts are identical -> plagiarism test"
           if isinstance(atk, attacks.ALIE) else "update-norm outlier test")
    print(f"detection precision={met['precision']:.2f} "
          f"recall={met['recall']:.2f} flagged={met['flagged']}  ({how})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=12)
    ap.add_argument("--attackers", type=int, default=3)
    ap.add_argument("--z", type=float, default=1.5)
    ap.add_argument("--attack", default=None,
                    help="override the ALIE default: signflip[:scale] | "
                         "noise[:sigma2[:scale]] | alie[:z] | "
                         "replace[:boost]")
    args = ap.parse_args()

    key = jax.random.key(0)
    src = FLDataSource(key, args.clients, samples_per_client=64, seed=0)
    params = init_mlp(jax.random.fold_in(key, 1))
    run_key = jax.random.fold_in(key, 2)
    if args.attack:
        atk = attacks.from_name(args.attack, args.attackers)
    else:
        atk = attacks.ALIE(n_attackers=args.attackers, z=args.z)
    print(f"{args.clients} clients, {args.attackers} x "
          f"{type(atk).__name__} attackers, ring(2) schedule, "
          f"K={args.rounds}")

    _, clean = run("clean baseline (linear ring)", src, params, run_key,
                   args.rounds, n_clients=args.clients)
    atk_name = type(atk).__name__
    _, attacked = run(f"{atk_name} vs linear ring", src, params, run_key,
                      args.rounds, atk=atk, n_clients=args.clients)
    _, defended = run(f"{atk_name} vs trimmed:3", src, params, run_key,
                      args.rounds, atk=atk, robust="trimmed:3",
                      n_clients=args.clients)

    show_detection(src, params, run_key, atk, args.clients)

    print(f"\nfinal eval-loss gap vs clean: "
          f"linear {attacked - clean:+.4f}, "
          f"trimmed {defended - clean:+.4f}")
    print("(try --attack signflip:8 to watch the linear gap explode while "
          "trimmed stays pinned — benchmarks/bench_robust.py sweeps this "
          "properly)")


if __name__ == "__main__":
    main()
