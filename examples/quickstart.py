"""Quickstart: a complete BLADE-FL run in ~40 lines.

20 clients, non-IID synthetic MNIST proxy, K=5 integrated rounds under a
t_sum=100 budget — local training, lazy clients, PoW mining, hash-chained
blocks, decentralized aggregation — then evaluate the final global model.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import BladeConfig
from repro.core import allocation, rounds, topology
from repro.core.aggregation import aggregate_once
from repro.data.pipeline import FLDataSource
from repro.models.mlp import init_mlp, mlp_loss


def main():
    blade = BladeConfig(n_clients=20, K=5, t_sum=100.0, alpha=1.0, beta=10.0,
                        eta=0.05, n_lazy=2, sigma2=0.01)
    tau = allocation.tau_from_budget(blade.t_sum, blade.K, blade.alpha,
                                     blade.beta)
    print(f"budget t_sum={blade.t_sum}: K={blade.K} rounds x "
          f"(tau={tau} local iters + mining)")

    key = jax.random.key(0)
    data = FLDataSource(key, blade.n_clients, blade.samples_per_client,
                        blade.dirichlet_alpha)
    params = init_mlp(jax.random.fold_in(key, 1))
    # topology=FullMesh() is the paper's Step 2+5 (broadcast to all, adopt
    # the aggregate) and the default — see examples/gossip_topologies.py for
    # ring / link-dropout / partial-participation variants of the same run.
    spec = rounds.RoundSpec(
        n_clients=blade.n_clients, tau=tau, eta=blade.eta,
        n_lazy=blade.n_lazy, sigma2=blade.sigma2,
        mine_attempts=allocation.mining_iterations(blade.beta),
        difficulty_bits=4, topology=topology.FullMesh())

    # static_batch() (full-batch GD reuses one [C, m, ...] batch) routes
    # run_blade_fl onto the compiled lax.scan engine: all K rounds on device,
    # one host transfer at the end.
    state, history, ledger = rounds.run_blade_fl(
        mlp_loss, spec, params, data.static_batch(), jax.random.fold_in(key, 2),
        blade.K)

    for k, h in enumerate(history):
        print(f"round {k}: global_loss={h['global_loss']:.4f} "
              f"miner={int(h['winner'])} hash={int(h['pow_hash']):#010x}")
    loss, metrics = mlp_loss(aggregate_once(state.params), data.eval_data)
    print(f"\nchain valid: {ledger.validate_chain()} "
          f"({len(ledger.blocks)} blocks)")
    print(f"final eval: loss={float(loss):.4f} "
          f"accuracy={float(metrics['accuracy']):.3f}")


if __name__ == "__main__":
    main()
