"""Lazy-client study (paper §5): how plagiarism + artificial noise degrade
BLADE-FL, and how the optimal allocation shifts (Corollary 5).

  PYTHONPATH=src python examples/lazy_clients.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common


def main():
    # common.sweep_k feeds a static batch to run_blade_fl, so every run in
    # these sweeps executes on the compiled lax.scan multi-round engine.
    print("lazy-ratio sweep (sigma^2 = 0.01, beta = 6)")
    print(f"{'M/N':>5} {'K*':>3} {'train_time':>10} {'loss':>8} {'acc':>6}")
    for frac in (0.0, 0.1, 0.2, 0.3):
        m = int(20 * frac)
        res = common.sweep_k(n_lazy=m, sigma2=0.01, beta=6.0, samples=192)
        best = common.best_of(res)
        print(f"{frac:>5.0%} {best['k']:>3} {best['train_time']:>10.0f} "
              f"{best['final_loss']:>8.4f} {best['accuracy']:>6.3f}")

    print("\nnoise-power sweep (M/N = 20%)")
    print(f"{'s^2':>5} {'K*':>3} {'train_time':>10} {'loss':>8} {'acc':>6}")
    for s2 in (0.01, 0.1, 0.3):
        res = common.sweep_k(n_lazy=4, sigma2=s2, beta=6.0, samples=192)
        best = common.best_of(res)
        print(f"{s2:>5.2f} {best['k']:>3} {best['train_time']:>10.0f} "
              f"{best['final_loss']:>8.4f} {best['accuracy']:>6.3f}")




def detection_demo():
    """Beyond-paper: in-round plagiarism detection (paper §8 future work)."""
    import jax
    from repro.core import rounds
    from repro.data.pipeline import FLDataSource
    from repro.models.mlp import init_mlp, mlp_loss

    key = jax.random.key(0)
    src = FLDataSource(key, 10, 128)
    params = init_mlp(jax.random.fold_in(key, 1))
    spec = rounds.RoundSpec(n_clients=10, tau=6, eta=0.2, n_lazy=3,
                            sigma2=0.01, mine_attempts=64, detect_lazy=True)
    fn = jax.jit(rounds.make_integrated_round(mlp_loss, spec))
    st = rounds.init_state(params, jax.random.key(2), 10)
    print("\nin-round plagiarism detection (3 true lazy clients):")
    for k in range(3):
        st, m = fn(st, src.round_batch(k))
        print(f"  round {k}: flagged {int(m['n_suspects'])} suspects")


if __name__ == "__main__":
    main()
    detection_demo()
