"""Cohort sampling: a 5,000-client enrolled population on one CPU.

The paper's experiments run every enrolled client every round — fine at
N = 20, impossible at federated-population scale where a [C, C] mixing
matrix alone would be gigabytes. The cohort driver keeps the paper's
integrated round (local training, lazy/DP perturbation, gossip mix, PoW
race, hash-linked ledger) but runs it on a per-round COHORT of A clients
drawn from the enrolled population: devices only ever hold the [A, ...]
stack, the intra-cohort mix is the sparse O(A·deg) segment path, and the
population lives in a lazy host-side store that materializes a client's
row only after it first participates.

Cohort membership is drawn from the engine's own per-round topology key
stream, so ``rounds.topology_keys(run_key, K)`` replays exactly which
clients were active each round — the same replayability contract the
stochastic topologies have.

  PYTHONPATH=src python examples/cohort_population.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core import rounds, topology
from repro.data.pipeline import CohortDataSource
from repro.models.mlp import init_mlp, mlp_loss


def main():
    n_enrolled, cohort_size, k_rounds = 5_000, 8, 6
    key = jax.random.key(0)
    data = CohortDataSource(key, samples_per_client=64, dirichlet_alpha=0.2)
    params = init_mlp(jax.random.fold_in(key, 1))

    # Pareto(1.5) participation weights: a heavy head of frequently-online
    # clients and a long tail that almost never joins — the realistic
    # availability skew uniform sampling papers over.
    cohort = topology.CohortSchedule.from_spec(
        n_enrolled, cohort_size, "pareto:1.5")
    spec = rounds.RoundSpec(n_clients=cohort_size, tau=4, eta=0.1,
                            mine_attempts=64, difficulty_bits=2,
                            topology=topology.FullMesh())

    run_key = jax.random.fold_in(key, 2)
    store, hist, ledger = rounds.run_blade_fl_cohort(
        mlp_loss, spec, params, data.cohort_batch, run_key, k_rounds, cohort)

    print(f"{'round':>5} {'cohort (client ids)':>34} {'local_loss':>10}")
    for k, h in enumerate(hist):
        ids = ",".join(str(i) for i in h["cohort"])
        print(f"{k:>5} {ids:>34} {h['local_loss_mean']:>10.4f}")

    # replay check: the published key stream reproduces every membership
    keys = rounds.topology_keys(run_key, k_rounds)
    replayed = [[int(i) for i in cohort.cohort_at(kt)] for kt in keys]
    assert replayed == [h["cohort"] for h in hist]

    print(f"\nenrolled {n_enrolled}, cohort {cohort_size}, {k_rounds} rounds")
    print(f"clients ever active: {store.touched} "
          f"(host stores {store.materialized_bytes() / 1e6:.1f} MB, "
          f"not {n_enrolled} model copies)")
    print(f"chain valid: {ledger.validate_chain()} "
          f"({len(ledger.blocks)} blocks)")
    print("cohort replay from rounds.topology_keys: exact")


if __name__ == "__main__":
    main()
