"""Batched serving example: prefill + KV-cache greedy decode on a reduced
assigned architecture (same code path the 512-chip dry-run lowers).

  PYTHONPATH=src python examples/serve_decode.py --arch deepseek-v2-236b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-236b")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    serve(ap.parse_args())


if __name__ == "__main__":
    main()
