"""Scheduled (time-varying) topologies: gossip rotation, epoch alternation,
and SNR link-quality fading — with spectral-gap diagnostics.

A static ring keeps talking to the same neighbors, so disagreement between
far-apart clients contracts slowly (small spectral gap 1 - |lambda_2(W)|).
A one-peer gossip ROTATION moves the same per-round communication budget
(one partner per client) around the ring round-robin: each phase barely
mixes, but the product over one period mixes almost like a full mesh — the
ergodic gap is the per-round rate that product actually achieves, and the
engine's measured client spread follows it. All schedules run inside the
same compiled ``lax.scan`` (one trace for all K rounds) and stay
bit-for-bit equal between the scan and the per-round Python loop.

  PYTHONPATH=src python examples/scheduled_gossip.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import rounds, spectral, topology
from repro.core.aggregation import aggregate_once, client_divergence
from repro.data.pipeline import FLDataSource
from repro.models.mlp import init_mlp, mlp_loss


def main():
    n_clients, k_rounds, tau = 12, 11, 4   # one full rotation period = 11
    key = jax.random.key(0)
    data = FLDataSource(key, n_clients, samples_per_client=128,
                        dirichlet_alpha=0.2)
    params = init_mlp(jax.random.fold_in(key, 1))

    cases = [
        ("full mesh (paper)", topology.FullMesh()),
        ("static ring, 1 nbr", topology.Ring(neighbors=1)),
        ("gossip rotation", topology.GossipRotation()),
        ("alt: ring x3 + mesh", topology.AlternatingSchedule(
            ((topology.Ring(neighbors=1), 3), (topology.FullMesh(), 1)))),
        ("snr fading (period 8)", topology.LinkQualitySchedule(
            fading_period=8)),
    ]

    print(f"{'schedule':>22} {'loss@K':>8} {'eval_acc':>8} {'spread':>10} "
          f"{'gap/round':>9} {'erg_gap':>8}")
    for name, topo in cases:
        spec = rounds.RoundSpec(n_clients=n_clients, tau=tau, eta=0.1,
                                mine_attempts=64, difficulty_bits=2,
                                topology=topo)
        # static batch -> every schedule runs on the compiled scan engine
        state, hist, ledger = rounds.run_blade_fl(
            mlp_loss, spec, params, data.static_batch(),
            jax.random.fold_in(key, 2), k_rounds)
        assert ledger.validate_chain()
        spread = float(client_divergence(state.params))
        loss, m = mlp_loss(aggregate_once(state.params), data.eval_data)
        rep = spectral.gap_report(topo, n_clients, k_rounds)
        print(f"{name:>22} {hist[-1]['global_loss']:>8.4f} "
              f"{float(m['accuracy']):>8.3f} {spread:>10.3e} "
              f"{rep['gap_mean']:>9.4f} {rep['ergodic_gap']:>8.4f}")

    # the rotation's partner cycles round-robin; each phase is one
    # collective_permute pair, yet the period mixes everything
    rot = topology.GossipRotation()
    print("\nrotation partners (client 0), C=6:",
          [(0 + rot.shift_at(t, 6)) % 6 for t in range(rot.period(6))])
    print("per-phase gap:",
          np.round(spectral.per_round_gaps(rot, 6, rot.period(6)), 3))
    print("ergodic gap over one period:",
          round(spectral.ergodic_gap(rot, 6), 4))


if __name__ == "__main__":
    main()
