"""End-to-end driver: BLADE-FL integrated rounds wrapped around an assigned
architecture (reduced config) with a real LM objective — the paper's
technique as a first-class feature of the training framework.

Runs a few hundred local GD iterations total (tau x K x clients) on a ~1M
param reduced model; prints the chain and the per-round global loss.

  PYTHONPATH=src python examples/arch_fl_training.py --arch xlstm-125m \
      --rounds 6 --clients 4
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import ShapeConfig, get_smoke_arch
from repro.core import rounds, topology
from repro.data.pipeline import LMDataSource
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lazy", type=int, default=1)
    ap.add_argument("--topology", default="full",
                    help="full | ring[:k] | random[:p] | partial:n")
    ap.add_argument("--eval-every", type=int, default=1)
    args = ap.parse_args()

    cfg = get_smoke_arch(args.arch)
    shape = ShapeConfig("t", args.seq, args.clients * 4, "train")
    src = LMDataSource(cfg, shape, args.clients)
    key = jax.random.key(0)
    params = registry.init_model(key, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params:,} params x {args.clients} clients, "
          f"tau={args.tau}, {args.rounds} rounds, {args.lazy} lazy")

    spec = rounds.RoundSpec(n_clients=args.clients, tau=args.tau, eta=5e-3,
                            n_lazy=args.lazy, sigma2=1e-4,
                            mine_attempts=512, difficulty_bits=3,
                            eval_every=args.eval_every,
                            topology=topology.from_name(args.topology))

    def loss_fn(p, b):
        return registry.loss_fn(p, cfg, b, remat=False)

    # per-round token streams, stacked [K, C, ...] so the whole horizon runs
    # inside the compiled scan engine
    state, hist, ledger = rounds.run_blade_fl(
        loss_fn, spec, params, src.stacked_batches(args.rounds),
        jax.random.fold_in(key, 1), args.rounds, stacked=True)
    for k, h in enumerate(hist):
        print(f"round {k}: loss={h['global_loss']:.4f} "
              f"divergence={h['divergence']:.3e} miner={int(h['winner'])}")
    print(f"chain valid: {ledger.validate_chain()} "
          f"({len(ledger.blocks)} blocks)")


if __name__ == "__main__":
    main()
