"""Communication topologies: the same BLADE-FL run under full mesh, ring
gossip, per-round link dropout, and static partial participation.

The paper's Step 2+5 is a full mesh — after every round all clients hold the
identical aggregate, so the post-round client spread is zero. Swapping the
``RoundSpec.topology`` (no other change: same data, same seeds, same chain)
turns Steps 2+5 into a row-stochastic mixing matrix and opens the
partial-connectivity regimes of the related work: under ring gossip or link
dropout the clients no longer reach consensus each round, divergence stays
positive, and learning slows at the same budget.

  PYTHONPATH=src python examples/gossip_topologies.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import rounds, topology
from repro.core.aggregation import aggregate_once, client_divergence
from repro.data.pipeline import FLDataSource
from repro.models.mlp import init_mlp, mlp_loss


def main():
    n_clients, k_rounds, tau = 12, 6, 4
    key = jax.random.key(0)
    data = FLDataSource(key, n_clients, samples_per_client=128,
                        dirichlet_alpha=0.2)
    params = init_mlp(jax.random.fold_in(key, 1))

    topologies = [
        ("full mesh (paper)", topology.FullMesh()),
        ("ring, 1 neighbor", topology.Ring(neighbors=1)),
        ("ring, 2 neighbors", topology.Ring(neighbors=2)),
        ("link dropout p=0.5", topology.RandomGraph(p_link=0.5)),
        ("partial, 6 of 12", topology.PartialParticipation(n_active=6)),
    ]

    print(f"{'topology':>20} {'loss@K':>8} {'eval_acc':>8} {'post-round spread':>18}")
    for name, topo in topologies:
        spec = rounds.RoundSpec(n_clients=n_clients, tau=tau, eta=0.1,
                                mine_attempts=64, difficulty_bits=2,
                                topology=topo)
        # static batch -> every topology runs on the compiled scan engine
        state, hist, ledger = rounds.run_blade_fl(
            mlp_loss, spec, params, data.static_batch(),
            jax.random.fold_in(key, 2), k_rounds)
        assert ledger.validate_chain()
        # consensus check: full mesh collapses the client spread every round,
        # partial topologies leave residual disagreement
        spread = float(client_divergence(state.params))
        loss, m = mlp_loss(aggregate_once(state.params), data.eval_data)
        print(f"{name:>20} {hist[-1]['global_loss']:>8.4f} "
              f"{float(m['accuracy']):>8.3f} {spread:>18.3e}")

    # mixing matrices themselves, for a tiny C (rows sum to 1)
    print("\nring(1) mixing matrix, C=5:")
    print(jnp.round(topology.Ring(1).matrix(5), 3))


if __name__ == "__main__":
    main()
