"""Resource allocation study (paper §4): sweep K, compare the empirical loss
against the Theorem-1 upper bound, and check the Theorem-3 closed-form K*.

  PYTHONPATH=src python examples/resource_allocation.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common
from repro.core import allocation, bounds


def main():
    eta, alpha, beta, t_sum = 0.01, 1.0, 8.0, 100.0
    print(f"sweeping K (t_sum={t_sum}, alpha={alpha}, beta={beta}, eta={eta})")
    results = common.sweep_k(eta=eta, alpha=alpha, beta=beta, t_sum=t_sum,
                             samples=192)
    p = common.fit_bound_params(results, eta=eta, alpha=alpha, beta=beta,
                                t_sum=t_sum)
    print(f"calibrated: L={p.L:.3f} xi={p.xi:.3f} delta={p.delta:.3f} "
          f"w0={p.w0_dist:.3f}")
    print(f"{'K':>3} {'tau':>4} {'train':>6} {'mine':>5} "
          f"{'loss':>8} {'bound':>8} {'acc':>6}")
    for r in results:
        b = bounds.loss_bound(p, r["k"])
        print(f"{r['k']:>3} {r['tau']:>4} {r['train_time']:>6.0f} "
              f"{r['mine_time']:>5.0f} {r['final_loss']:>8.4f} "
              f"{b:>8.4f} {r['accuracy']:>6.3f}")
    best = common.best_of(results)
    k_cf = bounds.k_star_closed_form(p)
    k_num = bounds.k_star_numeric(p)
    print(f"\nempirical K*={best['k']}  bound-argmin K*={k_num}  "
          f"closed-form (eq.6) K*={k_cf:.2f}")
    plan = allocation.plan(t_sum, best["k"], alpha, beta)
    print(f"optimal split: train {plan.train_time:.0f} / "
          f"mine {plan.mine_time:.0f} of {t_sum:.0f}")


if __name__ == "__main__":
    main()
