#!/usr/bin/env python
"""Docs link checker (CI docs lane).

Scans README.md and docs/*.md for markdown links and inline code paths:

  * relative links must resolve to an existing file/dir (anchors stripped);
  * bare `path/to/file.py` references in backticks must exist too, so the
    architecture/paper-map tables can't silently rot as modules move;
  * `core/rounds.make_local_train`-style symbol citations (paper_map.md's
    anchor format) must resolve to a real module symbol, via the same AST
    walk repro-lint uses (tools/repro_lint/symbols.py);
  * external http(s) links are skipped (checking them needs network).

Exit code 1 with a per-file report when anything dangles.

  python tools/check_doc_links.py
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, ROOT)

from tools.repro_lint.symbols import build_index  # noqa: E402

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `src/...py` / `tests/...py` / `benchmarks/...py` / `docs/...md` style
# backtick references; a trailing path component is enough to check.
CODE_PATH = re.compile(
    r"`((?:src|tests|benchmarks|docs|examples|tools)/[\w./-]+\.(?:py|md|yml))`")
# `core/rounds.make_local_train` / `core/chain.Ledger.append` /
# `core/bounds.g_of_k(M=256, ...)` style symbol citations: a repo module
# path (no extension) dotted into a symbol chain, optional call suffix.
SYMBOL_REF = re.compile(
    r"`((?:core|sharding|launch|models|data|training|kernels|configs"
    r"|benchmarks|examples|tools)/[\w/]+)"
    r"\.([A-Za-z_]\w*(?:\.[A-Za-z_]\w*)*)(?:\([^`]*\))?`")
_EXTENSIONS = {"py", "md", "yml", "yaml", "json", "txt", "toml", "sh"}


def doc_files():
    yield os.path.join(ROOT, "README.md")
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        for name in sorted(os.listdir(docs)):
            if name.endswith(".md"):
                yield os.path.join(docs, name)


def check_symbol_ref(module: str, symbols: str, index) -> str | None:
    """Return an error string when `module.symbols` doesn't resolve."""
    if module not in index:
        return f"dangling symbol ref: `{module}.{symbols}` (no such module)"
    parts = symbols.split(".")
    have = index[module]
    if parts[0] not in have:
        return (f"dangling symbol ref: `{module}.{symbols}` "
                f"({parts[0]} not defined in {module})")
    if len(parts) > 1 and ".".join(parts[:2]) not in have:
        return (f"dangling symbol ref: `{module}.{symbols}` "
                f"({parts[0]}.{parts[1]} not defined in {module})")
    return None


def check_file(path: str, index) -> list[str]:
    base = os.path.dirname(path)
    text = open(path, encoding="utf-8").read()
    errors = []
    for target in MD_LINK.findall(text):
        if target.startswith(("http://", "https://")):
            continue  # external: existence needs network, skip in CI
        if target.startswith(("#", "mailto:")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(os.path.join(base, rel))
        if not os.path.exists(resolved):
            errors.append(f"dangling link: {target}")
    for target in set(CODE_PATH.findall(text)):
        if not os.path.exists(os.path.join(ROOT, target)):
            errors.append(f"dangling code path: {target}")
    for module, symbols in sorted(set(SYMBOL_REF.findall(text))):
        if symbols.split(".")[0] in _EXTENSIONS:
            continue  # a file path like `docs/paper_map.md`, not a symbol
        err = check_symbol_ref(module, symbols, index)
        if err:
            errors.append(err)
    return errors


def main() -> int:
    failed = False
    index = build_index(ROOT)
    for path in doc_files():
        errors = check_file(path, index)
        rel = os.path.relpath(path, ROOT)
        if errors:
            failed = True
            print(f"{rel}: {len(errors)} problem(s)")
            for e in errors:
                print(f"  - {e}")
        else:
            print(f"{rel}: ok")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
