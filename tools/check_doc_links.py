#!/usr/bin/env python
"""Docs link checker (CI docs lane).

Scans README.md and docs/*.md for markdown links and inline code paths:

  * relative links must resolve to an existing file/dir (anchors stripped);
  * bare `path/to/file.py` references in backticks must exist too, so the
    architecture/paper-map tables can't silently rot as modules move;
  * external http(s) links are skipped (checking them needs network).

Exit code 1 with a per-file report when anything dangles.

  python tools/check_doc_links.py
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `src/...py` / `tests/...py` / `benchmarks/...py` / `docs/...md` style
# backtick references; a trailing path component is enough to check.
CODE_PATH = re.compile(
    r"`((?:src|tests|benchmarks|docs|examples|tools)/[\w./-]+\.(?:py|md|yml))`")


def doc_files():
    yield os.path.join(ROOT, "README.md")
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        for name in sorted(os.listdir(docs)):
            if name.endswith(".md"):
                yield os.path.join(docs, name)


def check_file(path: str) -> list[str]:
    base = os.path.dirname(path)
    text = open(path, encoding="utf-8").read()
    errors = []
    for target in MD_LINK.findall(text):
        if target.startswith(("http://", "https://")):
            continue  # external: existence needs network, skip in CI
        if target.startswith(("#", "mailto:")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(os.path.join(base, rel))
        if not os.path.exists(resolved):
            errors.append(f"dangling link: {target}")
    for target in set(CODE_PATH.findall(text)):
        if not os.path.exists(os.path.join(ROOT, target)):
            errors.append(f"dangling code path: {target}")
    return errors


def main() -> int:
    failed = False
    for path in doc_files():
        errors = check_file(path)
        rel = os.path.relpath(path, ROOT)
        if errors:
            failed = True
            print(f"{rel}: {len(errors)} problem(s)")
            for e in errors:
                print(f"  - {e}")
        else:
            print(f"{rel}: ok")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
