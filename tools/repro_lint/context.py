"""Traced-scope inference shared by every repro-lint rule.

The engine (see docs/architecture.md) traces Python functions exactly once
and replays the jaxpr; code that is correct at trace time but wrong at run
time (host entropy, asserts on traced values, device-side scalar reduces)
is invisible to unit tests that happen to hit the same trace. The rules
therefore need a static, conservative answer to "does this code run under
``jax.jit``/``lax.scan`` tracing?". We say a function is *traced* when:

* it is decorated with a tracing transform (``@jax.jit``, ``@jax.checkpoint``,
  ``@pl.when(...)``, ``functools.partial(jax.jit, ...)``), or
* it is passed by name (or inline ``lambda``) to a transform call —
  ``lax.scan``/``cond``/``switch``/``while_loop``/``fori_loop``,
  ``jax.jit``/``vmap``/``grad``/``value_and_grad``, ``shard_map``,
  ``pl.pallas_call`` — anywhere in the module, or
* it is nested (at any depth) inside a ``make_*``/``build_*`` stage factory
  (the repo-wide convention: factories close over static config and return
  functions that run under the scan; ``core/rounds.py``), or
* it is nested inside any function already deemed traced.

This intentionally over-approximates (a helper shared by host and device
paths counts as traced); suppressions exist for the rare deliberate case.
Pure stdlib ``ast`` — no jax import, so the lint lane needs no JAX runtime.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

# Callees whose function-valued arguments run under trace.
TRANSFORM_CALLEES = frozenset({
    "jit", "grad", "value_and_grad", "jacfwd", "jacrev", "hessian",
    "vmap", "checkpoint", "remat", "custom_jvp", "custom_vjp",
    "scan", "cond", "switch", "while_loop", "fori_loop", "associative_scan",
    "map", "shard_map", "pallas_call",
})

# Decorator names that put the decorated body under trace. ``when`` is
# ``pl.when(...)`` inside Pallas kernels.
TRACED_DECORATORS = TRANSFORM_CALLEES | {"when"}

FACTORY_PREFIXES = ("make_", "build_")


def terminal_name(node: ast.AST) -> Optional[str]:
    """``jax.lax.psum`` -> ``'psum'``; ``psum`` -> ``'psum'``; else None."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def dotted_name(node: ast.AST) -> Optional[str]:
    """``np.random.normal`` -> ``'np.random.normal'`` (None if not a pure
    dotted ``Name.attr.attr...`` chain)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class ScopeInfo:
    """Parent links + the traced-function set for one module AST."""

    def __init__(self, tree: ast.Module):
        self.tree = tree
        self.parent = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                self.parent[child] = node
        self.functions = [n for n in ast.walk(tree)
                          if isinstance(n, FUNC_NODES)]
        directly_traced = set()
        traced_names = set()
        for call in (n for n in ast.walk(tree) if isinstance(n, ast.Call)):
            callee = terminal_name(call.func)
            argv = list(call.args) + [k.value for k in call.keywords]
            if callee in TRANSFORM_CALLEES:
                for arg in argv:
                    if isinstance(arg, ast.Name):
                        traced_names.add(arg.id)
                    elif isinstance(arg, FUNC_NODES):
                        directly_traced.add(arg)
            elif callee == "partial" and any(
                    terminal_name(a) in TRANSFORM_CALLEES for a in call.args):
                # functools.partial(jax.jit, fn, ...) / partial(shard_map, f)
                for arg in call.args[1:]:
                    if isinstance(arg, ast.Name):
                        traced_names.add(arg.id)
        for fn in self.functions:
            if isinstance(fn, ast.Lambda):
                continue
            if fn.name in traced_names:
                directly_traced.add(fn)
            for dec in fn.decorator_list:
                head = dec.func if isinstance(dec, ast.Call) else dec
                if terminal_name(head) in TRACED_DECORATORS:
                    directly_traced.add(fn)
                elif (isinstance(dec, ast.Call)
                      and terminal_name(dec.func) == "partial"
                      and any(terminal_name(a) in TRANSFORM_CALLEES
                              for a in dec.args)):
                    directly_traced.add(fn)
        self._traced = set()
        for fn in self.functions:
            if fn in directly_traced or self._inherits_trace(
                    fn, directly_traced):
                self._traced.add(fn)

    def _inherits_trace(self, fn, directly_traced) -> bool:
        anc = self.parent.get(fn)
        while anc is not None:
            if isinstance(anc, FUNC_NODES):
                if anc in directly_traced:
                    return True
                if (isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and anc.name.startswith(FACTORY_PREFIXES)):
                    return True
            anc = self.parent.get(anc)
        return False

    def enclosing_functions(self, node: ast.AST) -> Iterator[ast.AST]:
        """Innermost-first chain of function nodes containing ``node``."""
        anc = self.parent.get(node)
        while anc is not None:
            if isinstance(anc, FUNC_NODES):
                yield anc
            anc = self.parent.get(anc)

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        return next(self.enclosing_functions(node), None)

    def outermost_function(self, node: ast.AST) -> Optional[ast.AST]:
        outer = None
        for fn in self.enclosing_functions(node):
            outer = fn
        return outer

    def is_traced(self, fn: ast.AST) -> bool:
        return fn in self._traced

    def in_traced_scope(self, node: ast.AST) -> bool:
        return any(f in self._traced for f in self.enclosing_functions(node))
