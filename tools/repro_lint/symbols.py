"""AST symbol index over the repo, shared by repro-lint consumers and
tools/check_doc_links.py (docs/paper_map.md cites symbols as
``core/rounds.make_local_train`` / ``core/chain.Ledger.append``; the doc
lane verifies those anchors exist so the map can't rot as modules move).

Pure stdlib ``ast`` — nothing here imports jax or the repo's own modules.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Set


def module_symbols(pyfile: str) -> Set[str]:
    """Top-level names of one module: functions, classes, constants, and
    ``Class.method`` / ``Class.attr`` one level deep."""
    with open(pyfile, encoding="utf-8") as fh:
        tree = ast.parse(fh.read(), filename=pyfile)
    out = set()

    def _targets(node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            yield node.name
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                elts = tgt.elts if isinstance(tgt, ast.Tuple) else [tgt]
                for e in elts:
                    if isinstance(e, ast.Name):
                        yield e.id
        elif isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name):
            yield node.target.id

    for node in tree.body:
        for name in _targets(node):
            out.add(name)
        if isinstance(node, ast.ClassDef):
            for sub in node.body:
                for name in _targets(sub):
                    out.add(f"{node.name}.{name}")
    return out


def build_index(root: str) -> Dict[str, Set[str]]:
    """Map citation-style module keys to their symbol sets.

    ``src/repro/core/rounds.py`` -> ``core/rounds`` (the ``src/repro``
    prefix is implicit in doc citations); top-level trees keep their
    directory: ``benchmarks/common.py`` -> ``benchmarks/common``,
    ``tools/check_doc_links.py`` -> ``tools/check_doc_links``.
    """
    index: Dict[str, Set[str]] = {}
    roots = [(os.path.join(root, "src", "repro"), ""),
             (os.path.join(root, "benchmarks"), "benchmarks"),
             (os.path.join(root, "examples"), "examples"),
             (os.path.join(root, "tools"), "tools")]
    for base, prefix in roots:
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames
                           if d != "__pycache__" and not d.startswith(".")]
            for name in sorted(filenames):
                if not name.endswith(".py"):
                    continue
                abspath = os.path.join(dirpath, name)
                rel = os.path.relpath(abspath, base).replace(os.sep, "/")
                key = rel[:-3]  # strip .py
                if key.endswith("__init__"):
                    key = key[:-len("__init__")].rstrip("/")
                if prefix:
                    key = f"{prefix}/{key}" if key else prefix
                if not key:
                    continue
                index[key] = module_symbols(abspath)
    return index
