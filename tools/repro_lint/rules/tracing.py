"""RL1xx — tracing discipline.

The engine traces each stage factory's closure once and replays the jaxpr
for all K rounds (docs/architecture.md §One compiled round). Host-side
control flow, host entropy, and unhashable static args are all trace-time
landmines that unit tests hitting a single trace never see.
"""
from __future__ import annotations

import ast

from tools.repro_lint.context import dotted_name, terminal_name
from tools.repro_lint.registry import rule

# --------------------------------------------------------------------------
# RL101


@rule("RL101", "assert inside a traced scope (invisible to the jaxpr; "
               "vanishes under python -O)")
def check_assert_in_traced(ctx):
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assert) and ctx.scopes.in_traced_scope(node):
            yield (node.lineno,
                   "assert inside a traced scope: it runs once at trace "
                   "time on tracers (and vanishes under `python -O`); "
                   "validate static args in the factory body, or use a "
                   "checked error on device values")


# --------------------------------------------------------------------------
# RL102

_MUTABLE_ANNOT = frozenset({
    "list", "List", "dict", "Dict", "set", "Set", "ndarray", "Array",
    "bytearray", "defaultdict", "deque", "MutableMapping", "MutableSequence",
})
_MUTABLE_FACTORY = frozenset({"list", "dict", "set"})


def _is_frozen_dataclass(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        if isinstance(dec, ast.Call) and terminal_name(dec.func) == "dataclass":
            for kw in dec.keywords:
                if (kw.arg == "frozen" and isinstance(kw.value, ast.Constant)
                        and kw.value.value is True):
                    return True
    return False


@rule("RL102", "mutable/unhashable field on a frozen dataclass used as a "
               "static jit arg")
def check_unhashable_static_field(ctx):
    for cls in ast.walk(ctx.tree):
        if not isinstance(cls, ast.ClassDef) or not _is_frozen_dataclass(cls):
            continue
        for stmt in cls.body:
            if not (isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)):
                continue
            names = {terminal_name(n) for n in ast.walk(stmt.annotation)
                     if isinstance(n, (ast.Name, ast.Attribute))}
            bad = sorted(names & _MUTABLE_ANNOT)
            factory = None
            if isinstance(stmt.value, ast.Call) and \
                    terminal_name(stmt.value.func) == "field":
                for kw in stmt.value.keywords:
                    if kw.arg == "default_factory" and \
                            terminal_name(kw.value) in _MUTABLE_FACTORY:
                        factory = terminal_name(kw.value)
            if bad or factory:
                what = bad[0] if bad else f"default_factory={factory}"
                yield (stmt.lineno,
                       f"field `{stmt.target.id}: {what}` makes frozen "
                       f"dataclass `{cls.name}` unhashable — these are "
                       "static-arg/lru_cache keys (RoundSpec, Topology); "
                       "use a Tuple instead")


# --------------------------------------------------------------------------
# RL103

_ENTROPY_PREFIXES = ("np.random.", "numpy.random.", "random.", "secrets.")
_ENTROPY_EXACT = frozenset({
    "time.time", "time.time_ns", "time.perf_counter", "time.monotonic",
    "datetime.now", "datetime.utcnow", "datetime.datetime.now",
    "datetime.datetime.utcnow", "uuid.uuid4",
})


@rule("RL103", "host entropy/clock call (np.random, time, datetime) inside "
               "a traced scope")
def check_host_entropy_in_traced(ctx):
    for call in ast.walk(ctx.tree):
        if not isinstance(call, ast.Call):
            continue
        dn = dotted_name(call.func)
        if dn is None:
            continue
        if dn in _ENTROPY_EXACT or dn.startswith(_ENTROPY_PREFIXES):
            if ctx.scopes.in_traced_scope(call):
                yield (call.lineno,
                       f"`{dn}(...)` in a traced scope is baked in as a "
                       "trace-time constant — replay and `topology_keys` "
                       "folding break; thread a jax.random key instead")


# --------------------------------------------------------------------------
# RL104


@rule("RL104", "validation assert in library code (vanishes under "
               "python -O); raise instead")
def check_library_assert(ctx):
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assert) and \
                not ctx.scopes.in_traced_scope(node):
            yield (node.lineno,
                   "validation assert in library code disappears under "
                   "`python -O`; raise ValueError/TypeError so callers "
                   "always get the check")
