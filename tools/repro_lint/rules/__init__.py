"""Rule modules register themselves on import (see registry.rule)."""
from tools.repro_lint.rules import fp32, kernels, sharding, tracing  # noqa: F401
