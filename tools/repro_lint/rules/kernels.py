"""RL4xx — Pallas kernel discipline.

Kernel call sites must (a) guard grid arithmetic that floor-divides a
runtime extent (pad, `%`-check, or ceil-div) and (b) pass an explicit
``interpret=`` so CPU CI exercises the kernel in interpret mode
(kernels/*/ops.py `_default_interpret`).
"""
from __future__ import annotations

import ast

from tools.repro_lint.context import terminal_name
from tools.repro_lint.registry import rule


def _pallas_calls(tree):
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and \
                terminal_name(node.func) == "pallas_call":
            yield node


def _is_ceil_div(fd: ast.BinOp) -> bool:
    # -(-a // b): the FloorDiv's left operand is a unary minus.
    return isinstance(fd.left, ast.UnaryOp) and isinstance(fd.left.op, ast.USub)


# --------------------------------------------------------------------------
# RL401


@rule("RL401", "pallas_call grid uses a plain floor-divide with no "
               "divisibility guard (pad / %-check / ceil-div)")
def check_grid_divisibility(ctx):
    for call in _pallas_calls(ctx.tree):
        grid = None
        for kw in call.keywords:
            if kw.arg == "grid":
                grid = kw.value
        if grid is None:
            continue
        scope = ctx.scopes.outermost_function(call) or ctx.tree
        # one-hop name resolution: n_blocks = ... // ... used in grid=(n_blocks,)
        local_defs = {}
        for stmt in ast.walk(scope):
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                local_defs[stmt.targets[0].id] = stmt.value
        exprs = [grid]
        for n in ast.walk(grid):
            if isinstance(n, ast.Name) and n.id in local_defs:
                exprs.append(local_defs[n.id])
        floordivs = [n for e in exprs for n in ast.walk(e)
                     if isinstance(n, ast.BinOp)
                     and isinstance(n.op, ast.FloorDiv)]
        unguarded = [fd for fd in floordivs if not _is_ceil_div(fd)]
        if not unguarded:
            continue
        guarded = any(
            (isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mod))
            or (isinstance(n, ast.Call) and terminal_name(n.func) == "pad")
            for n in ast.walk(scope))
        if not guarded:
            yield (call.lineno,
                   "grid floor-divides an extent with no divisibility guard "
                   "in scope: a non-multiple shape silently drops the tail "
                   "tile; pad the input, `%`-check the shape, or ceil-div "
                   "`-(-n // block)` with masking")


# --------------------------------------------------------------------------
# RL402


@rule("RL402", "pallas_call without an explicit interpret= fallback guard")
def check_interpret_guard(ctx):
    for call in _pallas_calls(ctx.tree):
        if not any(kw.arg == "interpret" for kw in call.keywords):
            yield (call.lineno,
                   "pallas_call without explicit `interpret=`: CPU CI (and "
                   "any TPU-less host) needs the interpret-mode fallback — "
                   "thread it like kernels/*/ops.py `_default_interpret()`")
