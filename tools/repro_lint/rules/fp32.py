"""RL3xx — fp32 association discipline.

The bitwise contract (docs/architecture.md §The bitwise contract) rests on
three association rules: gathered per-client values are reduced on the
host, never to a device-side scalar; every gather goes through the
``optimization_barrier``-pinned ``aggregation.client_all_gather``; window
accumulations are raw sums scaled once at the end (FMA contraction moves
bits otherwise).
"""
from __future__ import annotations

import ast

from tools.repro_lint.context import terminal_name
from tools.repro_lint.registry import rule

# --------------------------------------------------------------------------
# RL301

_SCALAR_REDUCERS = frozenset({
    "mean", "sum", "std", "var", "max", "min", "prod", "median",
})


def _is_gather_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and terminal_name(node.func) == "client_all_gather")


@rule("RL301", "device-side scalar reduction over gathered per-client [C] "
               "values in traced code")
def check_device_scalar_reduce(ctx):
    for fn in ctx.scopes.functions:
        if not ctx.scopes.is_traced(fn):
            continue
        gathered = set()
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Assign) and _is_gather_call(stmt.value):
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Name):
                        gathered.add(tgt.id)
        for call in ast.walk(fn):
            if not (isinstance(call, ast.Call)
                    and isinstance(call.func, ast.Attribute)
                    and call.func.attr in _SCALAR_REDUCERS):
                continue
            if len(call.args) != 1 or any(k.arg == "axis"
                                          for k in call.keywords):
                continue  # axis-wise reduce is fine; scalar collapse is not
            arg = call.args[0]
            is_gathered = _is_gather_call(arg) or (
                isinstance(arg, ast.Name) and arg.id in gathered)
            if is_gathered and ctx.scopes.in_traced_scope(call):
                yield (call.lineno,
                       f"`{call.func.attr}` collapses a client_all_gather'd "
                       "[C] value to a device-side scalar inside traced "
                       "code — the reduce order is fusion-context-sensitive; "
                       "emit the per-client vector and np.mean on the host "
                       "(docs/architecture.md §The bitwise contract)")


# --------------------------------------------------------------------------
# RL302


@rule("RL302", "raw lax.all_gather without an optimization_barrier in the "
               "enclosing function")
def check_unpinned_gather(ctx):
    for call in ast.walk(ctx.tree):
        if not (isinstance(call, ast.Call)
                and terminal_name(call.func) == "all_gather"):
            continue
        outer = ctx.scopes.outermost_function(call)
        haystack = outer if outer is not None else ctx.tree
        pinned = any(isinstance(n, ast.Call)
                     and terminal_name(n.func) == "optimization_barrier"
                     for n in ast.walk(haystack))
        if not pinned:
            yield (call.lineno,
                   "raw `lax.all_gather` without `optimization_barrier`: "
                   "XLA may fuse a scalar reduce across the gathered axis "
                   "and reassociate the fp32 sum; use "
                   "aggregation.client_all_gather (barrier-pinned) instead")


# --------------------------------------------------------------------------
# RL303


def _contains_scaling(node: ast.AST) -> bool:
    return any(isinstance(n, ast.BinOp) and isinstance(n.op, (ast.Mult, ast.Div))
               for n in ast.walk(node))


@rule("RL303", "scaled accumulation inside a loop in traced code (raw-sum-"
               "then-scale required)")
def check_scaled_accumulation(ctx):
    for loop in ast.walk(ctx.tree):
        if not isinstance(loop, (ast.For, ast.While)):
            continue
        if not ctx.scopes.in_traced_scope(loop):
            continue
        for stmt in ast.walk(loop):
            acc, contrib = None, None
            if (isinstance(stmt, ast.AugAssign) and isinstance(stmt.op, ast.Add)
                    and isinstance(stmt.target, ast.Name)):
                acc, contrib = stmt.target.id, stmt.value
            elif (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)
                    and isinstance(stmt.value, ast.BinOp)
                    and isinstance(stmt.value.op, ast.Add)):
                tgt = stmt.targets[0].id
                left, right = stmt.value.left, stmt.value.right
                if isinstance(left, ast.Name) and left.id == tgt:
                    acc, contrib = tgt, right
                elif isinstance(right, ast.Name) and right.id == tgt:
                    acc, contrib = tgt, left
            if acc is not None and contrib is not None \
                    and _contains_scaling(contrib):
                yield (stmt.lineno,
                       f"loop accumulates `{acc} += <scaled term>` in traced "
                       "code: XLA may contract the multiply-add into an FMA "
                       "and move bits; accumulate raw sums and scale once "
                       "after the loop (docs/architecture.md §The bitwise "
                       "contract, window sums)")
