"""RL2xx — sharding/collective discipline.

Collectives must thread ``axis_name`` from the shard_map/ScanCarryPlan
plumbing (a string literal silently pins one mesh layout); the engine is
shard_map-only; and the scan runner donates its carry.
"""
from __future__ import annotations

import ast

from tools.repro_lint.context import terminal_name
from tools.repro_lint.registry import rule

# --------------------------------------------------------------------------
# RL201

# collective -> index of its axis_name positional argument
_COLLECTIVES = {
    "psum": 1, "pmean": 1, "pmax": 1, "pmin": 1, "ppermute": 1,
    "pshuffle": 1, "all_gather": 1, "all_to_all": 1, "psum_scatter": 1,
    "pbroadcast": 1, "axis_index": 0,
}


@rule("RL201", "collective called with a string-literal axis_name instead "
               "of the threaded parameter")
def check_literal_axis_name(ctx):
    for call in ast.walk(ctx.tree):
        if not isinstance(call, ast.Call):
            continue
        name = terminal_name(call.func)
        if name not in _COLLECTIVES:
            continue
        axis = None
        for kw in call.keywords:
            if kw.arg == "axis_name":
                axis = kw.value
        if axis is None:
            pos = _COLLECTIVES[name]
            if len(call.args) > pos:
                axis = call.args[pos]
        if isinstance(axis, ast.Constant) and isinstance(axis.value, str):
            yield (call.lineno,
                   f"`{name}(..., axis_name={axis.value!r})` hardcodes the "
                   "mesh axis; thread axis_name from the shard_map / "
                   "ScanCarryPlan plumbing so lowerings stay layout-agnostic")


# --------------------------------------------------------------------------
# RL202

_BANNED = frozenset({"pmap", "soft_pmap", "xmap"})


@rule("RL202", "pmap/xmap usage (banned: this repo is shard_map-only)")
def check_pmap_ban(ctx):
    for node in ast.walk(ctx.tree):
        name = None
        if isinstance(node, ast.Attribute):
            name = node.attr
        elif isinstance(node, ast.Name):
            name = node.id
        if name in _BANNED:
            yield (node.lineno,
                   f"`{name}` is banned — the engine is shard_map-only "
                   "(single jit program, donated scan carry); see "
                   "docs/architecture.md")


# --------------------------------------------------------------------------
# RL203


def _assigned_names(stmt: ast.AST) -> set:
    out = set()
    for n in ast.walk(stmt):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
            out.add(n.id)
    return out


@rule("RL203", "donated scan-carry buffer read after the runner call")
def check_donated_carry_read(ctx):
    # The compiled runners (core/rounds._scan_runner) donate argnums=(0,):
    # after `state, m = runner(state, xs)` the *old* `state` buffers are
    # dead. Rebinding the name on the call's own assignment (the idiom) is
    # fine; loading it afterwards without a rebind is a use-after-free.
    # Factories like `_scan_runner(loss_fn, spec, ...)` *return* the runner;
    # calls to a name that is def'd in this module are factory calls, not
    # donating invocations.
    factory_defs = {f.name for f in ctx.scopes.functions
                    if not isinstance(f, ast.Lambda)}
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = terminal_name(node.func)
        if not name or not (name == "runner" or name.endswith("_runner")):
            continue
        if name in factory_defs:
            continue
        if not node.args or not isinstance(node.args[0], ast.Name):
            continue
        scope = ctx.scopes.enclosing_function(node) or ctx.tree
        donated = node.args[0].id
        names = [n for n in ast.walk(scope)
                 if isinstance(n, ast.Name) and n.id == donated
                 and (ctx.scopes.enclosing_function(n) or ctx.tree) is scope]
        store_lines = sorted(n.lineno for n in names
                             if isinstance(n.ctx, ast.Store)
                             and n.lineno >= node.lineno)
        for n in sorted(names, key=lambda n: n.lineno):
            if (isinstance(n.ctx, ast.Load) and n.lineno > node.lineno
                    and not any(node.lineno <= s <= n.lineno
                                for s in store_lines)):
                yield (n.lineno,
                       f"`{donated}` was donated to `{name}(...)` on line "
                       f"{node.lineno} (donate_argnums=(0,) carry) and is "
                       "read afterwards — the buffer is dead; use the "
                       "returned state")
                break


# --------------------------------------------------------------------------
# RL205

_MIX_KIND_CONSTS = frozenset({"ALL_REDUCE", "NEIGHBOR_PERMUTE", "GATHER",
                              "PSUM", "SEGMENT", "CLUSTER", "ROBUST"})
_MIX_KIND_STRINGS = frozenset({"all_reduce", "neighbor_permute", "gather",
                               "psum", "segment", "cluster", "robust"})


def _side_names(node):
    if isinstance(node, ast.Tuple):
        return [terminal_name(e) for e in node.elts]
    return [terminal_name(node)]


def _mix_kind_literal(node) -> bool:
    if isinstance(node, ast.Constant):
        return node.value in _MIX_KIND_STRINGS
    if isinstance(node, ast.Tuple):
        return any(isinstance(e, ast.Constant)
                   and e.value in _MIX_KIND_STRINGS for e in node.elts)
    return False


@rule("RL205", "MixLowering kind dispatched outside core/topology.py "
               "(resolve_mix_plan is the single decision surface)")
def check_mix_kind_dispatch(ctx):
    # core/topology.py's resolve_mix_plan is the ONE place allowed to look
    # at lowering kinds; everything downstream switches on the resolved
    # MixPlan.mode (the disjoint EXEC_* strings). Re-deriving a decision
    # from a kind string elsewhere is exactly the dispatch drift the
    # resolver refactor deleted.
    if ctx.path.replace("\\", "/").endswith("core/topology.py"):
        return
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Compare):
            sides = [node.left] + list(node.comparators)
            if any(nm in _MIX_KIND_CONSTS
                   for side in sides for nm in _side_names(side)):
                yield (node.lineno,
                       "comparison against a MixLowering kind constant — "
                       "dispatch on the resolved MixPlan.mode from "
                       "topology.resolve_mix_plan instead")
                continue
            if any("kind" in _side_names(side) for side in sides) \
                    and any(_mix_kind_literal(s) for s in sides):
                yield (node.lineno,
                       "comparison of `.kind` against a MixLowering kind "
                       "string — dispatch on the resolved MixPlan.mode "
                       "from topology.resolve_mix_plan instead")
        elif (isinstance(node, ast.Attribute) and node.attr == "kind"
              and isinstance(node.value, ast.Call)
              and terminal_name(node.value.func) == "lowering"):
            yield (node.lineno,
                   "`.lowering(...).kind` accessed outside the resolver — "
                   "consume topology.resolve_mix_plan(spec).mode/kind "
                   "instead of re-deriving the lowering")
