"""RL2xx — sharding/collective discipline.

Collectives must thread ``axis_name`` from the shard_map/ScanCarryPlan
plumbing (a string literal silently pins one mesh layout); the engine is
shard_map-only; and the scan runner donates its carry.
"""
from __future__ import annotations

import ast

from tools.repro_lint.context import terminal_name
from tools.repro_lint.registry import rule

# --------------------------------------------------------------------------
# RL201

# collective -> index of its axis_name positional argument
_COLLECTIVES = {
    "psum": 1, "pmean": 1, "pmax": 1, "pmin": 1, "ppermute": 1,
    "pshuffle": 1, "all_gather": 1, "all_to_all": 1, "psum_scatter": 1,
    "pbroadcast": 1, "axis_index": 0,
}


@rule("RL201", "collective called with a string-literal axis_name instead "
               "of the threaded parameter")
def check_literal_axis_name(ctx):
    for call in ast.walk(ctx.tree):
        if not isinstance(call, ast.Call):
            continue
        name = terminal_name(call.func)
        if name not in _COLLECTIVES:
            continue
        axis = None
        for kw in call.keywords:
            if kw.arg == "axis_name":
                axis = kw.value
        if axis is None:
            pos = _COLLECTIVES[name]
            if len(call.args) > pos:
                axis = call.args[pos]
        if isinstance(axis, ast.Constant) and isinstance(axis.value, str):
            yield (call.lineno,
                   f"`{name}(..., axis_name={axis.value!r})` hardcodes the "
                   "mesh axis; thread axis_name from the shard_map / "
                   "ScanCarryPlan plumbing so lowerings stay layout-agnostic")


# --------------------------------------------------------------------------
# RL202

_BANNED = frozenset({"pmap", "soft_pmap", "xmap"})


@rule("RL202", "pmap/xmap usage (banned: this repo is shard_map-only)")
def check_pmap_ban(ctx):
    for node in ast.walk(ctx.tree):
        name = None
        if isinstance(node, ast.Attribute):
            name = node.attr
        elif isinstance(node, ast.Name):
            name = node.id
        if name in _BANNED:
            yield (node.lineno,
                   f"`{name}` is banned — the engine is shard_map-only "
                   "(single jit program, donated scan carry); see "
                   "docs/architecture.md")


# --------------------------------------------------------------------------
# RL203


def _assigned_names(stmt: ast.AST) -> set:
    out = set()
    for n in ast.walk(stmt):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
            out.add(n.id)
    return out


@rule("RL203", "donated scan-carry buffer read after the runner call")
def check_donated_carry_read(ctx):
    # The compiled runners (core/rounds._scan_runner) donate argnums=(0,):
    # after `state, m = runner(state, xs)` the *old* `state` buffers are
    # dead. Rebinding the name on the call's own assignment (the idiom) is
    # fine; loading it afterwards without a rebind is a use-after-free.
    # Factories like `_scan_runner(loss_fn, spec, ...)` *return* the runner;
    # calls to a name that is def'd in this module are factory calls, not
    # donating invocations.
    factory_defs = {f.name for f in ctx.scopes.functions
                    if not isinstance(f, ast.Lambda)}
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = terminal_name(node.func)
        if not name or not (name == "runner" or name.endswith("_runner")):
            continue
        if name in factory_defs:
            continue
        if not node.args or not isinstance(node.args[0], ast.Name):
            continue
        scope = ctx.scopes.enclosing_function(node) or ctx.tree
        donated = node.args[0].id
        names = [n for n in ast.walk(scope)
                 if isinstance(n, ast.Name) and n.id == donated
                 and (ctx.scopes.enclosing_function(n) or ctx.tree) is scope]
        store_lines = sorted(n.lineno for n in names
                             if isinstance(n.ctx, ast.Store)
                             and n.lineno >= node.lineno)
        for n in sorted(names, key=lambda n: n.lineno):
            if (isinstance(n.ctx, ast.Load) and n.lineno > node.lineno
                    and not any(node.lineno <= s <= n.lineno
                                for s in store_lines)):
                yield (n.lineno,
                       f"`{donated}` was donated to `{name}(...)` on line "
                       f"{node.lineno} (donate_argnums=(0,) carry) and is "
                       "read afterwards — the buffer is dead; use the "
                       "returned state")
                break
