"""repro-lint core: file discovery, suppressions, baseline, reporting.

Findings are identified by ``(path, code)`` for baseline matching (line
numbers shift as files are edited; the baseline grants each ``(path, code)``
pair a fixed allowance and anything beyond it fails). Inline suppressions
use ``# repro-lint: disable=RL101`` (comma-separate multiple codes) on the
flagged line or on a comment line immediately above it.
"""
from __future__ import annotations

import argparse
import ast
import collections
import dataclasses
import json
import os
import re
import sys
from typing import Dict, List, Optional, Tuple

from tools.repro_lint.context import ScopeInfo
from tools.repro_lint.registry import RULES

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
DEFAULT_BASELINE = os.path.join(ROOT, "tools", "repro_lint", "baseline.json")
DEFAULT_PATHS = ("src", "benchmarks")

SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Z0-9,\s]+)")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    path: str   # repo-relative, posix separators
    line: int
    code: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


@dataclasses.dataclass
class FileContext:
    """Everything a rule's ``check`` gets to look at for one module."""
    path: str
    source: str
    tree: ast.Module
    lines: List[str]
    scopes: ScopeInfo


def _load_rules() -> None:
    # Importing the package registers every rule module exactly once.
    from tools.repro_lint import rules  # noqa: F401


def _suppressed_codes(lines: List[str], line_no: int) -> set:
    codes = set()
    for idx in (line_no - 1, line_no - 2):  # the line itself, then the one above
        if not 0 <= idx < len(lines):
            continue
        if idx == line_no - 2 and not lines[idx].strip().startswith("#"):
            continue  # the preceding line must be a pure comment
        m = SUPPRESS_RE.search(lines[idx])
        if m:
            codes.update(c.strip() for c in m.group(1).split(",") if c.strip())
    return codes


def lint_source(source: str, relpath: str) -> List[Finding]:
    """Run every registered rule over one module's source text."""
    _load_rules()
    tree = ast.parse(source, filename=relpath)
    ctx = FileContext(path=relpath, source=source, tree=tree,
                      lines=source.splitlines(), scopes=ScopeInfo(tree))
    findings = []
    for r in RULES.values():
        for line, message in r.check(ctx):
            if r.code in _suppressed_codes(ctx.lines, line):
                continue
            findings.append(Finding(path=relpath, line=line, code=r.code,
                                    message=message))
    return sorted(findings)


def iter_py_files(paths) -> List[Tuple[str, str]]:
    """Resolve CLI path args to ``(abspath, repo-relative posix path)``."""
    out = []
    for p in paths:
        absp = p if os.path.isabs(p) else os.path.join(ROOT, p)
        if os.path.isfile(absp):
            out.append(absp)
            continue
        for dirpath, dirnames, filenames in os.walk(absp):
            dirnames[:] = sorted(d for d in dirnames
                                 if d != "__pycache__" and not d.startswith("."))
            for name in sorted(filenames):
                if name.endswith(".py"):
                    out.append(os.path.join(dirpath, name))
    return [(a, os.path.relpath(a, ROOT).replace(os.sep, "/")) for a in out]


def lint_paths(paths) -> List[Finding]:
    findings = []
    for abspath, relpath in iter_py_files(paths):
        with open(abspath, encoding="utf-8") as fh:
            source = fh.read()
        findings.extend(lint_source(source, relpath))
    return sorted(findings)


def load_baseline(path: str) -> List[Dict]:
    if not os.path.exists(path):
        return []
    with open(path, encoding="utf-8") as fh:
        return json.load(fh).get("findings", [])


def apply_baseline(findings, baseline_entries):
    """Split findings into (fresh, waived) and report stale allowances."""
    allowance = collections.Counter(
        (e["path"], e["code"]) for e in baseline_entries)
    fresh, waived = [], []
    for f in findings:
        key = (f.path, f.code)
        if allowance.get(key, 0) > 0:
            allowance[key] -= 1
            waived.append(f)
        else:
            fresh.append(f)
    stale = {k: n for k, n in allowance.items() if n > 0}
    return fresh, waived, stale


def write_baseline(findings, path: str) -> None:
    entries = [{"path": f.path, "line": f.line, "code": f.code}
               for f in sorted(findings)]
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"comment": "Known debt waived by repro-lint; regenerate "
                              "with: python -m tools.repro_lint --write-baseline",
                   "findings": entries}, fh, indent=2)
        fh.write("\n")


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.repro_lint",
        description="AST lint for this repo's JAX tracing/sharding/fp32 "
                    "contracts (docs/architecture.md §Static contracts).")
    parser.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                        help="files/dirs relative to the repo root "
                             "(default: src benchmarks)")
    parser.add_argument("--baseline", default=DEFAULT_BASELINE,
                        help="baseline JSON of waived findings")
    parser.add_argument("--no-baseline", action="store_true",
                        help="report baselined findings too")
    parser.add_argument("--write-baseline", action="store_true",
                        help="rewrite the baseline from the current tree")
    parser.add_argument("--list-rules", action="store_true")
    args = parser.parse_args(argv)

    _load_rules()
    if args.list_rules:
        for code in sorted(RULES):
            print(f"{code}  {RULES[code].summary}")
        return 0

    findings = lint_paths(args.paths)
    if args.write_baseline:
        write_baseline(findings, args.baseline)
        print(f"wrote {len(findings)} finding(s) to "
              f"{os.path.relpath(args.baseline, ROOT)}")
        return 0

    baseline = [] if args.no_baseline else load_baseline(args.baseline)
    fresh, waived, stale = apply_baseline(findings, baseline)
    for f in fresh:
        print(f)
    for key, n in sorted(stale.items()):
        print(f"warning: stale baseline entry {key[1]} x{n} for {key[0]} "
              f"(regenerate with --write-baseline)")
    print(f"repro-lint: {len(fresh)} finding(s), {len(waived)} baselined, "
          f"{len(RULES)} rules")
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
