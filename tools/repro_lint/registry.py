"""Rule registry. A rule is ``check(ctx) -> iterable[(line, message)]`` over
one :class:`tools.repro_lint.engine.FileContext`, registered under a stable
``RLxxx`` code used by suppressions and the baseline."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Tuple


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    summary: str
    check: Callable[["FileContext"], Iterable[Tuple[int, str]]]  # noqa: F821


RULES: Dict[str, Rule] = {}


def rule(code: str, summary: str):
    def deco(fn):
        if code in RULES:
            raise ValueError(f"duplicate rule code {code}")
        RULES[code] = Rule(code=code, summary=summary, check=fn)
        return fn
    return deco
