"""repro-lint: AST checks for this repo's JAX tracing/sharding/fp32
contracts (docs/architecture.md §Static contracts).

CLI: ``python -m tools.repro_lint [paths ...]`` — exits 1 on any finding
not waived by ``tools/repro_lint/baseline.json`` or an inline
``# repro-lint: disable=RLxxx`` comment. Pure stdlib; never imports jax.
"""
from tools.repro_lint.engine import (  # noqa: F401
    Finding,
    apply_baseline,
    lint_paths,
    lint_source,
    load_baseline,
    main,
)
from tools.repro_lint.registry import RULES  # noqa: F401
