"""Repo tooling (doc-link checker, repro-lint). Import as ``tools.*`` with the
repo root on ``sys.path``; nothing here imports jax, so the CI lint lane runs
on a bare Python."""
